import os
import pathlib
import sys

# Smoke tests and benches run on the single real CPU device; only
# launch/dryrun.py (never imported here) installs fake devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:  # property tests use hypothesis when available ...
    import hypothesis  # noqa: F401
except ImportError:  # ... and a deterministic shim otherwise
    sys.path.insert(0, str(pathlib.Path(__file__).parent))
    import _hypothesis_shim

    _hypothesis_shim.install()

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
