import os

# Smoke tests and benches run on the single real CPU device; only
# launch/dryrun.py (never imported here) installs fake devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
