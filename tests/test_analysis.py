"""Static-analysis toolchain: linter rules, jaxpr audit, recompile
sentinel, donation effectiveness, and the trace-contract goldens.

The multi-device contract test runs in a subprocess with 4 forced host
devices (same pattern as test_tp) and asserts the acceptance criterion:
the static per-site psum counts read off the decode jaxpr equal BOTH the
trace-time ``dist.psum`` counter deltas and the committed golden manifest.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

from repro.analysis import lint

# ---------------------------------------------------------------------------
# REPRO linter rules (fixtures per rule)
# ---------------------------------------------------------------------------


def _rules(src: str, path: str = "mod.py") -> list[str]:
    return [f.rule for f in lint.lint_source(textwrap.dedent(src), path)]


def test_repro001_flags_old_eval_ppl_pattern():
    """The exact per-batch host-sync shape optim/losses.py shipped with
    (``float(nll)`` inside the eval loop) is caught."""
    src = """
        import jax

        def eval_ppl(cfg, params, batches):
            fn = jax.jit(lambda p, b: loss(p, b))
            tot, n = 0.0, 0
            for b in batches:
                nll = fn(params, b)
                tot += float(nll)
                n += 1
            return tot / n
    """
    assert "REPRO001" in _rules(src)


def test_repro001_single_sync_outside_loop_ok():
    src = """
        import jax

        def eval_once(params, b):
            fn = jax.jit(lambda p, b: loss(p, b))
            nll = fn(params, b)
            return float(nll)
    """
    assert "REPRO001" not in _rules(src)


def test_repro001_np_asarray_inside_scan_body():
    src = """
        import jax, numpy as np

        def body(carry, x):
            host = np.asarray(x)
            return carry, host

        def run(xs):
            return jax.lax.scan(body, 0, xs)
    """
    assert "REPRO001" in _rules(src)


def test_repro002_clock_pair_without_fence():
    src = """
        import jax, time

        def bench(params, b):
            fn = jax.jit(lambda p, b: p)
            t0 = time.perf_counter()
            out = fn(params, b)
            return time.perf_counter() - t0
    """
    assert "REPRO002" in _rules(src)


def test_repro002_fenced_clock_pair_ok():
    src = """
        import jax, time

        def bench(params, b):
            fn = jax.jit(lambda p, b: p)
            t0 = time.perf_counter()
            out = fn(params, b)
            jax.block_until_ready(out)
            return time.perf_counter() - t0
    """
    assert "REPRO002" not in _rules(src)


def test_repro003_silent_except_and_justified_except():
    silent = """
        def f(x):
            try:
                return g(x)
            except ValueError:
                return None
    """
    assert "REPRO003" in _rules(silent)
    justified = """
        def f(x):
            try:
                return g(x)
            except ValueError:
                return None  # absent cache: recompute downstream
    """
    assert "REPRO003" not in _rules(justified)
    warned = """
        import warnings

        def f(x):
            try:
                return g(x)
            except ValueError:
                warnings.warn("fallback")
                return None
    """
    assert "REPRO003" not in _rules(warned)


def test_repro004_np_in_kernel_body_only_under_kernels_path():
    src = """
        import numpy as np

        def add_kernel(x_ref, o_ref):
            o_ref[...] = np.tanh(x_ref[...])
    """
    assert "REPRO004" in _rules(src, "src/repro/kernels/ops.py")
    assert "REPRO004" not in _rules(src, "src/repro/serve/engine.py")


def test_repro005_unhashable_static_args():
    src = """
        import jax

        def run(xs):
            fn = jax.jit(step, static_argnums=(1,))
            return fn(xs, [1, 2, 3])
    """
    assert "REPRO005" in _rules(src)
    kw = """
        import jax

        def run(xs):
            fn = jax.jit(step, static_argnames=("shape",))
            return fn(xs, shape=[1, 2])
    """
    assert "REPRO005" in _rules(kw)
    # a list fed to a NON-static arg is a normal pytree input: clean
    ok = """
        import jax

        def run(xs):
            fn = jax.jit(step)
            return fn(xs, [1, 2, 3])
    """
    assert "REPRO005" not in _rules(ok)


def test_repro006_zip_tree_leaves():
    src = """
        import jax

        def pair(a, b):
            return list(zip(jax.tree.leaves(a), jax.tree.leaves(b)))
    """
    assert "REPRO006" in _rules(src)
    strict = """
        import jax

        def pair(a, b):
            return list(zip(jax.tree.leaves(a), jax.tree.leaves(b),
                            strict=True))
    """
    assert "REPRO006" not in _rules(strict)


def test_repro007_xla_flags_clobber():
    src = """
        import os

        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    """
    assert "REPRO007" in _rules(src)
    # appending to the user's existing flags is the sanctioned pattern
    append = """
        import os

        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                                   " --xla_force_host_platform_device_count=8")
    """
    assert "REPRO007" not in _rules(append)
    getenv = """
        import os

        os.environ["XLA_FLAGS"] = (os.getenv("XLA_FLAGS", "") + " --foo")
    """
    assert "REPRO007" not in _rules(getenv)
    # other env vars are none of this rule's business
    other = """
        import os

        os.environ["JAX_PLATFORMS"] = "cpu"
    """
    assert "REPRO007" not in _rules(other)
    # the assignment usually sits at module scope (pre-jax-import); the
    # rule must also catch it inside a function body
    in_fn = """
        import os

        def force(n):
            os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    """
    assert "REPRO007" in _rules(in_fn)
    suppressed = """
        import os

        os.environ["XLA_FLAGS"] = "--foo"  # noqa: REPRO007
    """
    assert _rules(suppressed) == []


def test_noqa_suppression():
    src = """
        import jax

        def eval_ppl(params, batches):
            fn = jax.jit(lambda p, b: p)
            tot = 0.0
            for b in batches:
                nll = fn(params, b)
                tot += float(nll)  # noqa: REPRO001
            return tot
    """
    assert _rules(src) == []


def test_lint_src_tree_clean():
    """The repo's own src/ tree lints clean - the CI gate, in-process."""
    root = pathlib.Path(__file__).parent.parent / "src"
    findings = lint.lint_paths([root])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_is_dependency_free():
    """The linter must import (and run) without jax/numpy installed -
    simulated by stubbing both out of sys.modules in a subprocess."""
    code = textwrap.dedent("""
        import sys
        sys.modules["jax"] = None
        sys.modules["numpy"] = None
        from repro.analysis import lint
        fs = lint.lint_source("def f():\\n    return 1\\n")
        assert fs == []
        print("ok")
    """)
    r = subprocess.run([sys.executable, "-c", code],
                       capture_output=True, text=True,
                       env={**os.environ, "PYTHONPATH": "src"},
                       cwd=str(pathlib.Path(__file__).parent.parent))
    assert r.returncode == 0 and "ok" in r.stdout, (r.stdout, r.stderr)


# ---------------------------------------------------------------------------
# jaxpr audit (single device)
# ---------------------------------------------------------------------------


def test_audit_counts_primitives_and_recurses_into_scan():
    import jax
    import jax.numpy as jnp
    from repro.analysis import jaxpr_audit

    def f(xs):
        def body(c, x):
            return c + jnp.sin(x), c
        return jax.lax.scan(body, jnp.zeros(()), xs)

    rep = jaxpr_audit.audit_fn(f, jnp.ones((8,)), surface="scanny")
    assert rep.primitives.get("scan") == 1
    assert rep.primitives.get("sin", 0) >= 1   # found inside the scan body
    assert rep.host_callbacks == []
    assert rep.surface == "scanny"


def test_audit_flags_host_callback():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis import jaxpr_audit

    def f(x):
        y = jax.pure_callback(
            lambda v: np.asarray(v) * 2,
            jax.ShapeDtypeStruct((4,), jnp.float32), x)
        return y + 1

    rep = jaxpr_audit.audit_fn(f, jnp.ones((4,)))
    assert len(rep.host_callbacks) == 1
    assert "callback" in rep.host_callbacks[0]["primitive"]


def test_audit_flags_large_bf16_upcast_but_not_small():
    import jax.numpy as jnp
    from repro.analysis import jaxpr_audit

    def f(x, s):
        return x.astype(jnp.float32).sum() + s.astype(jnp.float32)

    big = jnp.zeros((256, 256), jnp.bfloat16)      # 65536 >= threshold
    small = jnp.zeros((4,), jnp.bfloat16)
    rep = jaxpr_audit.audit_fn(f, big, small)
    assert rep.large_f32_upcasts == 1
    assert rep.upcasts[0]["numel"] == 65536


def test_audit_bytes_and_dtypes():
    import jax.numpy as jnp
    from repro.analysis import jaxpr_audit

    def f(x):
        return x * 2

    rep = jaxpr_audit.audit_fn(f, jnp.zeros((16, 16), jnp.bfloat16))
    assert rep.arg_bytes == 16 * 16 * 2
    assert rep.out_bytes == 16 * 16 * 2
    assert "bfloat16" in rep.dtypes


# ---------------------------------------------------------------------------
# donation effectiveness
# ---------------------------------------------------------------------------


def test_donation_same_dtype_aliases():
    import jax.numpy as jnp
    from repro.analysis import jaxpr_audit

    d = jaxpr_audit.audit_donation(lambda x: x + 1.0,
                                   (jnp.zeros((64, 64), jnp.float32),), (0,))
    assert d["declared"] == 1
    assert d["aliased"] >= 1, d
    assert d["undonated_warnings"] == [], d


def test_donation_dtype_change_reported_undonated():
    """bf16 in, f32 out: XLA cannot alias the donated buffer - the audit
    must surface the silently-ignored donation."""
    import jax.numpy as jnp
    from repro.analysis import jaxpr_audit

    d = jaxpr_audit.audit_donation(
        lambda x: x.astype(jnp.float32) + 1.0,
        (jnp.zeros((64, 64), jnp.bfloat16),), (0,))
    assert d["declared"] == 1
    assert d["aliased"] == 0, d
    assert d["undonated_warnings"], d


# ---------------------------------------------------------------------------
# recompile sentinel
# ---------------------------------------------------------------------------


def test_recompile_sentinel_counts_and_budget():
    import jax.numpy as jnp
    from repro.analysis import recompile

    recompile.enable(budgets={"decode": 2})
    try:
        a = jnp.zeros((4,), jnp.bfloat16)
        assert recompile.note("decode", (a,)) is True
        assert recompile.note("decode", (a,)) is False      # same signature
        assert recompile.counts()["decode"] == 1
        b = a.astype(jnp.float32)                           # dtype change
        assert recompile.note("decode", (b,)) is True
        assert recompile.counts()["decode"] == 2
        with pytest.raises(recompile.RecompileBudgetError):
            recompile.note("decode", (jnp.zeros((5,), jnp.bfloat16),))
    finally:
        recompile.disable()


def test_recompile_sentinel_disabled_is_noop():
    from repro.analysis import recompile
    recompile.disable()
    recompile.reset()
    assert recompile.note("decode", (1, 2)) is False
    assert recompile.counts() == {}


def test_recompile_sentinel_on_live_engine():
    """Steady-state decode holds ONE signature; an induced cache dtype
    change trips the budget BEFORE the retrace dispatches."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro import obs
    from repro.analysis import recompile
    from repro.configs.base import get_smoke_config
    from repro.models import model as M
    from repro.serve.engine import ServeEngine

    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=32)
    obs.configure(enabled=True)
    recompile.enable(budgets={"decode": 1})
    try:
        eng.submit(np.arange(1, 6) % cfg.vocab_size, 3)
        eng.run()
        assert recompile.counts().get("decode") == 1
        assert obs.gauge_value("analysis.recompiles", surface="decode") == 1
        # a second identical-shape request adds no signature
        eng.submit(np.arange(2, 7) % cfg.vocab_size, 2)
        eng.run()
        assert recompile.counts()["decode"] == 1
        # induced dtype flip on the caches: the sentinel trips on the next
        # decode step BEFORE the retrace dispatches
        eng.caches = jax.tree.map(
            lambda a: a.astype(jnp.float16)
            if jnp.issubdtype(a.dtype, jnp.floating) else a, eng.caches)
        with pytest.raises(recompile.RecompileBudgetError):
            eng._step()
    finally:
        recompile.disable()
        obs.disable()
        obs.reset()


# ---------------------------------------------------------------------------
# contracts: manifest diffing (pure) + the multi-device golden check
# ---------------------------------------------------------------------------


def test_contract_diff_structure():
    from repro.analysis import contracts
    g = {"surfaces": {"decode": {"psums_by_site": {"mlp": 2},
                                 "host_callbacks": 0}}}
    same = {"surfaces": {"decode": {"psums_by_site": {"mlp": 2},
                                    "host_callbacks": 0}}}
    assert contracts.diff_manifests(g, same,
                                    fields=("psums_by_site",
                                            "host_callbacks")) == []
    drift = {"surfaces": {"decode": {"psums_by_site": {"mlp": 4},
                                     "host_callbacks": 0}}}
    diffs = contracts.diff_manifests(g, drift, fields=("psums_by_site",))
    assert diffs == [{"surface": "decode", "field": "psums_by_site",
                      "golden": {"mlp": 2}, "current": {"mlp": 4}}]
    missing = {"surfaces": {}}
    diffs = contracts.diff_manifests(g, missing)
    assert diffs[0]["current"] == "missing"


def test_contract_check_missing_golden_fails(tmp_path):
    from repro.analysis import contracts
    ok, diffs = contracts.check(tmp_path / "nope.json", {"surfaces": {}})
    assert not ok and diffs


def test_contract_policy_violations():
    from repro.analysis import contracts
    man = {"surfaces": {
        "decode": {"policy": "serve", "host_callbacks": 1,
                   "large_f32_upcasts": 2, "dtypes": ["float64"]},
        "search_chunk": {"policy": "train", "host_callbacks": 0,
                         "large_f32_upcasts": 8, "dtypes": ["float32"]}}}
    v = contracts.policy_violations(man)
    fields = {(x["surface"], x["field"]) for x in v}
    assert ("decode", "host_callbacks") in fields
    assert ("decode", "large_f32_upcasts") in fields
    assert ("decode", "dtypes") in fields
    # train surfaces may upcast in the backward: not a policy violation
    assert ("search_chunk", "large_f32_upcasts") not in fields


def _run_forced_4dev(code: str) -> None:
    prelude = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c",
                        prelude + textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=str(pathlib.Path(__file__).parent.parent),
                       timeout=1200)
    assert r.returncode == 0 and "ok" in r.stdout, (r.stdout, r.stderr)


def test_static_psums_match_counters_and_golden_4dev():
    """Acceptance criterion: the per-site psum counts read STATICALLY off
    the decode jaxpr on the (2,2) mesh are mlp=2/attn=4/attn_kv=2, equal
    the flight recorder's trace-time dist.psum counter deltas, and match
    the committed golden manifest."""
    _run_forced_4dev("""
    import jax
    from repro import obs
    from repro.analysis import contracts, jaxpr_audit, surfaces

    obs.configure(enabled=True)
    surfs = surfaces.serve_surfaces("llama3.2-1b", mesh_shape=(2, 2))
    dec = next(s for s in surfs if s.name == "decode")
    sites = ("mlp", "attn", "attn_kv", "moe")
    snap = lambda: {s: obs.counter_value("dist.psum", site=s)
                    for s in sites}
    c0 = snap()
    rep = jaxpr_audit.audit_fn(dec.fn, *dec.args, surface="decode")
    c1 = snap()   # audit_fn traced the surface -> counters advanced once
    delta = {s: int(c1[s] - c0[s]) for s in sites if c1[s] != c0[s]}
    assert rep.psums_by_site == {"mlp": 2, "attn": 4, "attn_kv": 2}, \\
        rep.psums_by_site
    assert delta == rep.psums_by_site, (delta, rep.psums_by_site)

    man = contracts.build_manifest("llama3.2-1b", surfs, mesh_shape=(2, 2))
    ok, diffs = contracts.check("results/contracts/llama3.2-1b_2x2.json",
                                man)
    assert ok, diffs
    print("ok")
    """)
