"""MoE dispatch/combine invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe
from repro.models.common import Builder


def make(E=4, d=16, ff=32, shared=0):
    b = Builder("init", jax.random.key(0))
    return moe.moe_init(b, d_model=d, d_ff=ff, num_experts=E,
                        num_shared=shared)


def test_moe_output_shape_and_aux():
    p = make()
    x = 0.5 * jax.random.normal(jax.random.key(1), (2, 8, 16))
    y, aux = moe.moe_apply(p, x, top_k=2)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3  # E * E[f*p] >= 1 at any routing


def test_moe_dropless_equals_dense_mixture():
    """With capacity >= T*k the dispatch must equal the explicit mixture."""
    E, d, ff = 4, 16, 32
    p = make(E, d, ff)
    x = 0.5 * jax.random.normal(jax.random.key(1), (1, 8, d))
    y, _ = moe.moe_apply(p, x, top_k=2, capacity_factor=float(E))
    xt = x.reshape(-1, d)
    logits = xt @ p["router"]["kernel"]
    probs = jax.nn.softmax(logits, -1)
    gv, idx = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)

    def expert(e, t):
        up = xt[t] @ p["up"]["kernel"][e].astype(jnp.bfloat16)
        g = jax.nn.silu(xt[t] @ p["gate"]["kernel"][e].astype(jnp.bfloat16))
        return (up * g) @ p["down"]["kernel"][e].astype(jnp.bfloat16)

    want = np.zeros((8, d), np.float32)
    for t in range(8):
        for j in range(2):
            want[t] += float(gv[t, j]) * np.asarray(
                expert(int(idx[t, j]), t), np.float32)
    np.testing.assert_allclose(np.asarray(y.reshape(-1, d), np.float32),
                               want, rtol=5e-2, atol=5e-3)


def test_moe_capacity_drops_tokens():
    E, d = 4, 16
    p = make(E, d)
    x = 0.5 * jax.random.normal(jax.random.key(1), (1, 64, d))
    y_small, _ = moe.moe_apply(p, x, top_k=2, capacity_factor=0.5)
    y_big, _ = moe.moe_apply(p, x, top_k=2, capacity_factor=4.0)
    # dropping must change some outputs (and zero at least one token's y)
    assert not np.allclose(np.asarray(y_small), np.asarray(y_big))


def test_moe_shared_expert_added():
    p0 = make(shared=0)
    p1 = make(shared=1)
    for k in ("router", "up", "gate", "down"):
        p1[k] = p0[k]
    x = 0.5 * jax.random.normal(jax.random.key(1), (1, 8, 16))
    y0, _ = moe.moe_apply(p0, x, top_k=2, capacity_factor=4.0)
    y1, _ = moe.moe_apply(p1, x, top_k=2, capacity_factor=4.0)
    assert not np.allclose(np.asarray(y0), np.asarray(y1))


def test_moe_tape_stats_renormalized_by_routed_counts():
    """Per-expert activation stats must come from the actually-routed rows,
    rescaled to the layer's token count - NOT from the capacity-padded
    dispatch buffer sample size.  Hand-computed oracle: with top_k=1 and
    dropless capacity, expert e's stat is
    sqrt(sum_{tokens routed to e} x_j^2 * T / n_e)."""
    from repro.core import tape as tape_mod

    E, d, T = 4, 16, 8
    p = make(E=E, d=d)
    x = 0.5 * jax.random.normal(jax.random.key(2), (1, T, d), jnp.float32)
    t = tape_mod.StatsTape()
    t.register_layer(p, "", -1)
    with tape_mod.recording(t):
        moe.moe_apply(p, x, top_k=1, capacity_factor=float(E))
    stats = tape_mod.resolve_stats(t, p)

    # oracle routing: top-1 of the same fp32 router logits
    xt = np.asarray(x, np.float32).reshape(T, d)
    logits = xt @ np.asarray(p["router"]["kernel"], np.float32)
    routed_to = logits.argmax(-1)
    want = np.zeros((E, d), np.float64)
    for e in range(E):
        rows = xt[routed_to == e]
        if len(rows):
            want[e] = np.sqrt((rows.astype(np.float64) ** 2).sum(0)
                              * T / len(rows))
    assert (routed_to == routed_to[0]).mean() < 1.0  # >1 expert exercised
    np.testing.assert_allclose(np.asarray(stats["up"]["kernel"]), want,
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(stats["gate"]["kernel"]), want,
                               rtol=1e-5, atol=1e-6)
    # an expert that saw n_e < T tokens must NOT read diluted: its stat is
    # on the same T-token scale as a dense-FFN layer seeing every token
    counts = np.bincount(routed_to, minlength=E)
    e_small = counts.argmin()
    if counts[e_small]:
        undiluted = np.sqrt(
            (xt[routed_to == e_small].astype(np.float64) ** 2).sum(0))
        assert (np.asarray(stats["up"]["kernel"])[e_small].sum()
                >= undiluted.sum())


def test_positions_in_expert_capacity_semantics():
    flat_e = jnp.asarray([[0, 0, 0, 1, 0, 1]])
    e_idx, p_idx, keep, _ = moe._positions_in_expert(flat_e, E=2, C=2)
    np.testing.assert_array_equal(np.asarray(p_idx[0]), [0, 1, 2 * 0, 0, 0, 1])
    # third token to expert 0 dropped (pos 2 >= C)
    np.testing.assert_array_equal(np.asarray(keep[0]),
                                  [True, True, False, True, False, True])
