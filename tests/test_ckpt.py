"""Checkpointing: atomic roundtrip, async, resume, elastic re-shard plan,
straggler/failure policy."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ckpt.checkpoint import CheckpointManager
from repro.ckpt.straggler import HeartbeatMonitor, plan_recovery


def tree():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mu": jnp.ones((5,)), "count": jnp.asarray(3)},
            "none": None}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    state = tree()
    mgr.save(7, state, metadata={"next_step": 7})
    out, meta = mgr.restore(state)
    assert meta["next_step"] == 7
    np.testing.assert_array_equal(out["w"], np.asarray(state["w"]))
    np.testing.assert_array_equal(out["opt"]["mu"],
                                  np.asarray(state["opt"]["mu"]))
    assert out["none"] is None


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in [1, 2, 3]:
        mgr.save_async(s, tree())
    mgr.wait()
    assert mgr.latest_step() == 3
    assert mgr.all_steps() == [2, 3]  # keep=2 garbage-collects step 1


def test_torn_save_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, tree())
    # simulate a crash mid-save: stray tmp dir
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "junk.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 1
    out, _ = mgr.restore(tree())
    np.testing.assert_array_equal(out["w"], np.arange(12.0).reshape(3, 4))


def test_resave_same_step(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(5, tree())
    mgr.save(5, tree())  # periodic + final save collision must not raise
    assert mgr.latest_step() == 5


def test_restore_with_target_sharding(tmp_path):
    """Elastic restore: leaves are placed with the *target* sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, tree())
    sh = {"w": NamedSharding(mesh, P("data", None)),
          "opt": {"mu": NamedSharding(mesh, P()), "count": None},
          "none": None}
    out, _ = mgr.restore(tree(), shardings=sh)
    assert isinstance(out["w"], jax.Array)
    assert out["w"].sharding.spec == P("data", None)


# --- straggler / recovery ---------------------------------------------------

def test_heartbeat_failure_detection():
    mon = HeartbeatMonitor(4, timeout_s=10)
    for h in range(4):
        mon.beat(h, step=1, now=100.0, step_s=1.0)
    mon.beat(0, step=2, now=105.0, step_s=1.0)
    assert mon.failed(now=112.0) == [1, 2, 3]
    assert mon.failed(now=106.0) == []


def test_straggler_detection():
    mon = HeartbeatMonitor(4, straggler_factor=2.0)
    times = [1.0, 1.1, 0.9, 5.0]
    for h, t in enumerate(times):
        for s in range(5):
            mon.beat(h, step=s, now=float(s), step_s=t)
    assert mon.stragglers() == [3]
    assert 3 not in mon.healthy(now=4.0)


@settings(max_examples=25, deadline=None)
@given(n_fail=st.integers(0, 48), model_axis=st.sampled_from([8, 16]))
def test_recovery_plan_valid(n_fail, model_axis):
    hosts_total = 64
    chips = 4
    surviving = list(range(hosts_total - n_fail))
    if len(surviving) * chips < model_axis:
        return
    plan = plan_recovery(surviving, hosts_total=hosts_total,
                         old_mesh=(hosts_total * chips // model_axis,
                                   model_axis),
                         model_axis=model_axis, chips_per_host=chips)
    data, model = plan.mesh_shape
    assert model == model_axis
    assert data * model <= len(surviving) * chips
    old_data = hosts_total * chips // model_axis
    assert old_data % data == 0
    assert plan.accum_scale == old_data // data  # global batch preserved
    assert set(plan.hosts) <= set(surviving)
