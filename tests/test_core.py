"""UniPruning core: metrics, masks, prox, mirror-descent invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import calibrate, masks as masks_mod, metrics as metrics_mod
from repro.core import mirror, prox
from repro.core.prunable import prunable_map
from repro.data.synthetic import batches_for
from repro.models import model as M

TINY = ModelConfig(name="t", family="dense", d_model=64, num_layers=2,
                   num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128,
                   vocab_size=256)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(0.1, 10.0))
def test_metric_scale_behaviour(seed, scale):
    w = jax.random.normal(jax.random.key(seed), (32, 16))
    a = jnp.abs(jax.random.normal(jax.random.key(seed + 1), (32,)))
    # wanda scales linearly in W; RIA is scale-invariant in W
    np.testing.assert_allclose(metrics_mod.wanda(scale * w, a),
                               scale * metrics_mod.wanda(w, a), rtol=1e-5)
    np.testing.assert_allclose(metrics_mod.ria(scale * w, a),
                               metrics_mod.ria(w, a), rtol=1e-4, atol=1e-6)


def test_stochria_full_frac_equals_ria():
    w = jax.random.normal(jax.random.key(0), (32, 16))
    a = jnp.abs(jax.random.normal(jax.random.key(1), (32,)))
    s1 = metrics_mod.stochria(w, a, key=jax.random.key(2), frac=1.0)
    s2 = metrics_mod.ria(w, a)
    np.testing.assert_allclose(s1, s2, rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(sp=st.floats(0.05, 0.95), seed=st.integers(0, 1000))
def test_unstructured_mask_exact_sparsity(sp, seed):
    tree = {"a": jax.random.normal(jax.random.key(seed), (64, 32)),
            "b": jax.random.normal(jax.random.key(seed + 1), (128, 16))}
    m = masks_mod.unstructured_masks(tree, sp, scope="global")
    got = masks_mod.sparsity_of(m)
    assert abs(got - sp) < 0.02, (got, sp)


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([1, 2, 3]), m=st.sampled_from([4, 8]),
       seed=st.integers(0, 1000))
def test_nm_mask_constraint(n, m, seed):
    s = jax.random.normal(jax.random.key(seed), (64, 32))
    mask = jax.tree.leaves(masks_mod.nm_masks(s, n, m))[0]
    per_group = mask.reshape(64 // m, m, 32).sum(axis=1)
    assert bool(jnp.all(per_group == n))
    # kept entries are the group top-n by |s|
    grp = jnp.abs(s).reshape(64 // m, m, 32)
    kept_min = jnp.min(jnp.where(mask.reshape(64 // m, m, 32), grp, jnp.inf),
                       axis=1)
    dropped_max = jnp.max(
        jnp.where(mask.reshape(64 // m, m, 32), -jnp.inf, grp), axis=1)
    assert bool(jnp.all(kept_min >= dropped_max))


def test_threshold_bisect_matches_quantile():
    tree = {"a": jax.random.normal(jax.random.key(0), (512, 64))}
    for sp in [0.3, 0.6, 0.9]:
        t1 = float(masks_mod.global_threshold(tree, sp))
        t2 = float(masks_mod.threshold_bisect(tree, sp, iters=45))
        m = masks_mod.unstructured_masks(tree, sp, scope="global",
                                         exact=False)
        got = masks_mod.sparsity_of(m)
        assert abs(got - sp) < 5e-3, (sp, got)
        assert abs(t1 - t2) / (abs(t1) + 1e-9) < 1e-2


@settings(max_examples=20, deadline=None)
@given(lam=st.floats(0, 2, width=32), x=st.floats(-5, 5, width=32))
def test_soft_threshold_properties(lam, x):
    x = float(np.float32(x))  # the op runs in f32; avoid f64 subnormals
    lam = float(np.float32(lam))
    y = float(prox.soft_threshold(jnp.asarray(x), lam))
    assert abs(y) <= abs(x) + 1e-6
    if abs(x) <= lam:
        assert y == 0.0
    else:
        assert np.sign(y) == np.sign(x)
        assert abs(abs(y) - (abs(x) - lam)) < 1e-5


def test_prunable_map_excludes_embeddings():
    params = M.init_params(TINY, jax.random.key(0))
    pm = prunable_map(params)
    flat, _ = jax.tree_util.tree_flatten_with_path(pm)
    for kp, v in flat:
        path = jax.tree_util.keystr(kp)
        if "embed" in path or "norm" in path.lower():
            assert not v, path
        if "attn" in path and "kernel" in path and "norm" not in path:
            assert v, path


def _search_setup(steps=6, **kw):
    params = M.init_params(TINY, jax.random.key(0))
    calib = batches_for(TINY, n=4, batch=2, seq=32, split="calib")
    stats = calibrate.collect_stats(TINY, params, calib[:2])
    pcfg = PruneConfig(local_metric="wanda", steps=steps, **kw)
    return params, calib, stats, pcfg


def test_search_state_evolves_and_w0_untouched():
    params, calib, stats, pcfg = _search_setup()
    before = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    state, hist = calibrate.run_search(TINY, pcfg, params, calib, stats,
                                       log_every=1)
    # W0 untouched
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b))
    # Gamma engaged
    nz = sum(int(jnp.sum(g != 0)) for g in
             jax.tree.leaves(state.Gamma, is_leaf=lambda x: x is None)
             if g is not None)
    assert nz > 0
    assert int(state.step) == pcfg.steps


def test_one_shot_masks_nested_across_sparsity():
    """Higher sparsity mask must be a subset of lower sparsity mask."""
    params, calib, stats, pcfg = _search_setup()
    state, _ = calibrate.run_search(TINY, pcfg, params, calib, stats)
    m50 = mirror.export_masks(pcfg, state.Gamma, 0.5, V=state.V)
    m70 = mirror.export_masks(pcfg, state.Gamma, 0.7, V=state.V)
    for a, b in zip(jax.tree.leaves(m50, is_leaf=lambda x: x is None),
                    jax.tree.leaves(m70, is_leaf=lambda x: x is None)):
        if a is None:
            continue
        assert bool(jnp.all(jnp.where(b, a, True)))  # b => a


def test_nm_mode_produces_24_masks():
    params, calib, stats, pcfg = _search_setup(mode="nm")
    state, _ = calibrate.run_search(TINY, pcfg, params, calib, stats)
    masks = mirror.export_masks(pcfg, state.Gamma, 0.5, V=state.V)
    for mk in jax.tree.leaves(masks, is_leaf=lambda x: x is None):
        if mk is None:
            continue
        arr = np.asarray(mk)
        arr = arr.reshape(-1, 4, arr.shape[-1]) if arr.shape[0] % 4 == 0 \
            else None
        if arr is not None:
            assert (arr.sum(axis=1) == 2).all()


def test_apply_masks_zeroes_only_masked():
    params, calib, stats, pcfg = _search_setup(steps=3)
    state, _ = calibrate.run_search(TINY, pcfg, params, calib, stats)
    masks = mirror.export_masks(pcfg, state.Gamma, 0.6, V=state.V)
    pruned = masks_mod.apply_masks(params, masks)
    flat_m = jax.tree.leaves(masks, is_leaf=lambda x: x is None)
    for w0, w1, mk in zip(jax.tree.leaves(params), jax.tree.leaves(pruned),
                          flat_m):
        if mk is None:
            np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))
        else:
            np.testing.assert_array_equal(
                np.asarray(w1), np.asarray(w0 * mk.astype(w0.dtype)))
