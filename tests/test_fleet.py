"""Multi-budget sparsity fleet: one mask bank, N budgets, one router."""
import jax
import numpy as np
import pytest

from repro.configs.base import PruneConfig, get_smoke_config
from repro.core import calibrate
from repro.data.synthetic import batches_for
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.fleet import (Budget, SparsityFleet, parse_budget,
                               token_agreement)
from repro.sparse import apply as apply_mod
from repro.sparse.bank import MaskBank

CFG = get_smoke_config("llama3.2-1b")
BUDGETS = ["0.0", "0.5", "2:4"]


@pytest.fixture(scope="module")
def bank_setup(tmp_path_factory):
    params = M.init_params(CFG, jax.random.key(0))
    calib = batches_for(CFG, n=2, batch=2, seq=16, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=2)
    stats = calibrate.collect_stats(CFG, params, calib)
    state, _ = calibrate.run_search(CFG, pcfg, params, calib, stats)
    d = tmp_path_factory.mktemp("fleet") / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    return params, d


def test_parse_budget_spellings():
    assert parse_budget("2:4") == Budget("nm", nm=(2, 4))
    assert parse_budget((4, 8)) == Budget("nm", nm=(4, 8))
    assert parse_budget("0.5") == Budget("unstructured", sparsity=0.5)
    assert parse_budget(0.75).name == "0.75"
    for dense in ("0.0", "0", 0, 0.0, "dense"):
        assert parse_budget(dense) == Budget("dense")
    assert parse_budget("2:4").pruned_frac == 0.5
    assert parse_budget("0.75").pruned_frac == 0.75
    with pytest.raises(ValueError):
        parse_budget("1.5")
    assert token_agreement([1, 2, 3], [1, 9, 3]) == pytest.approx(2 / 3)
    assert token_agreement([1, 2], [1, 2, 3]) == pytest.approx(2 / 3)


def test_fleet_routes_each_budget_to_its_own_engine(bank_setup):
    """Tagged requests must return tokens from the engine serving THAT
    budget - each member token-identical to a standalone engine built from
    the same bank at the same budget, and the 0.0 member to a plain dense
    engine over params0 (the acceptance oracle)."""
    params, d = bank_setup
    fleet = SparsityFleet.from_artifact(d, params, BUDGETS, slots=6,
                                        capacity=32)
    # one calibration state load, one threshold pass per non-dense budget
    assert len(fleet.bank._mask_cache) == 2
    prompts = [np.array([5, 6, 7, 8]), np.array([9, 10, 11])]
    rids = {n: [fleet.submit(p, 5, budget=n) for p in prompts]
            for n in BUDGETS}
    res = fleet.run()
    outs = {n: [res[r] for r in rids[n]] for n in BUDGETS}

    bank = MaskBank.load(d)
    oracles = {
        "0.0": params,
        "0.5": bank.sparse_params(params, sparsity=0.5, compressed=False),
        "2:4": bank.sparse_params(params, nm=(2, 4), compressed=True),
    }
    for name, p in oracles.items():
        eng = ServeEngine(CFG, p, slots=2, capacity=32)
        want = [eng.submit(pr, 5) for pr in prompts]
        got = eng.run()
        assert outs[name] == [got[r] for r in want], name
    # every stream decoded to full length through its own member
    assert all(len(o) == 5 for n in BUDGETS for o in outs[n])


def test_fleet_materialization_is_shared_and_memoized(bank_setup):
    """Dense leaves pruning leaves untouched are the SAME buffers across
    members (one copy, not N); the dense member is params0 itself; repeated
    materialization at one budget returns the cached tree."""
    params, d = bank_setup
    fleet = SparsityFleet.from_artifact(d, params, BUDGETS, slots=3,
                                        capacity=32)
    assert fleet.engines["0.0"].params is params
    n_leaves = len(jax.tree.leaves(params))
    for name in ("0.5", "2:4"):
        sp = fleet.engines[name].params
        shared = apply_mod.shared_leaves(params, sp)
        assert 0 < shared < n_leaves  # embeddings/norms shared, kernels not
        assert fleet.reports[name]["shared_dense_leaves"] == shared
    assert fleet.reports["2:4"]["weight_bytes_ratio"] <= 9 / 16 + 1e-9
    assert fleet.reports["0.5"]["weight_bytes_ratio"] <= 1.0 + 1e-9
    # the threshold pass is memoized in the BANK: a second fleet over the
    # same bank re-uses the cached mask trees (no new quantile passes)
    before = dict(fleet.bank._mask_cache)
    SparsityFleet(fleet.bank, params, BUDGETS, slots=3, capacity=32)
    assert {k: id(v) for k, v in fleet.bank._mask_cache.items()} == \
        {k: id(v) for k, v in before.items()}


def test_fleet_ab_split_is_deterministic_and_scores_agreement(bank_setup):
    """ab= weights split traffic deterministically (weighted fair, no RNG)
    and off-reference picks are mirrored onto the densest member so the
    report carries live token-agreement."""
    params, d = bank_setup
    fleet = SparsityFleet.from_artifact(d, params, BUDGETS, slots=3,
                                        capacity=32)
    prompt = np.array([5, 6, 7, 8])
    ab = {"0.5": 3.0, "2:4": 1.0}
    rids = [fleet.submit(prompt, 3, ab=ab) for _ in range(8)]
    res = fleet.run()
    assert all(len(res[r]) == 3 for r in rids)
    rep = fleet.report()["budgets"]
    assert rep["0.5"]["requests"] == 6 and rep["2:4"]["requests"] == 2
    assert rep["0.0"]["requests"] == 0  # shadows are not routed requests
    # every A/B request was scored against the dense reference
    for name in ("0.5", "2:4"):
        agree = rep[name]["token_agreement_vs_reference"]
        assert agree is not None and 0.0 <= agree <= 1.0
    with pytest.raises(KeyError):
        fleet.submit(prompt, 3, ab={"0.9": 1.0})
    with pytest.raises(ValueError):
        fleet.submit(prompt, 3, budget="0.5", ab=True)


def test_fleet_report_keeps_shadow_traffic_out_of_headline(bank_setup):
    """A/B mirror (shadow) requests ride the reference member's batched
    steps but are NOT the reference's own traffic: they must accumulate
    under the member's ``shadow`` key and never inflate the headline
    tokens/tok_s (the old skew: shadow tokens padded the reference's token
    count while its request count ignored them, overstating tok_s)."""
    params, d = bank_setup
    fleet = SparsityFleet.from_artifact(d, params, BUDGETS, slots=3,
                                        capacity=32)
    prompt = np.array([5, 6, 7, 8])
    # all picks go to 0.5 -> every request mirrors onto the 0.0 reference
    rids = [fleet.submit(prompt, 4, ab={"0.5": 1.0}) for _ in range(3)]
    res = fleet.run()
    assert all(len(res[r]) == 4 for r in rids)
    rep = fleet.report()["budgets"]
    ref = rep["0.0"]
    # the reference served ONLY shadows: headline stays empty...
    assert ref["requests"] == 0 and ref["tokens"] == 0
    assert ref["tok_s"] is None
    assert ref["cumulative"]["seconds"] == 0.0
    # ...and the mirror work is fully visible under the shadow key
    assert ref["shadow"]["requests"] == 3
    assert ref["shadow"]["tokens"] == 12
    assert ref["shadow"]["seconds"] > 0.0
    # the picked member's headline counts its own traffic, shadow-free
    assert rep["0.5"]["requests"] == 3 and rep["0.5"]["tokens"] == 12
    assert rep["0.5"]["shadow"] == {"requests": 0, "tokens": 0,
                                    "seconds": 0.0}


def test_fleet_eos_frees_slot_and_reuses_it(bank_setup):
    """eos emitted on the FIRST decode step must free the member's slot and
    the queued request admitted into it must decode with no state leak -
    identical to a fresh single-budget engine with the same eos."""
    params, d = bank_setup
    p1, p2 = np.array([5, 6, 7, 8]), np.array([9, 10, 11])
    probe = SparsityFleet.from_artifact(d, params, BUDGETS, slots=3,
                                        capacity=32)
    r = probe.submit(p1, 8, budget="2:4")
    base = probe.run()[r]
    eos = base[0]  # the first token the 2:4 stream emits

    fleet = SparsityFleet.from_artifact(d, params, BUDGETS, slots=3,
                                        capacity=32, eos_id=eos)
    r1 = fleet.submit(p1, 8, budget="2:4")   # terminates on step 1
    r2 = fleet.submit(p2, 4, budget="2:4")   # queued: member has ONE slot
    out = fleet.run()
    assert out[r1] == [eos]                  # freed on the first decode step
    assert len(out[r2]) == 4
    bank = MaskBank.load(d)
    fresh = ServeEngine(CFG, bank.sparse_params(params, nm=(2, 4)),
                        slots=1, capacity=32, eos_id=eos)
    rf = fresh.submit(p2, 4)
    assert fresh.run()[rf] == out[r2]        # reused slot leaked nothing


def test_fleet_slot_pool_partition(bank_setup):
    params, d = bank_setup
    fleet = SparsityFleet.from_artifact(d, params, BUDGETS, slots=7,
                                        capacity=32)
    assert [fleet.engines[n].slots for n in BUDGETS] == [3, 2, 2]
    with pytest.raises(ValueError, match="slots"):
        SparsityFleet.from_artifact(d, params, BUDGETS, slots=2, capacity=32)
    with pytest.raises(ValueError, match="duplicate"):
        SparsityFleet.from_artifact(d, params, ["0.5", 0.5], capacity=32)
    # all members share ONE EngineFns: the jitted entry points are shared
    fns = {id(fleet.engines[n].fns) for n in BUDGETS}
    assert len(fns) == 1
