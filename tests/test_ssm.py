"""Chunked-parallel SSM/xLSTM forms vs step-by-step recurrence oracles."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ssm, xlstm
from repro.models.common import Builder


def test_mamba2_chunked_matches_recurrent():
    d_model, d_inner, d_state, hd = 32, 64, 16, 16
    p = ssm.mamba2_init(Builder("init", jax.random.key(0)), d_model=d_model,
                        d_inner=d_inner, d_state=d_state, head_dim=hd)
    B, S = 2, 48
    x = 0.5 * jax.random.normal(jax.random.key(1), (B, S, d_model))
    y_full, state_full = ssm.mamba2_apply_full(
        p, x, d_inner=d_inner, d_state=d_state, head_dim=hd, chunk=16,
        return_state=True)
    # recurrent decode, token by token
    st = ssm.mamba2_init_state(B, d_inner=d_inner, d_state=d_state,
                               head_dim=hd)
    ys = []
    for t in range(S):
        y_t, st = ssm.mamba2_apply_decode(p, x[:, t:t + 1], st,
                                          d_inner=d_inner, d_state=d_state,
                                          head_dim=hd)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=3e-2, atol=1e-2)  # bf16 conv/silu paths
    np.testing.assert_allclose(np.asarray(state_full["h"]),
                               np.asarray(st["h"]), rtol=3e-2, atol=3e-3)


def test_mamba2_nondivisible_length_padding():
    d_model, d_inner, d_state, hd = 16, 32, 8, 8
    p = ssm.mamba2_init(Builder("init", jax.random.key(0)), d_model=d_model,
                        d_inner=d_inner, d_state=d_state, head_dim=hd)
    x = 0.5 * jax.random.normal(jax.random.key(1), (1, 37, d_model))
    y, st = ssm.mamba2_apply_full(p, x, d_inner=d_inner, d_state=d_state,
                                  head_dim=hd, chunk=16, return_state=True)
    assert y.shape == (1, 37, d_model)
    assert not bool(jnp.isnan(y).any())
    # state must equal the state from an exactly-divisible run of the prefix
    y2, st2 = ssm.mamba2_apply_full(p, x[:, :32], d_inner=d_inner,
                                    d_state=d_state, head_dim=hd, chunk=16,
                                    return_state=True)
    np.testing.assert_allclose(np.asarray(y[:, :32], np.float32),
                               np.asarray(y2, np.float32), rtol=2e-2,
                               atol=2e-3)


def test_mlstm_chunked_matches_step():
    d_model, H = 32, 2
    p = xlstm.mlstm_init(Builder("init", jax.random.key(0)), d_model=d_model,
                         num_heads=H, proj_factor=2.0)
    B, S = 1, 40
    x = 0.5 * jax.random.normal(jax.random.key(1), (B, S, d_model))
    y_full, st_full = xlstm.mlstm_apply_full(p, x, num_heads=H, chunk=8,
                                             return_state=True)
    st = xlstm.mlstm_init_state(B, d_inner=2 * d_model, num_heads=H)
    ys = []
    for t in range(S):
        y_t, st = xlstm.mlstm_apply_decode(p, x[:, t:t + 1], st, num_heads=H)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full, np.float32),
                               np.asarray(y_seq, np.float32),
                               rtol=3e-2, atol=3e-3)
    np.testing.assert_allclose(np.asarray(st_full["C"]), np.asarray(st["C"]),
                               rtol=3e-2, atol=3e-3)


def test_slstm_state_continuity():
    d_model, H = 32, 2
    p = xlstm.slstm_init(Builder("init", jax.random.key(0)), d_model=d_model,
                         num_heads=H)
    x = 0.5 * jax.random.normal(jax.random.key(1), (1, 24, d_model))
    y_full, st_full = xlstm.slstm_apply(p, x, None, num_heads=H,
                                        return_state=True)
    y_a, st_a = xlstm.slstm_apply(p, x[:, :12], None, num_heads=H,
                                  return_state=True)
    y_b, st_b = xlstm.slstm_apply(p, x[:, 12:], st_a, num_heads=H,
                                  return_state=True)
    np.testing.assert_allclose(np.asarray(y_full[:, 12:], np.float32),
                               np.asarray(y_b, np.float32), rtol=2e-2,
                               atol=2e-3)
    for k in ("c", "n", "m", "h"):
        np.testing.assert_allclose(np.asarray(st_full[k]),
                                   np.asarray(st_b[k]), rtol=2e-2, atol=2e-3)
