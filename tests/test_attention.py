"""Flash attention (custom VJP) vs materialized oracle; decode paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A


def rand(key, shape, dtype=jnp.float32, scale=0.5):
    return scale * jax.random.normal(jax.random.key(key), shape, dtype)


@pytest.mark.parametrize("causal,window,softcap,kv_heads", [
    (True, 0, 0.0, 4),
    (True, 0, 0.0, 1),
    (True, 16, 0.0, 2),
    (True, 0, 30.0, 2),
    (False, 0, 0.0, 4),
    (True, 16, 50.0, 1),
])
def test_flash_vs_reference(causal, window, softcap, kv_heads):
    B, Sq, H, D = 2, 64, 4, 16
    q = rand(0, (B, Sq, H, D))
    k = rand(1, (B, Sq, kv_heads, D))
    v = rand(2, (B, Sq, kv_heads, D))
    out = A.flash_attention(q, k, v, causal=causal, window=window,
                            attn_softcap=softcap, q_block=16, kv_block=16)
    ref = A.reference_attention(q, k, v, causal=causal, window=window,
                                attn_softcap=softcap)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)


def test_flash_gradients_match_reference():
    B, Sq, H, D = 1, 32, 2, 8
    q, k, v = rand(0, (B, Sq, H, D)), rand(1, (B, Sq, H, D)), \
        rand(2, (B, Sq, H, D))
    dout = rand(3, (B, Sq, H, D))

    def f_flash(q, k, v):
        return jnp.sum(A.flash_attention(q, k, v, causal=True, window=8,
                                         attn_softcap=20.0, q_block=8,
                                         kv_block=8) * dout)

    def f_ref(q, k, v):
        return jnp.sum(A.reference_attention(q, k, v, causal=True, window=8,
                                             attn_softcap=20.0) * dout)

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-4)


def test_flash_uneven_vdim():
    B, Sq, H, Dq, Dv = 1, 32, 2, 16, 8
    q = rand(0, (B, Sq, H, Dq))
    k = rand(1, (B, Sq, H, Dq))
    v = rand(2, (B, Sq, H, Dv))
    out = A.flash_attention(q, k, v, causal=True, q_block=8, kv_block=8)
    ref = A.reference_attention(q, k, v, causal=True)
    assert out.shape == (B, Sq, H, Dv)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)


def test_ring_positions_cover_window():
    C = 8
    for t in [3, 7, 8, 13, 100]:
        kpos = A.ring_positions(jnp.asarray(t), C)
        valid = np.asarray(kpos[kpos <= t])
        # slots hold exactly the last min(t+1, C) positions
        want = np.arange(max(0, t - C + 1), t + 1)
        assert sorted(valid.tolist()) == want.tolist(), (t, valid)


def test_decode_attend_matches_reference():
    B, H, K, D, C = 2, 4, 2, 16, 32
    q = rand(0, (B, H, D))
    ck = rand(1, (B, C, K, D))
    cv = rand(2, (B, C, K, D))
    t = jnp.asarray(C - 1, jnp.int32)
    kpos = jnp.arange(C)
    out = A.decode_attend(q, ck, cv, kpos, t)
    ref = A.reference_attention(q[:, None], ck, cv, causal=True)[:, 0]
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-4)


def test_mla_prefill_decode_roundtrip():
    from repro.models.common import Builder
    B, S, d, H, r = 1, 24, 32, 2, 16
    nope, rd, vd = 16, 8, 16
    p = A.mla_init(Builder("init", jax.random.key(0)), d_model=d, num_heads=H,
                   kv_lora=r, nope_dim=nope, rope_dim=rd, v_dim=vd)
    x = rand(1, (B, S, d))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    kw = dict(num_heads=H, kv_lora=r, nope_dim=nope, rope_dim=rd, v_dim=vd)
    y_full, _ = A.mla_apply_full(p, x, positions=pos, **kw)
    _, cache = A.mla_apply_full(p, x[:, :S - 1], positions=pos[:, :S - 1],
                                cache_capacity=S, **kw)
    y_dec, _ = A.mla_apply_decode(p, x[:, S - 1:], cache,
                                  jnp.asarray(S - 1, jnp.int32), **kw)
    np.testing.assert_allclose(y_full[:, -1], y_dec[:, 0], rtol=3e-2,
                               atol=3e-3)
