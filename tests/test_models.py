"""Per-architecture smoke tests: reduced configs, forward + train step on
CPU, output shapes + no NaNs; prefill->decode consistency for representative
families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.optim.losses import lm_loss

B, S = 2, 64


def make_batch(cfg, key=1, seq=S):
    batch = {"tokens": jax.random.randint(jax.random.key(key), (B, seq), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            jax.random.key(2), (B, cfg.num_image_tokens, cfg.vit_dim),
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(jax.random.key(2),
                                            (B, seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    logits, aux, _ = M.forward(cfg, params, batch)
    exp_len = S + (cfg.num_image_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_len, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-7b", "xlstm-125m",
                                  "whisper-small"])
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    (loss, m), g = jax.value_and_grad(
        lambda p: lm_loss(cfg, p, batch, remat=True), has_aux=True)(params)
    assert jnp.isfinite(loss)
    gn = jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                      for x in jax.tree.leaves(g)))
    assert jnp.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma3-1b", "zamba2-7b",
                                  "xlstm-125m", "deepseek-v2-lite-16b",
                                  "whisper-small", "pixtral-12b"])
def test_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.num_experts:  # dropless capacity so paths are comparable
        cfg = dataclasses.replace(
            cfg, capacity_factor=float(cfg.num_experts) / cfg.top_k)
    params = M.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    tokens = batch["tokens"]
    full_logits, _, _ = M.forward(cfg, params, batch)
    pre = dict(batch)
    pre["tokens"] = tokens[:, :S - 1]
    t_img = cfg.num_image_tokens if cfg.family == "vlm" else 0
    _, caches = M.prefill(cfg, params, pre, cache_capacity=S + t_img)
    dec_logits, _ = M.decode_step(cfg, params, tokens[:, S - 1], caches,
                                  jnp.asarray(S - 1 + t_img, jnp.int32))
    a = np.asarray(full_logits[:, -1])
    b = np.asarray(dec_logits)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 3e-2, err


def test_pattern_stages_cover_all_layers():
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        total = sum(len(pat) * rep for pat, rep in M.make_stages(cfg))
        assert total == cfg.num_layers, arch


def test_sliding_window_restricts_context():
    cfg = dataclasses.replace(get_smoke_config("gemma2-2b"), num_layers=2,
                              pattern=("local",), sliding_window=8)
    params = M.init_params(cfg, jax.random.key(0))
    t1 = jax.random.randint(jax.random.key(1), (1, 64), 0, cfg.vocab_size)
    t2 = t1.at[:, :40].set((t1[:, :40] + 7) % cfg.vocab_size)
    l1, _, _ = M.forward(cfg, params, {"tokens": t1})
    l2, _, _ = M.forward(cfg, params, {"tokens": t2})
    # tokens beyond the window*num_layers receptive field are unaffected
    a, b = np.asarray(l1[0, -1]), np.asarray(l2[0, -1])
    assert np.allclose(a, b, rtol=1e-3, atol=1e-3)
