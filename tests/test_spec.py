"""Self-speculative decoding: sparse member drafts, dense member verifies.

The correctness anchor for every test here is LOSSLESSNESS: greedy
speculative decoding must emit streams bit-identical to the verifier
decoding alone, whatever the draft proposes (`serve.spec`).
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import PruneConfig, get_smoke_config
from repro.core import calibrate
from repro.core import masks as masks_mod
from repro.core import metrics as metrics_mod
from repro.core.prunable import prunable_map
from repro.data.synthetic import batches_for
from repro.models import model as M
from repro.serve.engine import EngineFns, ServeEngine
from repro.serve.fleet import SparsityFleet
from repro.serve.spec import SpecDecoder, accept_commit, parse_spec
from repro.sparse.bank import MaskBank

CFG = get_smoke_config("llama3.2-1b")
PROMPTS = [np.array([5, 6, 7, 8]), np.array([9, 10, 11]), np.array([1, 2])]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.key(0))


@pytest.fixture(scope="module")
def draft_params(params):
    """Magnitude-masked 0.5 variant: high token agreement, not identity."""
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    masks = masks_mod.unstructured_masks(scores, sparsity=0.5)
    return masks_mod.apply_masks(params, masks)


def _dense_oracle(params, prompts, gen, *, capacity=32, eos_id=None):
    eng = ServeEngine(CFG, params, slots=len(prompts), capacity=capacity,
                      eos_id=eos_id)
    rids = [eng.submit(p, gen) for p in prompts]
    res = eng.run()
    return [res[r] for r in rids]


def _spec_pair(params, draft_params, *, slots=3, capacity=32, eos_id=None,
               **kw):
    fns = EngineFns(CFG, capacity)
    v = ServeEngine(CFG, params, slots=slots, capacity=capacity, fns=fns,
                    eos_id=eos_id)
    d = ServeEngine(CFG, draft_params, slots=slots, capacity=capacity,
                    fns=fns, eos_id=eos_id)
    return SpecDecoder(d, v, **kw)


def test_accept_commit_edges():
    # all k accepted: commit the k drafts, NO correction token (the last
    # draft was itself verified; its continuation is next round's business)
    assert accept_commit([3, 4, 5], [3, 4, 5]) == (3, [3, 4, 5])
    # rejected at position 0: exactly the verifier's token commits - the
    # round degrades to plain (lossless) decode, never below
    assert accept_commit([3, 4, 5], [9, 4, 5]) == (0, [9])
    # mid rollback: agreeing prefix + the correction at first disagreement
    assert accept_commit([3, 4, 5], [3, 4, 7]) == (2, [3, 4, 7])
    assert accept_commit([3], [3]) == (1, [3])
    assert accept_commit([3], [8]) == (0, [8])


def test_spec_is_lossless_with_identical_params(params):
    """Draft == verifier params: every draft accepted, zero rollbacks, and
    the stream equals the verifier decoding alone."""
    want = _dense_oracle(params, PROMPTS, 8)
    sd = _spec_pair(params, params, k=3, k_max=6, init_accept=0.9)
    rids = [sd.submit(p, 8) for p in PROMPTS]
    res, foreign = sd.run()
    assert [res[r] for r in rids] == want
    assert foreign == {"draft": {}, "verify": {}}
    assert sd.stats["rollbacks"] == 0
    assert sd.stats["accepted_draft_tokens"] == sd.stats["tokens"]
    assert sd.k > 3  # adaptive k grew on sustained full acceptance
    s = sd.summary()
    assert s["accept_rate"] == 1.0 and s["tokens"] == sum(map(len, want))


def test_spec_is_lossless_with_divergent_draft(params):
    """A draft whose proposals DISAGREE still yields the verifier's exact
    stream - rollback safety is where losslessness is earned.  (Two random
    inits both echo their input token on smoke weights, so disagreement is
    forced structurally: boosting one tied-embedding row pins the draft's
    unembed argmax to that token.)"""
    boosted = np.asarray(params["embed"]["table"]).copy()
    boosted[7] *= 100.0
    bad_draft = dict(params, embed={"table": jax.numpy.asarray(boosted)})
    want = _dense_oracle(params, PROMPTS, 8)
    assert not any(7 in w for w in want)  # the pin genuinely disagrees
    sd = _spec_pair(params, bad_draft, k=4, init_accept=0.9)
    rids = [sd.submit(p, 8) for p in PROMPTS]
    res, _ = sd.run()
    assert [res[r] for r in rids] == want
    assert sd.stats["rollbacks"] > 0
    assert sd.summary()["accept_rate"] < 1.0


def test_spec_masked_draft_lossless_and_accepting(params, draft_params):
    """The production pairing: a 0.5 masked-dense draft agrees on most
    tokens (accept rate strictly between the degenerate extremes is not
    guaranteed on smoke weights, but losslessness is)."""
    want = _dense_oracle(params, PROMPTS, 10)
    sd = _spec_pair(params, draft_params, k=4, k_max=8)
    rids = [sd.submit(p, 10) for p in PROMPTS]
    res, _ = sd.run()
    assert [res[r] for r in rids] == want
    assert 0.0 <= sd.summary()["accept_rate"] <= 1.0


def test_spec_eos_truncates_inside_accepted_run(params):
    """eos emitted mid-round (inside a multi-token accepted run) must end
    the stream AT the eos - no post-eos tokens leak out of the same round's
    accepted suffix - free both members' slots, and leave no state for the
    next request admitted into them."""
    base = _dense_oracle(params, [PROMPTS[0]], 8)[0]
    eos = base[2]  # guaranteed to land inside the first k=4 accepted run
    want = base[:base.index(eos) + 1]
    sd = _spec_pair(params, params, slots=1, eos_id=eos, k=4,
                    init_accept=0.9)
    r1 = sd.submit(PROMPTS[0], 8)
    r2 = sd.submit(PROMPTS[1], 4)  # queued behind r1 on the 1-slot pair
    res, _ = sd.run()
    assert res[r1] == want
    assert res[r1][-1] == eos and eos not in res[r1][:-1]
    # the freed slots leaked nothing into the queued request
    fresh = _dense_oracle(params, [PROMPTS[1]], 4, eos_id=eos)[0]
    assert res[r2] == fresh
    assert all(r is None for r in sd.draft_eng.active)
    assert all(r is None for r in sd.verify_eng.active)


def test_spec_max_tokens_not_a_multiple_of_k(params):
    """A request budget that ends mid-round truncates the accepted run at
    exactly max_tokens (k=4 rounds, 6-token budget)."""
    want = _dense_oracle(params, PROMPTS, 6)
    sd = _spec_pair(params, params, k=4, k_min=4, k_max=4, adaptive=False)
    rids = [sd.submit(p, 6) for p in PROMPTS]
    res, _ = sd.run()
    assert [res[r] for r in rids] == want
    assert all(len(res[r]) == 6 for r in rids)


def test_spec_zero_and_one_token_requests(params):
    sd = _spec_pair(params, params, k=4)
    r0 = sd.submit(PROMPTS[0], 0)
    r1 = sd.submit(PROMPTS[0], 1)
    res, _ = sd.run()
    assert res[r0] == []
    assert res[r1] == _dense_oracle(params, [PROMPTS[0]], 1)[0]


def test_spec_k_eff_clamps_at_capacity_and_stays_lossless(params):
    """Near ring capacity the fed width shrinks to the headroom (a
    speculative write past capacity would WRAP the ring and evict live
    rows); at headroom 1 rounds degrade to plain decode, which matches the
    dense engine even once the ring genuinely wraps."""
    cap, gen = 16, 18  # positions run past capacity: wraps like plain decode
    want = _dense_oracle(params, [PROMPTS[0]], gen, capacity=cap)
    sd = _spec_pair(params, params, slots=1, capacity=cap, k=8,
                    k_min=8, k_max=8, adaptive=False, init_accept=0.9)
    rid = sd.submit(PROMPTS[0], gen)
    res, _ = sd.run()
    assert res[rid] == want[0]
    # clamped rounds fed fewer than k positions each
    assert sd.stats["draft_positions"] < 8 * sd.stats["pair_rounds"]


def test_spec_constructor_validation(params):
    eng_a = ServeEngine(CFG, params, slots=1, capacity=32)
    eng_b = ServeEngine(CFG, params, slots=1, capacity=32)
    with pytest.raises(ValueError, match="distinct"):
        SpecDecoder(eng_a, eng_a)
    with pytest.raises(ValueError, match="capacity"):
        SpecDecoder(eng_a, ServeEngine(CFG, params, slots=1, capacity=64))
    with pytest.raises(ValueError, match="eos_id"):
        SpecDecoder(eng_a, ServeEngine(CFG, params, slots=1, capacity=32,
                                       eos_id=7))
    with pytest.raises(ValueError, match="k_min"):
        SpecDecoder(eng_a, eng_b, k=9, k_max=8)
    # windowed rings evict live rows on speculative writes: rejected
    wcfg = get_smoke_config("gemma2-2b")
    wparams = M.init_params(wcfg, jax.random.key(0))
    wa = ServeEngine(wcfg, wparams, slots=1, capacity=32)
    wb = ServeEngine(wcfg, wparams, slots=1, capacity=32)
    with pytest.raises(ValueError, match="sliding|window|kinds"):
        SpecDecoder(wa, wb)
    # recurrent state cannot roll back: rejected
    xcfg = get_smoke_config("xlstm-125m")
    xparams = M.init_params(xcfg, jax.random.key(0))
    xa = ServeEngine(xcfg, xparams, slots=1, capacity=32)
    xb = ServeEngine(xcfg, xparams, slots=1, capacity=32)
    with pytest.raises(ValueError, match="kinds"):
        SpecDecoder(xa, xb)


def test_parse_spec_strings():
    sc = parse_spec("draft:2:4,verify:0.0,k:4")
    assert (sc.draft, sc.verify, sc.k) == ("2:4", "0.0", 4)
    sc = parse_spec("draft:0.5,k:3,k_max:6,adaptive:false,ema:0.5")
    assert sc.verify is None and sc.k_max == 6
    assert sc.adaptive is False and sc.ema == 0.5
    assert parse_spec(sc) is sc
    with pytest.raises(ValueError, match="key:value"):
        parse_spec("draft=0.5")
    with pytest.raises(ValueError, match="unknown spec key"):
        parse_spec("depth:4")


# -- fleet routing ----------------------------------------------------------

@pytest.fixture(scope="module")
def bank_setup(tmp_path_factory, params):
    calib = batches_for(CFG, n=2, batch=2, seq=16, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=2)
    stats = calibrate.collect_stats(CFG, params, calib)
    state, _ = calibrate.run_search(CFG, pcfg, params, calib, stats)
    d = tmp_path_factory.mktemp("specfleet") / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    return d


def test_fleet_spec_routing_is_lossless_and_reported(bank_setup, params):
    """fleet.submit(spec=True) drives the (draft, verifier) pair through
    interleaved speculative rounds; the caller's stream is bit-identical to
    pinning the same prompt on the dense reference, and report() grows a
    spec section."""
    budgets = ["0.0", "0.5"]
    oracle = SparsityFleet.from_artifact(bank_setup, params, budgets,
                                         slots=4, capacity=32)
    rids = [oracle.submit(p, 8, budget="0.0") for p in PROMPTS]
    res = oracle.run()
    want = [res[r] for r in rids]

    fleet = SparsityFleet.from_artifact(bank_setup, params, budgets,
                                        slots=4, capacity=32,
                                        spec="draft:0.5,k:3")
    srids = [fleet.submit(p, 8, spec=True) for p in PROMPTS]
    out = fleet.run()
    assert [out[r] for r in srids] == want
    rep = fleet.report()
    assert rep["spec"]["requests"] == len(PROMPTS)
    assert rep["spec"]["tokens"] == sum(map(len, want))
    assert rep["spec"]["tok_s"] is None or rep["spec"]["tok_s"] > 0
    assert 0.0 <= rep["spec"]["accept_rate"] <= 1.0
    assert (rep["spec"]["draft"], rep["spec"]["verify"]) == ("0.5", "0.0")


def test_fleet_spec_interleaves_foreign_member_traffic(bank_setup, params):
    """Pinned member requests sharing slots with spec rounds advance one
    token per round off column 0 of the same batched dispatch - their
    streams must equal a pinned-only fleet's."""
    budgets = ["0.0", "0.5"]
    oracle = SparsityFleet.from_artifact(bank_setup, params, budgets,
                                         slots=4, capacity=32)
    rp = oracle.submit(PROMPTS[2], 6, budget="0.5")
    want_pin = oracle.run()[rp]

    fleet = SparsityFleet.from_artifact(bank_setup, params, budgets,
                                        slots=4, capacity=32,
                                        spec="draft:0.5,k:3")
    pin = fleet.submit(PROMPTS[2], 6, budget="0.5")   # foreign on the draft
    srids = [fleet.submit(p, 8, spec=True) for p in PROMPTS[:2]]
    out = fleet.run()
    assert out[pin] == want_pin
    assert all(len(out[r]) == 8 for r in srids)
    # foreign tokens the spec rounds advanced are accounted per member
    cum = fleet.report()["budgets"]["0.5"]["cumulative"]
    assert cum["spec_phase_tokens"] == len(want_pin)


def test_fleet_spec_bad_member_and_reconfigure(bank_setup, params):
    fleet = SparsityFleet.from_artifact(bank_setup, params, ["0.0", "0.5"],
                                        slots=2, capacity=32)
    with pytest.raises(KeyError, match="spec member"):
        fleet.submit(PROMPTS[0], 4, spec="draft:2:4")
    with pytest.raises(ValueError, match="both"):
        fleet.submit(PROMPTS[0], 4, spec="draft:0.0")  # draft == reference
    fleet.submit(PROMPTS[0], 4, spec="draft:0.5,k:2")
    with pytest.raises(ValueError, match="reconfigure"):
        fleet.submit(PROMPTS[0], 4, spec="draft:0.5,k:3")
    with pytest.raises(ValueError, match="exactly one"):
        fleet.submit(PROMPTS[0], 4)
    fleet.run()
