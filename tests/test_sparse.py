"""Sparse inference runtime: formats, mask bank, compressed execution."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PruneConfig, get_smoke_config
from repro.core import calibrate, masks as masks_mod, metrics as metrics_mod
from repro.core import mirror
from repro.core.prunable import prunable_map
from repro.data.synthetic import batches_for
from repro.kernels import ref as kref
from repro.kernels.nm_spmm import nm_matmul
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.sparse import apply as apply_mod
from repro.sparse import formats, pack
from repro.sparse.bank import MaskBank

CFG = get_smoke_config("llama3.2-1b")


def _tree_eq(a, b):
    la = jax.tree.leaves(a, is_leaf=lambda x: x is None)
    lb = jax.tree.leaves(b, is_leaf=lambda x: x is None)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if x is None:
            assert y is None
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def nm_masks_tree():
    params = M.init_params(CFG, jax.random.key(0))
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    return params, masks_mod.nm_masks(scores)


# -- formats: pack -> unpack round trips ------------------------------------

@pytest.mark.parametrize("idx_bits", [8, 2])
def test_nm_pack_roundtrip_equals_masked_dense(idx_bits):
    w = jax.random.normal(jax.random.key(3), (64, 48), jnp.float32)
    mask = kref.nm_mask_ref(w)
    st = pack.pack_nm(w, mask, idx_bits=idx_bits)
    assert st.shape == w.shape and st.idx_bits == idx_bits
    np.testing.assert_array_equal(np.asarray(st.to_dense()),
                                  np.asarray(w * mask))
    # storage: vals f32 + idx; 2-bit = 1/16 of an int8 idx plane per row grp
    idx_bytes = w.size // 8 if idx_bits == 2 else w.size // 2
    assert st.nbytes == w.size // 2 * 4 + idx_bytes


def test_nm_pack_stacked_layer_leaves():
    w = jax.random.normal(jax.random.key(4), (3, 32, 16), jnp.float32)
    mask = jnp.stack([kref.nm_mask_ref(w[i]) for i in range(3)])
    st = pack.pack_nm(w, mask, idx_bits=2)
    np.testing.assert_array_equal(np.asarray(st.to_dense()),
                                  np.asarray(w * mask))


def test_pack_pads_to_byte_boundary_instead_of_widening():
    """K % 8 != 0 used to silently widen to int8 indices; now the packed
    plane zero-pads to the byte boundary and storage stays 2-bit."""
    w = jax.random.normal(jax.random.key(6), (12, 16), jnp.float32)
    mask = kref.nm_mask_ref(w)
    st = pack.pack_nm(w, mask, idx_bits=2)
    assert st.idx_bits == 2 and st.layout == "packed2"
    assert st.kernel_layout == "int8"  # padded plane -> dispatch fallback
    assert st.idx.shape == (2, 16)     # ceil((12/2)/4) byte rows
    np.testing.assert_array_equal(np.asarray(st.to_dense()),
                                  np.asarray(w * mask))
    # execution still matches masked-dense through the fallback
    x = 0.1 * jax.random.normal(jax.random.key(7), (4, 12), jnp.float32)
    y = apply_mod.sparse_dense(st, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ (w * mask)),
                               rtol=1e-6, atol=1e-6)


def test_sparsify_params_keeps_2bit_on_odd_k():
    w = jax.random.normal(jax.random.key(8), (12, 16), jnp.float32)
    mask = kref.nm_mask_ref(w)
    sp = apply_mod.sparsify_params({"kernel": w}, {"kernel": mask},
                                   idx_bits=2)
    st = sp["kernel"]
    assert isinstance(st, formats.SparseTensor) and st.idx_bits == 2
    rep = apply_mod.compressed_report(sp)
    (layer,) = rep["layers"]
    assert layer["layout"] == "packed2"
    assert layer["kernel_layout"] == "int8"
    assert rep["kernel_native_packed"] == 0
    # honest bytes: f32 vals + the padded packed plane actually stored
    assert layer["bytes_compressed"] == 6 * 16 * 4 + 2 * 16


def test_kernel_layout_tags():
    w = jax.random.normal(jax.random.key(9), (64, 32), jnp.float32)
    mask = kref.nm_mask_ref(w)
    st2 = pack.pack_nm(w, mask, idx_bits=2)
    st8 = pack.pack_nm(w, mask, idx_bits=8)
    assert (st2.layout, st2.kernel_layout) == ("packed2", "packed2")
    assert (st8.layout, st8.kernel_layout) == ("int8", "int8")


def _expert_nm_mask(w):
    """2:4 keep-mask for an (..., K, N) expert bank, per trailing 2-D slice."""
    flat = w.reshape((-1,) + w.shape[-2:])
    return jnp.stack([kref.nm_mask_ref(flat[i])
                      for i in range(flat.shape[0])]).reshape(w.shape)


@pytest.mark.parametrize("idx_bits,d", [(2, 16), (8, 16), (2, 12)])
def test_sparse_moe_dense_matches_masked_einsum(idx_bits, d):
    """Expert-grid kernel over the dispatch buffer == masked-dense einsum,
    for the kernel-native packed, int8, and byte-padded (K % 8 != 0,
    dispatch falls back to the int8 plane) layouts."""
    E, f, G, C = 4, 24, 2, 5
    w = jax.random.normal(jax.random.key(0), (E, d, f), jnp.float32)
    mask = _expert_nm_mask(w)
    st = pack.pack_nm(w, mask, idx_bits=idx_bits)
    assert st.shape == (E, d, f)
    buf = 0.3 * jax.random.normal(jax.random.key(1), (G, E, C, d),
                                  jnp.float32)
    y = apply_mod.sparse_moe_dense(st, buf)
    want = jnp.einsum("gecd,edf->gecf", buf, w * mask)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_sparsify_params_compresses_expert_banks():
    """Scan-stacked MoE expert banks (layers, E, K, N) no longer fall back
    to masked-dense: they pack with the expert axis carried through and the
    masks-aware report shows zero fallbacks at the 9/16 bound."""
    cfg = get_smoke_config("mixtral-8x22b")
    params = M.init_params(cfg, jax.random.key(0))
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    masks = masks_mod.nm_masks(scores)
    sp = apply_mod.sparsify_params(params, masks, axes=M.param_axes(cfg),
                                   idx_bits=2, dtype=jnp.bfloat16)
    rep = apply_mod.compressed_report(sp, masks)
    expert = [l for l in rep["layers"] if "['moe']" in l["path"]]
    assert len(expert) == 3  # up / gate / down banks
    for l in expert:
        assert len(l["shape"]) == 4 and not l["fallback"]  # (L, E, K, N)
        assert l["kernel_layout"] == "packed2"
    assert rep["fallback_leaves"] == 0
    assert rep["ratio"] <= 9 / 16 + 1e-9


def test_sparsify_params_rejects_mismatched_masks():
    w = jax.random.normal(jax.random.key(0), (8, 8), jnp.float32)
    params = {"a": {"kernel": w}, "b": {"kernel": w}}
    mask = kref.nm_mask_ref(w)
    with pytest.raises(ValueError, match="masks"):  # missing leaf
        apply_mod.sparsify_params(params, {"a": {"kernel": mask}})
    with pytest.raises(ValueError, match=r"\['c'\]"):  # mis-paired leaf
        apply_mod.sparsify_params(
            params, {"a": {"kernel": mask}, "c": {"kernel": mask}})
    with pytest.raises(ValueError, match="axes"):
        apply_mod.sparsify_params(
            params, {"a": {"kernel": mask}, "b": {"kernel": mask}},
            axes={"a": {"kernel": "embed|mlp"}})


def test_compressed_report_fallback_leaves():
    """Pruned leaves that stayed masked-dense must show up in the report
    (full dense bytes, fallback flag) instead of silently inflating the
    headline compression ratio."""
    w = jax.random.normal(jax.random.key(0), (16, 8), jnp.float32)
    wf = jax.random.normal(jax.random.key(1), (6, 8), jnp.float32)  # K%4!=0
    masks = {"a": {"kernel": kref.nm_mask_ref(w)},
             "b": {"kernel": jnp.ones_like(wf, jnp.bool_)}}
    sp = apply_mod.sparsify_params({"a": {"kernel": w}, "b": {"kernel": wf}},
                                   masks, idx_bits=2)
    assert isinstance(sp["a"]["kernel"], formats.SparseTensor)
    assert not isinstance(sp["b"]["kernel"], formats.SparseTensor)
    rep = apply_mod.compressed_report(sp, masks)
    by_path = {l["path"]: l for l in rep["layers"]}
    fb = by_path["['b']['kernel']"]
    assert fb["fallback"] and fb["kernel_layout"] == "masked-dense"
    assert fb["bytes_compressed"] == fb["bytes_dense_bf16"] == 6 * 8 * 2
    assert rep["fallback_leaves"] == 1
    # headline ratio counts the dense bytes the fallback still moves
    comp = by_path["['a']['kernel']"]
    want = (comp["bytes_compressed"] + fb["bytes_dense_bf16"]) / \
        (comp["bytes_dense_bf16"] + fb["bytes_dense_bf16"])
    assert abs(rep["ratio"] - want) < 1e-12
    # without masks the fallback is invisible (back-compat shape)
    rep0 = apply_mod.compressed_report(sp)
    assert rep0["fallback_leaves"] == 0 and len(rep0["layers"]) == 1


def test_bitmask_roundtrip():
    key = jax.random.key(5)
    for shape in [(33, 7), (64, 128), (5,)]:
        mask = jax.random.bernoulli(key, 0.4, shape)
        bm = formats.BitMask.pack(mask)
        assert bm.nbytes == -(-int(np.prod(shape)) // 8)
        np.testing.assert_array_equal(np.asarray(bm.to_dense()),
                                      np.asarray(mask))
    tree = {"a": mask, "b": None}
    _tree_eq(pack.unpack_mask_tree(pack.pack_mask_tree(tree)), tree)


# -- kernel vs oracle on the engine's decode shapes -------------------------

def test_nm_matmul_interpret_on_decode_shapes():
    """Exact GEMM shapes the smoke engine decodes: (slots, K) per kernel."""
    shapes = {(CFG.d_model, CFG.num_heads * CFG.head_dim),
              (CFG.d_model, CFG.num_kv_heads * CFG.head_dim),
              (CFG.num_heads * CFG.head_dim, CFG.d_model),
              (CFG.d_model, CFG.d_ff), (CFG.d_ff, CFG.d_model)}
    for i, (K, N) in enumerate(sorted(shapes)):
        w = jax.random.normal(jax.random.key(i), (K, N), jnp.float32)
        vals, idx = kref.compress_24(w)
        x = 0.1 * jax.random.normal(jax.random.key(100 + i), (4, K))
        y = nm_matmul(x, vals, idx, bm=4, bk=K, bn=N, interpret=True)
        yr = kref.nm_matmul_ref(x, vals, idx)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-5, atol=2e-5)


# -- sparsify + dispatch ----------------------------------------------------

def test_sparse_forward_bit_matches_masked_dense(nm_masks_tree):
    params, masks = nm_masks_tree
    sp = apply_mod.sparsify_params(params, masks, axes=M.param_axes(CFG),
                                   idx_bits=2, dtype=jnp.bfloat16)
    rep = apply_mod.compressed_report(sp)
    assert rep["layers"] and rep["ratio"] <= 5 / 8  # 2-bit idx: 9/16
    masked = masks_mod.apply_masks(params, masks)
    batch = batches_for(CFG, n=1, batch=2, seq=16, split="valid")[0]
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    lg_s, _, _ = M.forward(CFG, sp, batch)
    lg_d, _, _ = M.forward(CFG, masked, batch)
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_d))


def test_sparse_engine_tokens_match_masked_dense(nm_masks_tree):
    params, masks = nm_masks_tree
    sp = apply_mod.sparsify_params(params, masks, axes=M.param_axes(CFG),
                                   idx_bits=2, dtype=jnp.bfloat16)
    masked = masks_mod.apply_masks(params, masks)
    prompts = [np.array([5, 6, 7, 8]), np.array([9, 10, 11])]
    outs = []
    for p in (sp, masked):
        eng = ServeEngine(CFG, p, slots=2, capacity=32)
        rids = [eng.submit(pr, 5) for pr in prompts]
        res = eng.run()
        outs.append([res[r] for r in rids])
    assert outs[0] == outs[1]


# -- mask bank --------------------------------------------------------------

@pytest.fixture(scope="module")
def calibrated():
    params = M.init_params(CFG, jax.random.key(0))
    calib = batches_for(CFG, n=4, batch=2, seq=32, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=4)
    stats = calibrate.collect_stats(CFG, params, calib[:2])
    state, _ = calibrate.run_search(CFG, pcfg, params, calib, stats)
    return params, pcfg, stats, state


def test_bank_roundtrip_masks_bit_exact(calibrated, tmp_path):
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    bank = MaskBank.load(d)
    assert bank.pcfg == pcfg
    # saved state round-trips exactly
    _tree_eq(bank.Gamma, state.Gamma)
    _tree_eq(bank.V, state.V)
    _tree_eq(bank.stats, stats)
    # one-shot re-threshold across restarts == in-process export, 3 budgets
    pc_u = dataclasses.replace(pcfg, mode="unstructured")
    for s in (0.4, 0.5, 0.6):
        _tree_eq(bank.masks_at(sparsity=s),
                 mirror.export_masks(pc_u, state.Gamma, s, V=state.V))
    # and the calibrated N:M pattern
    _tree_eq(bank.masks_at(),
             mirror.export_masks(pcfg, state.Gamma, 0.5, V=state.V))


def test_bank_sparse_params_serve(calibrated, tmp_path):
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    eng = ServeEngine.from_artifact(d, params, slots=1, capacity=32)
    assert formats.sparse_leaves(eng.params)
    rid = eng.submit(np.array([3, 1, 4, 1, 5]), 4)
    out = eng.run()[rid]
    assert len(out) == 4


def test_bank_masks_at_memoizes_per_budget(calibrated, tmp_path,
                                           monkeypatch):
    """Identical budgets must not re-threshold the calibration state: one
    export_masks pass per (sparsity | nm) key, repeats return the cached
    tree (so fleet construction and repeated sparse_params calls are
    one-shot per budget)."""
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    bank = MaskBank.load(d)
    calls = []
    real = mirror.export_masks
    monkeypatch.setattr(mirror, "export_masks",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    m1 = bank.masks_at(sparsity=0.5)
    m2 = bank.masks_at(sparsity=0.5)
    assert m1 is m2 and len(calls) == 1
    bank.masks_at(sparsity=0.6)
    assert len(calls) == 2
    # the calibrated N:M default and an explicit nm=(2, 4) share one key
    m3 = bank.masks_at()
    assert bank.masks_at(nm=(2, 4)) is m3 and len(calls) == 3
    # sparse_params at a cached budget re-uses the masks (no new pass)
    bank.sparse_params(params, nm=(2, 4), compressed=False)
    assert len(calls) == 3


def test_bank_mask_cache_is_bounded_lru(calibrated, tmp_path, monkeypatch):
    """The memo must not grow without bound across a budget sweep: with
    the cap shrunk to 2 the least-recently-used budget evicts (a revisit
    re-thresholds), a cache hit refreshes recency, and the
    ``analysis.mask_cache_entries`` gauge tracks the live size."""
    from repro.sparse import bank as bank_mod
    from repro import obs
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    bank = MaskBank.load(d)
    monkeypatch.setattr(bank_mod, "MASK_CACHE_ENTRIES", 2)
    calls = []
    real = mirror.export_masks
    monkeypatch.setattr(mirror, "export_masks",
                        lambda *a, **kw: calls.append(1) or real(*a, **kw))
    obs.configure(enabled=True)
    try:
        bank.masks_at(sparsity=0.5)       # cache: [0.5]
        m6 = bank.masks_at(sparsity=0.6)  # cache: [0.5, 0.6]
        assert len(calls) == 2
        assert obs.gauge_value("analysis.mask_cache_entries") == 2.0
        bank.masks_at(sparsity=0.5)       # hit: recency now [0.6, 0.5]
        assert len(calls) == 2
        bank.masks_at(sparsity=0.7)       # evicts 0.6, keeps refreshed 0.5
        assert len(calls) == 3
        assert obs.gauge_value("analysis.mask_cache_entries") == 2.0
        assert bank.masks_at(sparsity=0.5) is not None and len(calls) == 3
        assert bank.masks_at(sparsity=0.6) is not m6 and len(calls) == 4
    finally:
        obs.disable()


def test_bank_saved_without_stats_loads_clean(calibrated, tmp_path):
    """The checksum must be structure-insensitive: load rebuilds the tree
    through the full params template, expanding a saved stats=None into a
    subtree of None leaves; a valid artifact must not read as corrupt."""
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank_nostats"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state, pcfg=pcfg)
    bank = MaskBank.load(d)
    _tree_eq(bank.Gamma, state.Gamma)
    assert all(x is None for x in jax.tree.leaves(
        bank.stats, is_leaf=lambda x: x is None))


def test_bank_corrupt_leaf_fails_loudly(calibrated, tmp_path):
    """A truncated/bit-rotted artifact must refuse to load (checksum)."""
    import glob
    import pathlib
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    f = pathlib.Path(sorted(glob.glob(str(d / "leaf_*.npy")))[2])
    raw = bytearray(f.read_bytes())
    raw[-4] ^= 0xFF
    f.write_bytes(bytes(raw))
    with pytest.raises(ValueError, match="integrity"):
        MaskBank.load(d)


def test_bank_newer_format_version_fails_loudly(calibrated, tmp_path):
    import json
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    mf = d / "manifest.json"
    m = json.loads(mf.read_text())
    m["metadata"]["format_version"] = 99
    mf.write_text(json.dumps(m))
    with pytest.raises(ValueError, match="format_version"):
        MaskBank.load(d)


# -- fused batched decode ---------------------------------------------------

def test_fused_decode_matches_vmapped_scan_with_midbatch_admission(
        nm_masks_tree):
    """One fused decode invocation with a per-slot position vector must be
    token-identical to the legacy per-slot vmapped scan, including requests
    admitted mid-batch while other slots are mid-generation."""
    params, masks = nm_masks_tree
    sp = apply_mod.sparsify_params(params, masks, axes=M.param_axes(CFG),
                                   idx_bits=2, dtype=jnp.bfloat16)
    prompts = [np.array([5, 6, 7, 8]), np.array([9, 10, 11]),
               np.array([1, 2]), np.array([12, 13, 14, 15, 16])]
    lens = [6, 3, 5, 4]
    outs = {}
    for mode in ("fused", "vmap"):
        # 4 requests into 2 slots: the 3rd and 4th join mid-batch
        eng = ServeEngine(CFG, sp, slots=2, capacity=32, decode_mode=mode)
        rids = [eng.submit(p, n) for p, n in zip(prompts, lens)]
        res = eng.run()
        outs[mode] = [res[r] for r in rids]
    assert outs["fused"] == outs["vmap"]
    assert [len(o) for o in outs["fused"]] == lens


def test_decode_step_vector_positions_match_scalar():
    """decode_step with a constant position vector equals the scalar path
    (same ring writes, same masks) - the fused engine's correctness core."""
    params = M.init_params(CFG, jax.random.key(1))
    B, P, cap = 2, 6, 16
    from repro.data.synthetic import batches_for
    batch = {k: jnp.asarray(v) for k, v in
             batches_for(CFG, n=1, batch=B, seq=P, split="valid")[0].items()}
    _, caches = M.prefill(CFG, params, batch, cache_capacity=cap)
    tok = jnp.array([3, 4], jnp.int32)
    lg_s, c_s = M.decode_step(CFG, params, tok, caches,
                              jnp.asarray(P, jnp.int32))
    lg_v, c_v = M.decode_step(CFG, params, tok, caches,
                              jnp.full((B,), P, jnp.int32))
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v))
    _tree_eq(c_s, c_v)


# -- MoE expert banks through the serving path ------------------------------

@pytest.fixture(scope="module")
def moe_sparse_tree():
    cfg = get_smoke_config("mixtral-8x22b")
    params = M.init_params(cfg, jax.random.key(0))
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    masks = masks_mod.nm_masks(scores)
    sp = apply_mod.sparsify_params(params, masks, axes=M.param_axes(cfg),
                                   idx_bits=2, dtype=jnp.bfloat16)
    return cfg, params, masks, sp


def test_moe_fused_decode_matches_vmap_and_masked_dense(moe_sparse_tree):
    """Compressed expert banks through the continuous-batching engine:
    fused single-invocation decode == legacy vmapped scan == masked-dense
    oracle, token for token, with unequal prompt lengths so the 3rd/4th
    requests admit mid-batch into freed slots."""
    cfg, params, masks, sp = moe_sparse_tree
    masked = masks_mod.apply_masks(params, masks)
    prompts = [np.array([5, 6, 7, 8]), np.array([9, 10, 11]),
               np.array([1, 2]), np.array([12, 13, 14, 15, 16])]
    lens = [5, 3, 4, 4]
    outs = {}
    for name, p, mode in (("fused", sp, "fused"), ("vmap", sp, "vmap"),
                          ("oracle", masked, "fused")):
        eng = ServeEngine(cfg, p, slots=2, capacity=32, decode_mode=mode)
        rids = [eng.submit(pr_, n) for pr_, n in zip(prompts, lens)]
        res = eng.run()
        outs[name] = [res[r] for r in rids]
    assert outs["fused"] == outs["vmap"]
    assert outs["fused"] == outs["oracle"]
    assert [len(o) for o in outs["fused"]] == lens


def test_moe_bank_from_artifact_serves_compressed(tmp_path):
    """The acceptance path: calibrate a smoke MoE config, persist the bank,
    and ``ServeEngine.from_artifact(..., compressed=True)`` must execute the
    expert banks through the compressed kernel (packed2, no masked-dense
    fallback, ratio <= 9/16) with tokens identical to the masked-dense
    engine."""
    cfg = get_smoke_config("mixtral-8x22b")
    params = M.init_params(cfg, jax.random.key(0))
    calib = batches_for(cfg, n=2, batch=2, seq=16, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=2)
    stats = calibrate.collect_stats(cfg, params, calib)
    state, _ = calibrate.run_search(cfg, pcfg, params, calib, stats)
    d = tmp_path / "bank_moe"
    MaskBank.save(d, arch="mixtral-8x22b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    eng = ServeEngine.from_artifact(d, params, slots=2, capacity=32)
    rep = apply_mod.compressed_report(eng.params)
    expert = [l for l in rep["layers"] if "['moe']" in l["path"]]
    assert expert and all(l["kernel_layout"] == "packed2" for l in expert)
    assert rep["ratio"] <= 9 / 16 + 1e-9
    bank = MaskBank.load(d)
    masked = bank.sparse_params(params, compressed=False)
    eng_m = ServeEngine(cfg, masked, slots=2, capacity=32)
    prompts = [np.array([3, 1, 4, 1, 5]), np.array([2, 7])]
    outs = []
    for e in (eng, eng_m):
        rids = [e.submit(p, 4) for p in prompts]
        res = e.run()
        outs.append([res[r] for r in rids])
    assert outs[0] == outs[1]


# -- engine prefill semantics ----------------------------------------------

def test_engine_chunked_prefill_single_compile_per_bucket():
    params = M.init_params(CFG, jax.random.key(0))
    eng = ServeEngine(CFG, params, slots=2, capacity=64)
    for p in ([1, 2, 3], [4, 5, 6, 7], [8, 9]):  # all pad to one bucket
        eng.submit(np.array(p), 2)
    eng.run()
    assert set(eng.fns.prefill_fns) == {8}  # bucketed: one jitted prefill
