"""Sparse inference runtime: formats, mask bank, compressed execution."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PruneConfig, get_smoke_config
from repro.core import calibrate, masks as masks_mod, metrics as metrics_mod
from repro.core import mirror
from repro.core.prunable import prunable_map
from repro.data.synthetic import batches_for
from repro.kernels import ref as kref
from repro.kernels.nm_spmm import nm_matmul
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.sparse import apply as apply_mod
from repro.sparse import formats, pack
from repro.sparse.bank import MaskBank

CFG = get_smoke_config("llama3.2-1b")


def _tree_eq(a, b):
    la = jax.tree.leaves(a, is_leaf=lambda x: x is None)
    lb = jax.tree.leaves(b, is_leaf=lambda x: x is None)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        if x is None:
            assert y is None
            continue
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.fixture(scope="module")
def nm_masks_tree():
    params = M.init_params(CFG, jax.random.key(0))
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    return params, masks_mod.nm_masks(scores)


# -- formats: pack -> unpack round trips ------------------------------------

@pytest.mark.parametrize("idx_bits", [8, 2])
def test_nm_pack_roundtrip_equals_masked_dense(idx_bits):
    w = jax.random.normal(jax.random.key(3), (64, 48), jnp.float32)
    mask = kref.nm_mask_ref(w)
    st = pack.pack_nm(w, mask, idx_bits=idx_bits)
    assert st.shape == w.shape and st.idx_bits == idx_bits
    np.testing.assert_array_equal(np.asarray(st.to_dense()),
                                  np.asarray(w * mask))
    # storage: vals f32 + idx; 2-bit = 1/16 of an int8 idx plane per row grp
    idx_bytes = w.size // 8 if idx_bits == 2 else w.size // 2
    assert st.nbytes == w.size // 2 * 4 + idx_bytes


def test_nm_pack_stacked_layer_leaves():
    w = jax.random.normal(jax.random.key(4), (3, 32, 16), jnp.float32)
    mask = jnp.stack([kref.nm_mask_ref(w[i]) for i in range(3)])
    st = pack.pack_nm(w, mask, idx_bits=2)
    np.testing.assert_array_equal(np.asarray(st.to_dense()),
                                  np.asarray(w * mask))


def test_bitmask_roundtrip():
    key = jax.random.key(5)
    for shape in [(33, 7), (64, 128), (5,)]:
        mask = jax.random.bernoulli(key, 0.4, shape)
        bm = formats.BitMask.pack(mask)
        assert bm.nbytes == -(-int(np.prod(shape)) // 8)
        np.testing.assert_array_equal(np.asarray(bm.to_dense()),
                                      np.asarray(mask))
    tree = {"a": mask, "b": None}
    _tree_eq(pack.unpack_mask_tree(pack.pack_mask_tree(tree)), tree)


# -- kernel vs oracle on the engine's decode shapes -------------------------

def test_nm_matmul_interpret_on_decode_shapes():
    """Exact GEMM shapes the smoke engine decodes: (slots, K) per kernel."""
    shapes = {(CFG.d_model, CFG.num_heads * CFG.head_dim),
              (CFG.d_model, CFG.num_kv_heads * CFG.head_dim),
              (CFG.num_heads * CFG.head_dim, CFG.d_model),
              (CFG.d_model, CFG.d_ff), (CFG.d_ff, CFG.d_model)}
    for i, (K, N) in enumerate(sorted(shapes)):
        w = jax.random.normal(jax.random.key(i), (K, N), jnp.float32)
        vals, idx = kref.compress_24(w)
        x = 0.1 * jax.random.normal(jax.random.key(100 + i), (4, K))
        y = nm_matmul(x, vals, idx, bm=4, bk=K, bn=N, interpret=True)
        yr = kref.nm_matmul_ref(x, vals, idx)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-5, atol=2e-5)


# -- sparsify + dispatch ----------------------------------------------------

def test_sparse_forward_bit_matches_masked_dense(nm_masks_tree):
    params, masks = nm_masks_tree
    sp = apply_mod.sparsify_params(params, masks, axes=M.param_axes(CFG),
                                   idx_bits=2, dtype=jnp.bfloat16)
    rep = apply_mod.compressed_report(sp)
    assert rep["layers"] and rep["ratio"] <= 5 / 8  # 2-bit idx: 9/16
    masked = masks_mod.apply_masks(params, masks)
    batch = batches_for(CFG, n=1, batch=2, seq=16, split="valid")[0]
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    lg_s, _, _ = M.forward(CFG, sp, batch)
    lg_d, _, _ = M.forward(CFG, masked, batch)
    np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_d))


def test_sparse_engine_tokens_match_masked_dense(nm_masks_tree):
    params, masks = nm_masks_tree
    sp = apply_mod.sparsify_params(params, masks, axes=M.param_axes(CFG),
                                   idx_bits=2, dtype=jnp.bfloat16)
    masked = masks_mod.apply_masks(params, masks)
    prompts = [np.array([5, 6, 7, 8]), np.array([9, 10, 11])]
    outs = []
    for p in (sp, masked):
        eng = ServeEngine(CFG, p, slots=2, capacity=32)
        rids = [eng.submit(pr, 5) for pr in prompts]
        res = eng.run()
        outs.append([res[r] for r in rids])
    assert outs[0] == outs[1]


# -- mask bank --------------------------------------------------------------

@pytest.fixture(scope="module")
def calibrated():
    params = M.init_params(CFG, jax.random.key(0))
    calib = batches_for(CFG, n=4, batch=2, seq=32, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=4)
    stats = calibrate.collect_stats(CFG, params, calib[:2])
    state, _ = calibrate.run_search(CFG, pcfg, params, calib, stats)
    return params, pcfg, stats, state


def test_bank_roundtrip_masks_bit_exact(calibrated, tmp_path):
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    bank = MaskBank.load(d)
    assert bank.pcfg == pcfg
    # saved state round-trips exactly
    _tree_eq(bank.Gamma, state.Gamma)
    _tree_eq(bank.V, state.V)
    _tree_eq(bank.stats, stats)
    # one-shot re-threshold across restarts == in-process export, 3 budgets
    pc_u = dataclasses.replace(pcfg, mode="unstructured")
    for s in (0.4, 0.5, 0.6):
        _tree_eq(bank.masks_at(sparsity=s),
                 mirror.export_masks(pc_u, state.Gamma, s, V=state.V))
    # and the calibrated N:M pattern
    _tree_eq(bank.masks_at(),
             mirror.export_masks(pcfg, state.Gamma, 0.5, V=state.V))


def test_bank_sparse_params_serve(calibrated, tmp_path):
    params, pcfg, stats, state = calibrated
    d = tmp_path / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    eng = ServeEngine.from_artifact(d, params, slots=1, capacity=32)
    assert formats.sparse_leaves(eng.params)
    rid = eng.submit(np.array([3, 1, 4, 1, 5]), 4)
    out = eng.run()[rid]
    assert len(out) == 4


# -- engine prefill semantics ----------------------------------------------

def test_engine_chunked_prefill_single_compile_per_bucket():
    params = M.init_params(CFG, jax.random.key(0))
    eng = ServeEngine(CFG, params, slots=2, capacity=64)
    for p in ([1, 2, 3], [4, 5, 6, 7], [8, 9]):  # all pad to one bucket
        eng.submit(np.array(p), 2)
    eng.run()
    assert set(eng._prefill_fns) == {8}  # bucketed: one jitted prefill
