"""First direct unit tests for launch.hlo_analysis against a small golden
HLO text fixture: while-loop trip multiplication, fusion internals, sync
AND async-pair collectives (counted once, not zero/twice), tab/CRLF dump
tolerance, input_output_alias parsing, and per-computation attribution.
"""
import textwrap

from repro.launch import hlo_analysis as H

# A hand-built optimized-HLO-shaped dump: entry calls a while loop (trip
# count 3 from the condition's constant) whose body does one dot via a
# fusion, one sync all-reduce, and one async all-gather start/done pair.
GOLDEN = textwrap.dedent("""\
    HloModule jit_step, input_output_alias={ {0}: (0, {}, may-alias) }, entry_computation_layout={(f32[8,16])->f32[8,16]}

    %fused_dot (p0: f32[8,16], p1: f32[16,16]) -> f32[8,16] {
      %p0 = f32[8,16] parameter(0)
      %p1 = f32[16,16] parameter(1)
      ROOT %d = f32[8,16] dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }

    %body (arg: (s32[], f32[8,16], f32[16,16])) -> (s32[], f32[8,16], f32[16,16]) {
      %arg = (s32[], f32[8,16], f32[16,16]) parameter(0)
      %i = s32[] get-tuple-element(%arg), index=0
      %x = f32[8,16] get-tuple-element(%arg), index=1
      %w = f32[16,16] get-tuple-element(%arg), index=2
      %one = s32[] constant(1)
      %ip = s32[] add(%i, %one)
      %y = f32[8,16] fusion(%x, %w), kind=kOutput, calls=%fused_dot
      %ar = f32[8,16] all-reduce(%y), replica_groups=[1,4], to_apply=%sum
      %ag.start = f32[8,16] all-gather-start(%ar), replica_groups=[2,2], dimensions={0}
      %ag.done = f32[8,16] all-gather-done(%ag.start)
      ROOT %out = (s32[], f32[8,16], f32[16,16]) tuple(%ip, %ag.done, %w)
    }

    %cond (carg: (s32[], f32[8,16], f32[16,16])) -> pred[] {
      %carg = (s32[], f32[8,16], f32[16,16]) parameter(0)
      %ci = s32[] get-tuple-element(%carg), index=0
      %trip = s32[] constant(3)
      ROOT %lt = pred[] compare(%ci, %trip), direction=LT
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (e0: f32[8,16], e1: f32[16,16]) -> f32[8,16] {
      %e0 = f32[8,16] parameter(0)
      %e1 = f32[16,16] parameter(1)
      %zero = s32[] constant(0)
      %t = (s32[], f32[8,16], f32[16,16]) tuple(%zero, %e0, %e1)
      %w = (s32[], f32[8,16], f32[16,16]) while(%t), condition=%cond, body=%body
      ROOT %r = f32[8,16] get-tuple-element(%w), index=1
    }
""")

F32 = 4
OUT_BYTES = 8 * 16 * F32          # one f32[8,16] buffer


def test_while_trip_count_multiplies_body():
    s = H.analyze(GOLDEN)
    assert s.n_while == 1
    assert s.trip_counts == [3]
    # fused dot: 2 * numel(out) * contracted = 2 * 128 * 16, x3 trips
    assert s.dot_flops == 3 * 2 * 128 * 16


def test_async_collective_pair_counted_exactly_once():
    s = H.analyze(GOLDEN)
    # sync all-reduce: 2x bytes; async all-gather pair: 1x bytes ONCE
    # (the -done materialization must not double it), each x3 trips
    assert s.coll_by_op["all-reduce"] == 3 * 2 * OUT_BYTES
    assert s.coll_by_op["all-gather"] == 3 * OUT_BYTES
    assert s.coll_bytes == 3 * 3 * OUT_BYTES


def test_fusion_internals_not_double_counted():
    s = H.analyze(GOLDEN)
    # materialized per trip: ip(s32, 4B) + fusion out + all-reduce out +
    # ag.start + ag.done, x3 trips.  The fusion-INTERNAL dot output and
    # the tuple/GTE/parameter/constant/while plumbing add nothing.
    assert s.bytes_out == 3 * (4 + 4 * OUT_BYTES)


def test_crlf_and_tab_dumps_parse_identically():
    crlf = GOLDEN.replace("\n", "\r\n")
    tabbed = "\n".join(
        ("\t" + ln.lstrip() if ln[:1].isspace() else ln)
        for ln in GOLDEN.splitlines())
    base = H.analyze(GOLDEN)
    for variant in (crlf, tabbed):
        s = H.analyze(variant)
        assert s.dot_flops == base.dot_flops
        assert s.coll_bytes == base.coll_bytes
        assert s.trip_counts == base.trip_counts


def test_input_output_alias_parsing():
    aliases = H.parse_input_output_aliases(GOLDEN)
    assert aliases == [{"output_index": [0], "param_number": 0,
                        "param_index": [], "kind": "may-alias"}]
    assert H.parse_input_output_aliases("HloModule nothing") == []
    multi = ("HloModule m, input_output_alias={ {0}: (0, {}, may-alias), "
             "{1}: (2, {0}, must-alias) }")
    got = H.parse_input_output_aliases(multi)
    assert len(got) == 2
    assert got[1] == {"output_index": [1], "param_number": 2,
                      "param_index": [0], "kind": "must-alias"}


def test_attribution_rows_localize_the_loop_body():
    rows = H.attribution(GOLDEN)
    by_name = {name: (b, f, c, m) for b, f, c, m, name in rows}
    assert "body" in by_name
    b, f, c, m = by_name["body"]
    assert m == 3                      # trip-count multiplicity
    assert f == 3 * 2 * 128 * 16       # the fusion's dot attributed here
    assert c == 3 * 3 * OUT_BYTES
    # entry holds no flops of its own
    eb, ef, ec, em = by_name["main"]
    assert ef == 0 and em == 1
