"""Abstract pipeline auditor: memory planner, sharding checker, zoo dry-run.

The acceptance criteria live here: the static liveness walk agrees with
compiled ``memory_analysis()`` within 10% on the llama + mixtral smoke
configs, the static SearchState estimate equals the live figure
``results/bench/BENCH_calibrate.json`` records, and the whole-zoo dry-run
matches its committed golden contracts.
"""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).parent.parent


# ---------------------------------------------------------------------------
# memplan: SearchState static bytes == live bench figure
# ---------------------------------------------------------------------------

def test_search_state_bytes_matches_live_bench():
    """eval_shape of init_search must reproduce the byte count the live
    calibration benchmark measured off real buffers - the planner's fit
    table is only trustworthy if the static and live layouts agree."""
    from repro.analysis import memplan
    static = memplan.search_state_bytes("llama3.2-1b")
    bench = json.loads((REPO / "results/bench/BENCH_calibrate.json")
                       .read_text())
    assert bench["arch"] == "llama3.2-1b" and bench.get("smoke", True)
    assert static == bench["search_state_bytes"] == 7344652


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x22b"])
def test_memplan_within_10pct_of_compiled(arch):
    """Acceptance criterion: static peak bytes within 10% of compiled
    ``memory_analysis()`` on the dense decode surface of both smoke
    configs.  (bf16 surfaces diverge on the CPU backend only because XLA
    stages f32 copies of bf16 GEMM operands - memplan reports that
    separately as ``bf16_staging_bytes``.)"""
    from repro.analysis import memplan, surfaces
    surf = surfaces.serve_surfaces(arch, mesh_shape=None, sparse=False)[0]
    assert surf.name == "decode"
    res = memplan.crosscheck(surf.fn, *surf.args, surface=surf.name)
    assert res["compiled"]["total_bytes"] > 0
    assert abs(res["rel_err"]) <= 0.10, res


def test_memplan_extracts_pallas_vmem_blocks():
    """BlockSpec-derived VMEM footprints for every pallas_call in the
    sparse decode jaxpr: nonzero bytes, plausible bound (v5e VMEM 128MB)."""
    import jax
    from repro.analysis import memplan, surfaces
    surf = surfaces.serve_surfaces("llama3.2-1b", mesh_shape=None)[0]
    closed = jax.make_jaxpr(surf.fn)(*surf.args)
    plan = memplan.plan_jaxpr(closed, surface="decode")
    assert plan.pallas, "sparse decode must run through pallas kernels"
    for pc in plan.pallas:
        assert pc.vmem_bytes > 0 and pc.vmem_bytes < 128 * 2**20, pc
        assert pc.n_blocks > 0
    names = {pc.name for pc in plan.pallas}
    assert any("nm" in n or "matmul" in n for n in names), names


def test_search_plan_streaming_threshold():
    """The O(sqrt N) table: a generous budget makes streaming optional
    (g_max == L); shrinking the budget below W + shadows forces a smaller
    group; below W + shadows/L even g=1 overflows (g_max None)."""
    from repro.analysis import memplan
    gen = memplan.search_plan("llama3.2-1b", smoke=True,
                              device_counts=(1,), budget_gb=16.0)
    L = gen["num_layers"]
    row = gen["per_mesh"][0]
    assert row["fits"] and row["max_group_layers"] == L
    assert not row["streaming_mandatory"]
    assert 1 <= gen["sqrt_group_layers"] <= L

    w, sh = gen["w_bytes"], gen["shadow_bytes"]
    mid = (w + sh / L * (L / 2)) / 1e9          # fits ~L/2 groups only
    tight = memplan.search_plan("llama3.2-1b", smoke=True,
                                device_counts=(1,), budget_gb=mid)
    t = tight["per_mesh"][0]
    assert t["streaming_mandatory"] and 1 <= t["max_group_layers"] < L

    none = memplan.search_plan("llama3.2-1b", smoke=True,
                               device_counts=(1,),
                               budget_gb=(w * 0.5) / 1e9)
    assert none["per_mesh"][0]["max_group_layers"] is None


# ---------------------------------------------------------------------------
# zoo: family reports + golden contracts
# ---------------------------------------------------------------------------

def test_zoo_llama_matches_committed_golden_1dev():
    """One family end-to-end against its committed golden (the full-zoo
    sweep runs in CI); drift in any pinned fact fails structurally."""
    from repro.analysis import zoo
    man = zoo.build_zoo_manifest("llama3.2-1b", mesh_shape=None)
    golden = json.loads(
        (REPO / "results/contracts/zoo/llama3.2-1b_1dev.json").read_text())
    assert zoo.zoo_diff(golden, man) == []
    assert man["feasibility"]["traces"] and man["feasibility"]["fits_16gb"]
    st = man["stages"]
    assert st["calibrate"]["search_state_bytes"] == 7344652
    assert st["engine_decode"]["host_callbacks"] == 0
    assert st["sparsify"]["kernel_native_packed"] == 7
    assert st["fleet"]["shared_leaves"] == 4


def test_zoo_whisper_structured_skip():
    """Encoder-decoder families cannot use the slot engine; the zoo must
    emit a structured skip AND still audit decode_step directly."""
    from repro.analysis import zoo
    man = zoo.build_zoo_manifest("whisper-small", mesh_shape=None)
    ed = man["stages"]["engine_decode"]
    assert ed["status"] == "skip" and "encoder-decoder" in ed["reason"]
    assert ed["surface"] == "decode_step" and ed["host_callbacks"] == 0
    assert man["feasibility"]["traces"]


def test_zoo_xlstm_nm_infeasible_skip():
    """xlstm's ff_down kernel (K=85) breaks 2:4 grouping: the sparsify
    stage skips with the offending leaf named, the bank re-thresholds
    unstructured budgets instead, and serving audits masked-dense."""
    from repro.analysis import zoo
    man = zoo.build_zoo_manifest("xlstm-125m", mesh_shape=None)
    sp = man["stages"]["sparsify"]
    assert sp["status"] == "skip" and "K=85" in sp["reason"]
    assert man["stages"]["bank"]["budgets"] == 2
    assert man["stages"]["engine_decode"]["sparse"] is False
    assert man["feasibility"]["traces"]


def test_zoo_diff_ignores_info_flags_drift(tmp_path):
    from repro.analysis import zoo
    golden = {"family": "x", "stages": {"bank": {"budgets": 2}},
              "info": {"jax": "0.0.0"}}
    same = {"family": "x", "stages": {"bank": {"budgets": 2}},
            "info": {"jax": "9.9.9"}}
    assert zoo.zoo_diff(golden, same) == []
    drift = {"family": "x", "stages": {"bank": {"budgets": 3}},
             "info": {"jax": "9.9.9"}}
    diffs = zoo.zoo_diff(golden, drift)
    assert len(diffs) == 1 and diffs[0]["path"].endswith("bank.budgets")
    missing = {"family": "x", "stages": {}, "info": {}}
    assert any(d["current"] == "<missing>"
               for d in zoo.zoo_diff(golden, missing))


def test_zoo_run_update_then_check_roundtrip(tmp_path):
    """run_zoo --update writes a golden that the very next check accepts;
    a missing golden fails with a structured diff artifact."""
    from repro.analysis import zoo
    d = tmp_path / "zoo"
    assert zoo.run_zoo(["llama3.2-1b"], zoo_dir=d, update=True) == 0
    assert zoo.run_zoo(["llama3.2-1b"], zoo_dir=d) == 0
    diff_out = tmp_path / "diff.json"
    rc = zoo.run_zoo(["gemma3-1b"], zoo_dir=d, diff_out=diff_out)
    assert rc == 1 and json.loads(diff_out.read_text())


# ---------------------------------------------------------------------------
# shardcheck (mesh runs in a forced-4-device subprocess, as test_tp does)
# ---------------------------------------------------------------------------

def _run_forced_4dev(code: str) -> None:
    prelude = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
            " --xla_force_host_platform_device_count=4")
        os.environ["JAX_PLATFORMS"] = "cpu"
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c",
                        prelude + textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=str(REPO), timeout=1200)
    assert r.returncode == 0 and "ok" in r.stdout, (r.stdout, r.stderr)


def test_shardcheck_1dev_is_structured_skip():
    from repro.analysis import shardcheck
    rep = shardcheck.check_arch("llama3.2-1b", mesh_shape=None)
    assert rep["clean"] and rep["skipped"] and rep["findings"] == []


def test_shardcheck_leaves_and_psums_clean_4dev():
    """On the (2,2) mesh every llama compressed leaf K-shards (no silent
    replicated fallback), every decode psum axis is partitioned in an
    input and absent from the outputs, and a deliberately unpartitioned
    psum IS flagged (the checker can fail, not just pass)."""
    _run_forced_4dev("""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.analysis import shardcheck
    from repro.models.common import shard_map

    rep = shardcheck.check_arch("llama3.2-1b", mesh_shape=(2, 2))
    assert rep["clean"], rep["findings"]
    lv = rep["leaves"]
    assert lv["sparse_leaves"] == lv["k_sharded"] == 7, lv
    assert lv["replicated_k"] == 0 and rep["surfaces"]["decode"]["psums"] > 0

    # negative control: psum over an axis no input spec partitions
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    bad = shard_map(lambda x: jax.lax.psum(x, "model"), mesh=mesh,
                    in_specs=(P("data"),), out_specs=P("data"))
    closed = jax.make_jaxpr(bad)(jnp.ones((4, 8)))
    counts, findings = shardcheck.check_psum_axes(closed, surface="bad")
    assert counts["psums"] == 1
    assert any(f["kind"] == "psum_axis_unpartitioned" for f in findings)

    # xlstm auto-falls back to the dense engine and stays clean
    rx = shardcheck.check_arch("xlstm-125m", mesh_shape=(2, 2))
    assert rx["clean"] and rx["leaves"]["sparse_leaves"] == 0
    assert "2:4 infeasible" in rx["sparse_note"]
    print("ok")
    """)


def test_zoo_golden_matches_4dev_mesh():
    """The CI mesh variant: llama's 2x2 zoo golden reproduces under 4
    forced devices, with the shardcheck stage clean."""
    _run_forced_4dev("""
    import json
    from repro.analysis import zoo
    man = zoo.build_zoo_manifest("llama3.2-1b", mesh_shape=(2, 2))
    golden = json.loads(
        open("results/contracts/zoo/llama3.2-1b_2x2.json").read())
    assert zoo.zoo_diff(golden, man) == []
    sc = man["stages"]["shardcheck"]
    assert sc["status"] == "ok" and sc["clean"]
    assert man["feasibility"]["sharding_clean"] is True
    print("ok")
    """)
