"""Continuous-batching serve engine."""
import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def test_prefill_padding_respects_sliding_window_ring():
    """gemma2 smoke caps local-attention rings at window=16: a pow-2 prefill
    bucket larger than the ring would evict real in-window tokens and leave
    junk at positions the ring treats as valid.  Padded and exact prefill
    must decode identically."""
    cfg = get_smoke_config("gemma2-2b")
    params = M.init_params(cfg, jax.random.key(0))
    prompt = np.arange(1, 19) % cfg.vocab_size       # len 18 > window ring 16
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    rid = eng.submit(prompt, 4)
    out = eng.run()[rid]
    # oracle: token-by-token decode through the same jitted step function
    caches = M.init_caches(cfg, 1, 32)
    dec = jax.jit(lambda p, tok, c, t: M.decode_step(cfg, p, tok, c, t))
    tok = None
    for t, x in enumerate(prompt):
        logits, caches = dec(params, np.array([x], np.int32), caches,
                             np.int32(t))
        tok = int(np.asarray(logits[0]).argmax())
    want = [tok]
    for i in range(3):
        logits, caches = dec(params, np.array([tok], np.int32), caches,
                             np.int32(len(prompt) + i))
        tok = int(np.asarray(logits[0]).argmax())
        want.append(tok)
    assert out == want


def test_single_token_prompt_resets_reused_slot():
    """xlstm recurrent state is not position-masked: a 1-token prompt (which
    runs no prefill forward) admitted into a reused slot must not see the
    previous request's state."""
    cfg = get_smoke_config("xlstm-125m")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    first = eng.submit(np.array([5, 6, 7, 8, 9]), 4)
    eng.run()
    second = eng.submit(np.array([3]), 4)
    reused = eng.run()[second]
    fresh_eng = ServeEngine(cfg, params, slots=1, capacity=32)
    rid = fresh_eng.submit(np.array([3]), 4)
    fresh = fresh_eng.run()[rid]
    assert reused == fresh


def test_eos_terminates_slot_and_reuses_it_midbatch():
    """A slot must free on emitting eos (not just max_tokens): the eos is
    the request's last output token, generation stops early, and a queued
    request admitted into the freed slot decodes exactly as it would on a
    fresh engine."""
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    p1, p2 = np.array([5, 6, 7, 8]), np.array([9, 10, 11])
    eng0 = ServeEngine(cfg, params, slots=1, capacity=32)
    assert eng0.eos_id is None  # cfg default: max_tokens only
    r0 = eng0.submit(p1, 8)
    base = eng0.run()[r0]
    assert len(base) == 8                     # no eos -> runs to max_tokens
    eos = base[0]                             # a token this stream emits

    eng = ServeEngine(cfg, params, slots=1, capacity=32, eos_id=eos)
    r1 = eng.submit(p1, 8)
    r2 = eng.submit(p2, 4)          # queued; admitted after r1 hits eos
    out = eng.run()
    # terminated ON the first eos, eos included, well short of max_tokens
    assert out[r1] == base[:base.index(eos) + 1] and len(out[r1]) < 8
    # the non-eos stream is unaffected and the reused slot leaked nothing
    assert eos not in out[r2] and len(out[r2]) == 4
    fresh = ServeEngine(cfg, params, slots=1, capacity=32, eos_id=eos)
    rf = fresh.submit(p2, 4)
    assert fresh.run()[rf] == out[r2]

    # eos_id plumbs from the ModelConfig when not passed explicitly
    import dataclasses
    cfg_eos = dataclasses.replace(cfg, eos_id=eos)
    eng_cfg = ServeEngine(cfg_eos, params, slots=1, capacity=32)
    assert eng_cfg.eos_id == eos
    rc = eng_cfg.submit(p1, 8)
    assert eng_cfg.run()[rc] == out[r1]


def test_engine_batching_invariance():
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=64)
    r1 = eng.submit(np.array([5, 6, 7, 8]), max_tokens=5)
    r2 = eng.submit(np.array([9, 10, 11]), max_tokens=4)
    r3 = eng.submit(np.array([1, 2]), max_tokens=3)
    out = eng.run()
    assert set(out) == {r1, r2, r3}
    assert [len(out[r]) for r in (r1, r2, r3)] == [5, 4, 3]
    # same request alone must decode identically (slot isolation)
    eng2 = ServeEngine(cfg, params, slots=1, capacity=64)
    rid = eng2.submit(np.array([5, 6, 7, 8]), max_tokens=5)
    assert eng2.run()[rid] == out[r1]
