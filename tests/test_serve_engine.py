"""Continuous-batching serve engine."""
import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def test_prefill_padding_respects_sliding_window_ring():
    """gemma2 smoke caps local-attention rings at window=16: a pow-2 prefill
    bucket larger than the ring would evict real in-window tokens and leave
    junk at positions the ring treats as valid.  Padded and exact prefill
    must decode identically."""
    cfg = get_smoke_config("gemma2-2b")
    params = M.init_params(cfg, jax.random.key(0))
    prompt = np.arange(1, 19) % cfg.vocab_size       # len 18 > window ring 16
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    rid = eng.submit(prompt, 4)
    out = eng.run()[rid]
    # oracle: token-by-token decode through the same jitted step function
    caches = M.init_caches(cfg, 1, 32)
    dec = jax.jit(lambda p, tok, c, t: M.decode_step(cfg, p, tok, c, t))
    tok = None
    for t, x in enumerate(prompt):
        logits, caches = dec(params, np.array([x], np.int32), caches,
                             np.int32(t))
        tok = int(np.asarray(logits[0]).argmax())
    want = [tok]
    for i in range(3):
        logits, caches = dec(params, np.array([tok], np.int32), caches,
                             np.int32(len(prompt) + i))
        tok = int(np.asarray(logits[0]).argmax())
        want.append(tok)
    assert out == want


def test_single_token_prompt_resets_reused_slot():
    """xlstm recurrent state is not position-masked: a 1-token prompt (which
    runs no prefill forward) admitted into a reused slot must not see the
    previous request's state."""
    cfg = get_smoke_config("xlstm-125m")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    first = eng.submit(np.array([5, 6, 7, 8, 9]), 4)
    eng.run()
    second = eng.submit(np.array([3]), 4)
    reused = eng.run()[second]
    fresh_eng = ServeEngine(cfg, params, slots=1, capacity=32)
    rid = fresh_eng.submit(np.array([3]), 4)
    fresh = fresh_eng.run()[rid]
    assert reused == fresh


def test_engine_batching_invariance():
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=64)
    r1 = eng.submit(np.array([5, 6, 7, 8]), max_tokens=5)
    r2 = eng.submit(np.array([9, 10, 11]), max_tokens=4)
    r3 = eng.submit(np.array([1, 2]), max_tokens=3)
    out = eng.run()
    assert set(out) == {r1, r2, r3}
    assert [len(out[r]) for r in (r1, r2, r3)] == [5, 4, 3]
    # same request alone must decode identically (slot isolation)
    eng2 = ServeEngine(cfg, params, slots=1, capacity=64)
    rid = eng2.submit(np.array([5, 6, 7, 8]), max_tokens=5)
    assert eng2.run()[rid] == out[r1]
