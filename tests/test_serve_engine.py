"""Continuous-batching serve engine."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models import model as M
from repro.serve.engine import EngineFns, ServeEngine


def test_prefill_padding_respects_sliding_window_ring():
    """gemma2 smoke caps local-attention rings at window=16: a pow-2 prefill
    bucket larger than the ring would evict real in-window tokens and leave
    junk at positions the ring treats as valid.  Padded and exact prefill
    must decode identically."""
    cfg = get_smoke_config("gemma2-2b")
    params = M.init_params(cfg, jax.random.key(0))
    prompt = np.arange(1, 19) % cfg.vocab_size       # len 18 > window ring 16
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    rid = eng.submit(prompt, 4)
    out = eng.run()[rid]
    # oracle: token-by-token decode through the same jitted step function
    caches = M.init_caches(cfg, 1, 32)
    dec = jax.jit(lambda p, tok, c, t: M.decode_step(cfg, p, tok, c, t))
    tok = None
    for t, x in enumerate(prompt):
        logits, caches = dec(params, np.array([x], np.int32), caches,
                             np.int32(t))
        tok = int(np.asarray(logits[0]).argmax())
    want = [tok]
    for i in range(3):
        logits, caches = dec(params, np.array([tok], np.int32), caches,
                             np.int32(len(prompt) + i))
        tok = int(np.asarray(logits[0]).argmax())
        want.append(tok)
    assert out == want


def test_single_token_prompt_resets_reused_slot():
    """xlstm recurrent state is not position-masked: a 1-token prompt (which
    runs no prefill forward) admitted into a reused slot must not see the
    previous request's state."""
    cfg = get_smoke_config("xlstm-125m")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    first = eng.submit(np.array([5, 6, 7, 8, 9]), 4)
    eng.run()
    second = eng.submit(np.array([3]), 4)
    reused = eng.run()[second]
    fresh_eng = ServeEngine(cfg, params, slots=1, capacity=32)
    rid = fresh_eng.submit(np.array([3]), 4)
    fresh = fresh_eng.run()[rid]
    assert reused == fresh


def test_eos_terminates_slot_and_reuses_it_midbatch():
    """A slot must free on emitting eos (not just max_tokens): the eos is
    the request's last output token, generation stops early, and a queued
    request admitted into the freed slot decodes exactly as it would on a
    fresh engine."""
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    p1, p2 = np.array([5, 6, 7, 8]), np.array([9, 10, 11])
    eng0 = ServeEngine(cfg, params, slots=1, capacity=32)
    assert eng0.eos_id is None  # cfg default: max_tokens only
    r0 = eng0.submit(p1, 8)
    base = eng0.run()[r0]
    assert len(base) == 8                     # no eos -> runs to max_tokens
    eos = base[0]                             # a token this stream emits

    eng = ServeEngine(cfg, params, slots=1, capacity=32, eos_id=eos)
    r1 = eng.submit(p1, 8)
    r2 = eng.submit(p2, 4)          # queued; admitted after r1 hits eos
    out = eng.run()
    # terminated ON the first eos, eos included, well short of max_tokens
    assert out[r1] == base[:base.index(eos) + 1] and len(out[r1]) < 8
    # the non-eos stream is unaffected and the reused slot leaked nothing
    assert eos not in out[r2] and len(out[r2]) == 4
    fresh = ServeEngine(cfg, params, slots=1, capacity=32, eos_id=eos)
    rf = fresh.submit(p2, 4)
    assert fresh.run()[rf] == out[r2]

    # eos_id plumbs from the ModelConfig when not passed explicitly
    import dataclasses
    cfg_eos = dataclasses.replace(cfg, eos_id=eos)
    eng_cfg = ServeEngine(cfg_eos, params, slots=1, capacity=32)
    assert eng_cfg.eos_id == eos
    rc = eng_cfg.submit(p1, 8)
    assert eng_cfg.run()[rc] == out[r1]


def test_submit_rejects_empty_prompt_without_wedging_a_slot():
    """A zero-length prompt used to IndexError inside _prefill_slot AFTER
    the slot was claimed, wedging it forever; it must be rejected at
    submit() and leave the engine fully serviceable."""
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.array([], np.int32), 4)
    assert not eng.pending and all(r is None for r in eng.active)
    # the engine still serves: the rejected request claimed nothing
    rid = eng.submit(np.array([5, 6, 7]), 3)
    assert len(eng.run()[rid]) == 3


def test_max_tokens_zero_and_one():
    """max_tokens=0 used to emit 1 token (appended before the length check)
    and burn a decode step; it must short-circuit at submit.  max_tokens=1
    emits exactly one."""
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    r0 = eng.submit(np.array([5, 6, 7]), 0)
    r1 = eng.submit(np.array([5, 6, 7]), 1)
    out = eng.run()
    assert out[r0] == [] and len(out[r1]) == 1
    # a lone zero-token request completes without claiming a slot or
    # stepping the model (positions untouched)
    eng2 = ServeEngine(cfg, params, slots=1, capacity=32)
    rz = eng2.submit(np.array([1, 2]), 0)
    assert eng2.run() == {rz: []}
    assert (eng2.pos == 0).all() and all(r is None for r in eng2.active)


def test_submit_rejects_prompt_at_capacity():
    """A prompt needing >= capacity prefill rows used to trip a bare assert
    inside the run() loop (gone under python -O), killing every in-flight
    request; it must raise at submit() and leave other requests unharmed."""
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=8)
    ok = eng.submit(np.arange(1, 5), 3)         # fits
    with pytest.raises(ValueError, match="capacity"):
        eng.submit(np.arange(1, 10), 3)         # 9 tokens -> 8 rows == cap
    out = eng.run()
    assert len(out[ok]) == 3 and len(out) == 1


def test_shared_engine_fns_match_per_engine_build():
    """Two engines sharing one EngineFns (the fleet construction) must
    decode token-identically to engines that build their own."""
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    fns = EngineFns(cfg, 32, "fused")
    prompts = [np.array([5, 6, 7, 8]), np.array([9, 10, 11])]
    outs = []
    for shared in (fns, None):
        toks = []
        for p in prompts:
            eng = ServeEngine(cfg, params, slots=1, capacity=32, fns=shared)
            rid = eng.submit(p, 4)
            toks.append(eng.run()[rid])
        outs.append(toks)
    assert outs[0] == outs[1]
    # shared prefill cache serves both engines (one bucket, one entry)
    assert set(fns.prefill_fns) == {8}
    with pytest.raises(ValueError, match="EngineFns"):
        ServeEngine(cfg, params, slots=1, capacity=64, fns=fns)  # mismatch


def test_queue_is_fifo_deque():
    """Admission pops the OLDEST queued request (O(1) off a deque): with
    one slot, three requests complete in submission order."""
    import collections
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=1, capacity=32)
    assert isinstance(eng.queue, collections.deque)
    prompts = [np.array([5, 6, 7]), np.array([9, 10]), np.array([1, 2, 3])]
    rids = [eng.submit(p, 2) for p in prompts]
    assert [r.rid for r in eng.queue] == rids  # submission order kept
    eng._admit()
    assert eng.active[0].rid == rids[0]        # oldest admitted first
    assert [r.rid for r in eng.queue] == rids[1:]
    out = eng.run()
    assert all(len(out[r]) == 2 for r in rids)


def test_engine_fns_verify_matches_sequential_decode():
    """EngineFns.verify(k) - the speculative verifier's ONE batched
    teacher-forced pass - must be bit-identical (argmax AND cache rows) to
    feeding the same k tokens through the fused decode one at a time, with
    every row at its own position."""
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    fns = EngineFns(cfg, 32)
    B, k = 3, 4
    caches = M.init_caches(cfg, B, 32)
    rng = np.random.default_rng(7)
    pos = np.array([0, 0, 0], np.int32)
    tok = rng.integers(1, cfg.vocab_size, size=(B,)).astype(np.int32)
    for _ in range(5):  # build unequal per-row history
        step = (pos < np.array([5, 2, 4])).astype(np.int32)
        logits, caches = fns.decode(params, tok, caches, pos)
        nxt = np.asarray(logits.argmax(-1)).astype(np.int32)
        tok = np.where(step, nxt, tok)
        pos = pos + step  # rows that "idle" rewrite the same ring row
    fed = rng.integers(1, cfg.vocab_size, size=(B, k)).astype(np.int32)

    seq_caches, p = caches, pos.copy()
    want = []
    for i in range(k):
        logits, seq_caches = fns.decode(params, fed[:, i], seq_caches, p)
        want.append(np.asarray(logits.argmax(-1)))
        p += 1
    want = np.stack(want, 1)

    got, ver_caches = fns.verify(k)(params, fed, caches, pos)
    assert np.array_equal(np.asarray(got), want)
    for a, b in zip(jax.tree.leaves(seq_caches), jax.tree.leaves(ver_caches)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert set(fns.verify_fns) == {k}
    assert "verify_4" in fns.jit_cache_sizes()


def test_engine_fns_draft_matches_own_sequential_decode():
    """EngineFns.draft(k) - the proposer's one-dispatch autoregressive
    loop - must reproduce the engine's own per-token greedy stream."""
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    fns = EngineFns(cfg, 32)
    B, k = 2, 5
    caches = M.init_caches(cfg, B, 32)
    seed = np.array([5, 9], np.int32)
    pos = np.zeros((B,), np.int32)

    seq_caches, p = caches, pos.copy()
    tok, want = seed.copy(), []
    for _ in range(k):
        logits, seq_caches = fns.decode(params, tok, seq_caches, p)
        tok = np.asarray(logits.argmax(-1)).astype(np.int32)
        want.append(tok)
        p += 1
    want = np.stack(want, 1)

    got, _ = fns.draft(k)(params, seed, caches, pos)
    assert np.array_equal(np.asarray(got), want)
    assert set(fns.draft_fns) == {k}


def test_engine_batching_invariance():
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=64)
    r1 = eng.submit(np.array([5, 6, 7, 8]), max_tokens=5)
    r2 = eng.submit(np.array([9, 10, 11]), max_tokens=4)
    r3 = eng.submit(np.array([1, 2]), max_tokens=3)
    out = eng.run()
    assert set(out) == {r1, r2, r3}
    assert [len(out[r]) for r in (r1, r2, r3)] == [5, 4, 3]
    # same request alone must decode identically (slot isolation)
    eng2 = ServeEngine(cfg, params, slots=1, capacity=64)
    rid = eng2.submit(np.array([5, 6, 7, 8]), max_tokens=5)
    assert eng2.run()[rid] == out[r1]
