"""Continuous-batching serve engine."""
import jax
import numpy as np

from repro.configs.base import get_smoke_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def test_engine_batching_invariance():
    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(cfg, params, slots=2, capacity=64)
    r1 = eng.submit(np.array([5, 6, 7, 8]), max_tokens=5)
    r2 = eng.submit(np.array([9, 10, 11]), max_tokens=4)
    r3 = eng.submit(np.array([1, 2]), max_tokens=3)
    out = eng.run()
    assert set(out) == {r1, r2, r3}
    assert [len(out[r]) for r in (r1, r2, r3)] == [5, 4, 3]
    # same request alone must decode identically (slot isolation)
    eng2 = ServeEngine(cfg, params, slots=1, capacity=64)
    rid = eng2.submit(np.array([5, 6, 7, 8]), max_tokens=5)
    assert eng2.run()[rid] == out[r1]
