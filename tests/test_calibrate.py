"""Mesh-scale calibration pipeline: jitted-stats parity vs the tape oracle,
scanned-vs-eager search equivalence, microbatch gradient accumulation,
no_mirror_step leaf alignment, device-side export tie-breaking, and the
launch.calibrate -> MaskBank -> serve artifact handoff."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import calibrate, masks as masks_mod, metrics as metrics_mod
from repro.core import mirror
from repro.core.prunable import prunable_map
from repro.data.synthetic import batches_for
from repro.models import model as M
from repro.optim.losses import lm_loss

# scan-stacked: 4 layers of a 1-kind pattern -> (4, ...) stacked leaves
STACKED = ModelConfig(name="t4", family="dense", d_model=64, num_layers=4,
                      num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128,
                      vocab_size=256)
MOE = ModelConfig(name="m4", family="moe", d_model=64, num_layers=4,
                  num_heads=2, num_kv_heads=2, head_dim=32, d_ff=128,
                  moe_d_ff=128, vocab_size=256, pattern=("moe",),
                  num_experts=4, top_k=2)

_is_none = lambda x: x is None


def _stats_pair(cfg, params, batches):
    jit_stats = calibrate.collect_stats(cfg, params, batches, impl="jit")
    tape_stats = calibrate.collect_stats(cfg, params, batches, impl="tape")
    return jit_stats, tape_stats


def _assert_parity(cfg, params, jit_stats, tape_stats, *, tol):
    """The same aggregate criterion the bench gate enforces (see
    calibrate.stats_parity for why it is Frobenius, not elementwise)."""
    worst, ok, checked = calibrate.stats_parity(
        tape_stats, jit_stats, prunable_map(params), tol=tol)
    assert ok, (worst, tol)
    assert checked >= 5, checked  # attn + mlp/moe kernels all covered


def test_jit_stats_match_tape_scan_stacked():
    params = M.init_params(STACKED, jax.random.key(0))
    batches = batches_for(STACKED, n=3, batch=2, seq=32, split="calib")
    jit_stats, tape_stats = _stats_pair(STACKED, params, batches)
    # stacked leaves keep their leading layer axis in both impls
    ks = [s for s in jax.tree.leaves(jit_stats, is_leaf=_is_none)
          if s is not None]
    assert any(s.ndim == 2 and s.shape[0] == 4 for s in ks), \
        [s.shape for s in ks]
    _assert_parity(STACKED, params, jit_stats, tape_stats, tol=5e-2)


def test_jit_stats_match_tape_moe():
    params = M.init_params(MOE, jax.random.key(1))
    batches = batches_for(MOE, n=2, batch=2, seq=32, split="calib")
    jit_stats, tape_stats = _stats_pair(MOE, params, batches)
    # per-expert stats carry the (layers, E, d_in) shape in both impls
    shapes = {tuple(s.shape)
              for s in jax.tree.leaves(jit_stats, is_leaf=_is_none)
              if s is not None}
    assert (4, 4, 64) in shapes, shapes
    _assert_parity(MOE, params, jit_stats, tape_stats, tol=5e-2)


def test_stats_batches_policy_lives_in_pruneconfig():
    params = M.init_params(STACKED, jax.random.key(0))
    batches = batches_for(STACKED, n=4, batch=2, seq=32, split="calib")
    pcfg = PruneConfig(stats_batches=2)
    limited = calibrate.collect_stats(STACKED, params, batches, pcfg=pcfg)
    manual = calibrate.collect_stats(STACKED, params, batches[:2])
    for a, b in zip(jax.tree.leaves(limited, is_leaf=_is_none),
                    jax.tree.leaves(manual, is_leaf=_is_none)):
        if a is None:
            assert b is None
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6)


def _search_pair(pcfg_a, pcfg_b):
    params = M.init_params(STACKED, jax.random.key(0))
    batches = batches_for(STACKED, n=3, batch=4, seq=32, split="calib")
    stats = calibrate.collect_stats(STACKED, params, batches)
    sa, ha = calibrate.run_search(STACKED, pcfg_a, params, batches, stats,
                                 log_every=1)
    sb, hb = calibrate.run_search(STACKED, pcfg_b, params, batches, stats,
                                 log_every=1)
    return sa, ha, sb, hb


def test_scanned_search_matches_eager():
    """lax.scan-chunked steps (with a remainder chunk) == per-step loop."""
    eager = PruneConfig(local_metric="wanda", steps=5, scan_chunk=0)
    scanned = dataclasses.replace(eager, scan_chunk=2)  # 2+2+1: remainder
    sa, ha, sb, hb = _search_pair(eager, scanned)
    assert int(sa.step) == int(sb.step) == 5
    assert len(ha) == len(hb) == 5
    for a, b in zip(jax.tree.leaves(sa.Gamma, is_leaf=_is_none),
                    jax.tree.leaves(sb.Gamma, is_leaf=_is_none)):
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)
    for ma, mb in zip(ha, hb):
        assert abs(ma["loss"] - mb["loss"]) < 1e-3 * (1 + abs(ma["loss"]))


def test_grad_accum_matches_full_batch():
    """grad_accum=2 microbatches == one full-batch step (uniform masks)."""
    full = PruneConfig(local_metric="wanda", steps=3, grad_accum=1)
    accum = dataclasses.replace(full, grad_accum=2)
    sa, _, sb, _ = _search_pair(full, accum)
    for a, b in zip(jax.tree.leaves(sa.W), jax.tree.leaves(sb.W)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_search_with_host_mesh_rules_matches_unsharded():
    """rules= places W/Gamma/V via dist.sharding and changes no numerics."""
    from repro.dist.sharding import make_production_rules
    from repro.launch.mesh import make_host_mesh
    rules = make_production_rules(make_host_mesh())
    params = M.init_params(STACKED, jax.random.key(0))
    batches = batches_for(STACKED, n=2, batch=2, seq=32, split="calib")
    stats = calibrate.collect_stats(STACKED, params, batches)
    pcfg = PruneConfig(local_metric="wanda", steps=3)
    plain, _ = calibrate.run_search(STACKED, pcfg, params, batches, stats)
    sharded, _ = calibrate.run_search(STACKED, pcfg, params, batches, stats,
                                      rules=rules)
    for a, b in zip(jax.tree.leaves(plain.Gamma, is_leaf=_is_none),
                    jax.tree.leaves(sharded.Gamma, is_leaf=_is_none)):
        if a is None:
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


def test_search_never_touches_w0_with_donation():
    """Donated scan buffers must never alias the pretrained params."""
    params = M.init_params(STACKED, jax.random.key(0))
    before = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    batches = batches_for(STACKED, n=2, batch=2, seq=32, split="calib")
    stats = calibrate.collect_stats(STACKED, params, batches)
    pcfg = PruneConfig(local_metric="wanda", steps=4, scan_chunk=4)
    calibrate.run_search(STACKED, pcfg, params, batches, stats)
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b))


def test_no_mirror_step_leaf_alignment_moe():
    """The Eq. 8 objective must regularize exactly the prunable leaves -
    verified against a hand-rolled total on a model whose flattened leaf
    order interleaves prunable kernels with non-prunable ones (router,
    norms, embeddings)."""
    params = M.init_params(MOE, jax.random.key(2))
    batches = batches_for(MOE, n=1, batch=2, seq=32, split="calib")
    stats = calibrate.collect_stats(MOE, params, batches)
    prunable = prunable_map(params)
    pcfg = PruneConfig(local_metric="wanda", rho=1e-3, steps=1)
    W = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = jax.random.key(7)
    step = jnp.zeros((), jnp.int32)
    loss_fn = lambda w, b: lm_loss(MOE, w, b)
    _, total = mirror.no_mirror_step(pcfg, loss_fn, W, batches[0], stats,
                                     prunable, rng, step, l2=0.01)

    key = jax.random.fold_in(rng, step)
    S = metrics_mod.metric_tree(pcfg.local_metric, W, stats, prunable,
                                key=key, stoch_frac=pcfg.stoch_frac)
    reg = wreg = 0.0
    flat, _ = jax.tree_util.tree_flatten_with_path(prunable)
    for (kp, p), s, w in zip(
            flat, jax.tree.leaves(S, is_leaf=_is_none),
            jax.tree.leaves(W)):
        path = jax.tree_util.keystr(kp)
        if not p:
            continue
        assert s is not None, path
        assert "router" not in path and "embed" not in path, path
        reg += float(jnp.sum(jnp.square(s)))
        wreg += float(jnp.sum(jnp.square(w)))
    task = float(loss_fn(W, batches[0])[0])
    expect = task + 0.5 * pcfg.rho * reg + 0.01 * wreg
    assert abs(float(total) - expect) < 1e-2 * (1 + abs(expect)), \
        (float(total), expect)


def test_no_mirror_step_rejects_misaligned_trees():
    params = M.init_params(STACKED, jax.random.key(0))
    batches = batches_for(STACKED, n=1, batch=2, seq=32, split="calib")
    stats = calibrate.collect_stats(STACKED, params, batches)
    bad_prunable = {"not": "params-shaped"}
    W = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    with pytest.raises((ValueError, KeyError, TypeError)):
        mirror.no_mirror_step(
            PruneConfig(steps=1), lambda w, b: lm_loss(STACKED, w, b), W,
            batches[0], stats, bad_prunable, jax.random.key(0),
            jnp.zeros((), jnp.int32), l2=0.0)


def test_export_masks_device_side_tie_break():
    """Gamma zeros tie; V must break the tie without host pulls reordering
    nonzero Gamma entries."""
    pcfg = PruneConfig(mode="unstructured")
    Gamma = {"a": jnp.asarray([[0.0, 0.0, 3.0, 2.0]] * 4).T}
    V = {"a": jnp.asarray([[0.5, 0.9, 0.1, 0.1]] * 4).T}
    masks = mirror.export_masks(pcfg, Gamma, 0.25, V=V)  # keep 12/16
    m = np.asarray(masks["a"])
    # the two nonzero-Gamma rows always win; among the Gamma==0 ties the
    # higher-|V| row is kept
    assert m[2].all() and m[3].all()
    assert m[1].all() and not m[0].any()


def test_launch_calibrate_writes_consumable_bank(tmp_path):
    """The entry point's artifact serves masks + stats with zero re-runs."""
    from repro.launch import calibrate as launch_cal
    from repro.sparse.bank import MaskBank
    arch = "llama3.2-1b"
    from repro.configs.base import get_smoke_config
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    calib = batches_for(cfg, n=2, batch=2, seq=32, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=2,
                       stats_batches=2)
    out = tmp_path / "bank"
    bank = launch_cal.calibrate_to_bank(out, cfg=cfg, pcfg=pcfg,
                                        params=params, calib=calib,
                                        arch=arch, smoke=True)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # v2 artifact loads silently
        loaded = MaskBank.load(out)
    assert loaded.meta["params_fingerprint"] == \
        launch_cal.params_fingerprint(params)
    # masks from the loaded artifact == masks from the in-memory bank
    for a, b in zip(
            jax.tree.leaves(bank.masks_at(), is_leaf=_is_none),
            jax.tree.leaves(loaded.masks_at(), is_leaf=_is_none)):
        if a is None:
            assert b is None
            continue
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # persisted stats drive baselines without a stats pass
    wanda = calibrate.baseline_masks("wanda", params, loaded.stats, 0.5)
    sp = masks_mod.sparsity_of(wanda)
    assert 0.3 < sp < 0.7, sp
    # ensure_bank: matching pcfg+weights -> pure load (bit-identical Gamma)
    again = launch_cal.ensure_bank(out, cfg=cfg, pcfg=pcfg, params=params,
                                   calib=calib, arch=arch, smoke=True)
    assert again.meta.get("checksum") == bank.meta.get("checksum")


def test_bank_legacy_v1_load_warns(tmp_path):
    from repro.ckpt import checkpoint as ckpt
    from repro.sparse.bank import SCHEMA, MaskBank
    from repro.configs.base import get_smoke_config
    arch = "llama3.2-1b"
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    calib = batches_for(cfg, n=1, batch=2, seq=32, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=1,
                       stats_batches=1)
    stats = calibrate.collect_stats(cfg, params, calib, pcfg=pcfg)
    state, _ = calibrate.run_search(cfg, pcfg, params, calib, stats)
    # a legacy writer: schema v1 metadata, no format_version / checksum
    legacy = tmp_path / "v1bank"
    ckpt.save_artifact(legacy,
                       {"Gamma": state.Gamma, "V": state.V, "stats": stats},
                       metadata={"schema": SCHEMA, "arch": arch,
                                 "smoke": True,
                                 "pcfg": dataclasses.asdict(pcfg)})
    with pytest.warns(UserWarning, match="format_version=1"):
        bank = MaskBank.load(legacy)
    assert bank.meta.get("format_version", 1) == 1
    assert bank.masks_at() is not None  # still serves, just loudly
