"""Minimal deterministic stand-in for ``hypothesis`` (not installed here).

Installed into ``sys.modules`` by conftest only when the real package is
missing.  Supports the subset the suite uses: ``@settings(max_examples=N,
deadline=None)``, ``@given(**kwargs)`` with ``sampled_from`` / ``integers``
/ ``floats`` / ``booleans`` strategies.  Each test runs ``max_examples``
times with deterministic draws (boundary values first, then seeded
pseudo-random), so failures are reproducible; there is no shrinking.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example_at(self, rng: random.Random, i: int):
        return self._draw(rng, i)


def sampled_from(options):
    options = list(options)
    return _Strategy(lambda rng, i: options[i % len(options)]
                     if i < len(options) else rng.choice(options))


def integers(min_value, max_value):
    def draw(rng, i):
        if i == 0:
            return min_value
        if i == 1:
            return max_value
        return rng.randint(min_value, max_value)
    return _Strategy(draw)


def floats(min_value, max_value, width=64, **_kw):
    def draw(rng, i):
        if i == 0:
            return float(min_value)
        if i == 1:
            return float(max_value)
        return rng.uniform(float(min_value), float(max_value))
    return _Strategy(draw)


def booleans():
    return _Strategy(lambda rng, i: bool(i % 2) if i < 2 else rng.random() < 0.5)


def tuples(*strats):
    return _Strategy(lambda rng, i: tuple(s.example_at(rng, i) for s in strats))


def just(value):
    return _Strategy(lambda rng, i: value)


def settings(max_examples: int = 20, deadline=None, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # read at call time: @settings sits ABOVE @given, so it applies
            # after us and tags the wrapper, not fn
            n = getattr(wrapper, "_shim_max_examples",
                        getattr(fn, "_shim_max_examples", 20))
            for i in range(n):
                seed = zlib.crc32(f"{fn.__module__}.{fn.__name__}:{i}".encode())
                rng = random.Random(seed)
                drawn = {k: s.example_at(rng, i)
                         for k, s in sorted(strategies.items())}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on example {i}: {drawn!r}"
                    ) from e
        # n examples collapse into one pytest item.  Hide the drawn-argument
        # parameters from pytest's fixture resolution (wraps copies
        # __wrapped__, which inspect.signature would follow otherwise).
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        params = [p for name, p in
                  inspect.signature(fn).parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = inspect.Signature(params)
        wrapper._shim_given = True
        return wrapper
    return deco


def install() -> None:
    if "hypothesis" in sys.modules:
        return
    mod = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("sampled_from", "integers", "floats", "booleans", "tuples",
                 "just"):
        setattr(strat, name, globals()[name])
    mod.given = given
    mod.settings = settings
    mod.strategies = strat
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
