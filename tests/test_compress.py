"""int8 error-feedback gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import compress


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_error_bound(seed, scale):
    x = scale * jax.random.normal(jax.random.key(seed), (256,))
    q, s = compress.quantize_int8(x)
    err = np.abs(np.asarray(compress.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6  # half-ulp rounding


def test_error_feedback_unbiased_over_time():
    """Mean of EF-compressed grads converges to the true mean direction."""
    g = jax.random.normal(jax.random.key(0), (128,))
    err = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    n = 50
    for _ in range(n):
        q, s, err = compress.ef_quantize(g, err)
        acc = acc + compress.dequantize_int8(q, s)
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g),
                               rtol=0, atol=float(jnp.max(jnp.abs(g))) / 100)


def test_simulated_allreduce_matches_mean():
    grads = [{"w": jax.random.normal(jax.random.key(i), (64,))}
             for i in range(4)]
    errs = [compress.tree_ef_init(g) for g in grads]
    mean, new_errs = compress.simulate_workers(grads, errs)
    want = sum(np.asarray(g["w"]) for g in grads) / 4
    got = np.asarray(mean["w"])
    tol = max(float(np.abs(np.asarray(g["w"])).max()) for g in grads) / 100
    np.testing.assert_allclose(got, want, atol=tol)
    # error feedback captured the residual
    for g, e, in zip(grads, new_errs):
        assert float(jnp.max(jnp.abs(e["w"]))) > 0


def test_wire_bytes_4x():
    t = {"a": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    assert compress.wire_bytes(t, compressed=False) == \
        4 * compress.wire_bytes(t, compressed=True)
