"""Synthetic corpus: determinism, split disjointness, resume, host sharding."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.configs.base import ModelConfig
from repro.data.synthetic import (CorpusConfig, DataCursor, ShardedLoader,
                                  batches_for, sample_tokens)

CFG = ModelConfig(name="t", family="dense", d_model=32, num_layers=1,
                  num_heads=1, num_kv_heads=1, head_dim=32, d_ff=64,
                  vocab_size=512)


def test_deterministic():
    c = CorpusConfig(512, seed=3)
    a = sample_tokens(c, "train", 5, 4, 64)
    b = sample_tokens(c, "train", 5, 4, 64)
    np.testing.assert_array_equal(a, b)


def test_splits_and_indices_differ():
    c = CorpusConfig(512, seed=3)
    a = sample_tokens(c, "train", 0, 4, 64)
    b = sample_tokens(c, "valid", 0, 4, 64)
    d = sample_tokens(c, "train", 1, 4, 64)
    assert not np.array_equal(a, b)
    assert not np.array_equal(a, d)


def test_learnable_structure_present():
    """The successor rule fires ~p_succ of the time (learnability)."""
    c = CorpusConfig(512, seed=0)
    toks = sample_tokens(c, "train", 0, 8, 256).astype(np.int64)
    from repro.data.synthetic import _succ_params
    a, b = _succ_params(512, 0)
    succ_hits = (toks[:, 1:] == (a * toks[:, :-1] + b) % 512).mean()
    assert 0.4 < succ_hits < 0.75, succ_hits


def test_loader_resume_equivalence():
    l1 = ShardedLoader(CFG, global_batch=4, seq=32)
    batches = [next(l1) for _ in range(5)]
    l2 = ShardedLoader(CFG, global_batch=4, seq=32,
                       cursor=DataCursor(index=3))
    np.testing.assert_array_equal(batches[3]["tokens"],
                                  next(l2)["tokens"])


@settings(max_examples=10, deadline=None)
@given(num_hosts=st.sampled_from([1, 2, 4]))
def test_host_shards_partition_global_batch(num_hosts):
    full = ShardedLoader(CFG, global_batch=8, seq=16)
    want = next(full)["tokens"]
    parts = []
    for h in range(num_hosts):
        l = ShardedLoader(CFG, global_batch=8, seq=16, host_id=h,
                          num_hosts=num_hosts)
        parts.append(next(l)["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), want)


def test_family_batches_have_stub_inputs():
    import dataclasses
    audio = dataclasses.replace(CFG, family="audio")
    vlm = dataclasses.replace(CFG, family="vlm", vit_dim=16,
                              num_image_tokens=4)
    b = batches_for(audio, n=1, batch=2, seq=16, split="calib")[0]
    assert b["frames"].shape == (2, 16, 32)
    b = batches_for(vlm, n=1, batch=2, seq=16, split="calib")[0]
    assert b["patches"].shape == (2, 4, 16)
