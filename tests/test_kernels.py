"""Pallas kernels vs pure-jnp oracles (interpret mode) with hypothesis
shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.prox import prox_nm24, prox_nm24_ref
from repro.kernels import ref
from repro.kernels.nm_prox import nm_mask24, prox24
from repro.kernels.nm_spmm import nm_matmul
from repro.kernels.saliency_fuse import saliency_fused_step

SHAPES = st.sampled_from([(64, 128), (128, 128), (256, 384), (64, 256)])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


@settings(max_examples=8, deadline=None)
@given(kn=SHAPES, dtype=DTYPES, seed=st.integers(0, 10_000))
def test_nm_matmul_matches_ref(kn, dtype, seed):
    K, N = kn
    M = 32
    w = jax.random.normal(jax.random.key(seed), (K, N), jnp.float32)
    vals, idx = ref.compress_24(w)
    vals = vals.astype(dtype)
    x = (0.1 * jax.random.normal(jax.random.key(seed + 1), (M, K),
                                 jnp.float32)).astype(dtype)
    y = nm_matmul(x, vals, idx, bm=32, bk=64, bn=128, interpret=True)
    yr = ref.nm_matmul_ref(x, vals, idx)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(y.astype(jnp.float32),
                               yr.astype(jnp.float32), rtol=rtol, atol=rtol)


@settings(max_examples=8, deadline=None)
@given(kn=SHAPES, dtype=DTYPES, seed=st.integers(0, 10_000))
def test_nm_matmul_packed2_bit_exact_vs_int8(kn, dtype, seed):
    """Kernel-native 2-bit-packed index tiles (unpacked in VMEM after the
    copy) must match the int8 index plane bit-for-bit across TPU-shaped
    tilings (grid > 1 in every dim) in interpret mode."""
    from repro.sparse.formats import _pack_idx2
    K, N = kn
    M = 32
    w = jax.random.normal(jax.random.key(seed), (K, N), jnp.float32)
    vals, idx = ref.compress_24(w)
    vals = vals.astype(dtype)
    packed = _pack_idx2(idx)
    x = (0.1 * jax.random.normal(jax.random.key(seed + 1), (M, K),
                                 jnp.float32)).astype(dtype)
    y8 = nm_matmul(x, vals, idx, bm=16, bk=32, bn=128, layout="int8",
                   interpret=True)
    y2 = nm_matmul(x, vals, packed, bm=16, bk=32, bn=128, layout="packed2",
                   interpret=True)
    np.testing.assert_array_equal(np.asarray(y8), np.asarray(y2))
    # and layout inference from the index-plane shape picks the same path
    y2i = nm_matmul(x, vals, packed, bm=16, bk=32, bn=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y2i))


def test_nm_matmul_packed2_matches_masked_dense_single_tile():
    """Interpret-mode single tile (the CPU serving configuration) stays
    bit-exact vs the masked-dense fp32 dot."""
    from repro.sparse.formats import _pack_idx2
    K, N, M = 64, 48, 4
    w = jax.random.normal(jax.random.key(11), (K, N), jnp.float32)
    m = ref.nm_mask_ref(w)
    vals, idx = ref.compress_24(w * m)
    x = 0.1 * jax.random.normal(jax.random.key(12), (M, K), jnp.float32)
    y = nm_matmul(x, vals, _pack_idx2(idx), bm=M, bk=K, bn=N,
                  layout="packed2", interpret=True)
    want = jnp.dot(x, w * m, preferred_element_type=jnp.float32)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(want))


def test_compress_roundtrip_preserves_24_weights():
    w = jax.random.normal(jax.random.key(0), (128, 64))
    m = ref.nm_mask_ref(w)
    w24 = w * m
    vals, idx = ref.compress_24(w24)
    np.testing.assert_allclose(ref.decompress_24(vals, idx), w24, rtol=1e-6)


def test_compressed_bytes_ratio():
    K, N = 1024, 1024
    dense_bytes = K * N * 2                      # bf16
    comp_bytes = (K // 2) * N * 2 + (K // 2) * N  # bf16 vals + int8 idx
    assert comp_bytes / dense_bytes == 0.75
    packed = (K // 2) * N * 2 + (K // 2) * N // 4  # 2-bit packed idx
    assert packed / dense_bytes == 0.5625


@settings(max_examples=6, deadline=None)
@given(kn=SHAPES, metric=st.sampled_from(["wanda", "ria", "magnitude"]),
       seed=st.integers(0, 1000))
def test_saliency_fuse_matches_ref(kn, metric, seed):
    K, N = kn
    key = jax.random.key(seed)
    w = jax.random.normal(key, (K, N))
    a = jnp.abs(jax.random.normal(jax.random.key(seed + 1), (K,))) * 5
    g = 0.1 * jax.random.normal(jax.random.key(seed + 2), (K, N))
    v = 0.1 * jax.random.normal(jax.random.key(seed + 3), (K, N))
    rows = jnp.sum(jnp.abs(w), 1)
    cols = jnp.sum(jnp.abs(w), 0)
    kw = dict(rowsum=rows, colsum=cols) if metric == "ria" else {}
    v2, g2 = saliency_fused_step(w, a, g, v, metric=metric, interpret=True,
                                 bk=64, bn=128, **kw)
    if metric == "wanda":
        vr, gr = ref.saliency_step_ref(w, a, g, v, v_lr=0.1, lam=1e-3)
    elif metric == "magnitude":
        vr, gr = ref.saliency_step_ref(w, jnp.ones_like(a), g, v, v_lr=0.1,
                                       lam=1e-3)
    else:
        vr, gr = ref.saliency_step_ref(w, a, g, v, v_lr=0.1, lam=1e-3,
                                       rowsum=rows[:, None],
                                       colsum=cols[None, :])
    np.testing.assert_allclose(v2, vr, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(g2, gr, rtol=1e-5, atol=1e-6)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), lam=st.sampled_from([0.0, 0.01, 0.05, 0.5]))
def test_prox24_kernel_matches_core(seed, lam):
    w = jax.random.normal(jax.random.key(seed), (64, 128))
    p1 = prox24(w, lam=lam, interpret=True, bk=32, bn=128)
    p2 = prox_nm24(w, lam)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)


def test_prox24_against_bruteforce_oracle():
    w = jax.random.normal(jax.random.key(7), (16, 8))
    np.testing.assert_allclose(prox_nm24(w, 0.05), prox_nm24_ref(w, 0.05),
                               rtol=1e-3, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), ties=st.booleans())
def test_nm_mask24_kernel_matches_ref(seed, ties):
    w = jax.random.normal(jax.random.key(seed), (64, 128))
    if ties:
        w = jnp.round(w * 2) / 2
    m1 = nm_mask24(w, interpret=True, bk=32, bn=128)
    m2 = ref.nm_mask_ref(w)
    assert bool(jnp.all(m1 == m2))
    assert bool(jnp.all(m1.reshape(16, 4, 128).sum(1) == 2))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), lam=st.floats(0.0, 1.0))
def test_prox24_properties(seed, lam):
    """Shrinkage (|out| <= |w|), sign preservation, lam=0 identity."""
    w = jax.random.normal(jax.random.key(seed), (32, 16))
    out = prox_nm24(w, lam)
    assert bool(jnp.all(jnp.abs(out) <= jnp.abs(w) + 1e-6))
    nz = jnp.abs(out) > 0
    assert bool(jnp.all(jnp.where(nz, jnp.sign(out) == jnp.sign(w), True)))
    if lam == 0.0:
        np.testing.assert_allclose(out, w, rtol=1e-6)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000),
       dims=st.sampled_from([(2, 2, 4, 32, 128), (1, 1, 8, 64, 256),
                             (2, 4, 1, 32, 64)]))
def test_flash_decode_matches_ref(seed, dims):
    from repro.kernels.flash_decode import flash_decode, flash_decode_ref
    B, K, G, D, C = dims
    q = 0.5 * jax.random.normal(jax.random.key(seed), (B, K, G, D))
    k = 0.5 * jax.random.normal(jax.random.key(seed + 1), (B, C, K, D))
    v = 0.5 * jax.random.normal(jax.random.key(seed + 2), (B, C, K, D))
    valid = jax.random.randint(jax.random.key(seed + 3), (), C // 2, C + 1)
    bias = jnp.where(jnp.arange(C)[None, :] < valid, 0.0, -1e30) * \
        jnp.ones((B, 1))
    y = flash_decode(q, k, v, bias, bc=32, interpret=True)
    yr = flash_decode_ref(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=2e-4,
                               atol=2e-5)


def test_ops_sparse_dense_roundtrip():
    from repro.kernels import ops
    w = jax.random.normal(jax.random.key(0), (128, 64))
    m = ref.nm_mask_ref(w)
    packed = ops.compress_leaf(w * m)
    x = 0.1 * jax.random.normal(jax.random.key(1), (8, 128))
    y = ops.sparse_dense(x, packed)
    np.testing.assert_allclose(
        np.asarray(y, np.float32),
        np.asarray(x @ (w * m).astype(jnp.bfloat16), np.float32),
        rtol=3e-2, atol=3e-3)
