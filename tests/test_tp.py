"""Tensor-parallel sparse serving: K-shard tags, partial-softmax combine,
and token parity of the shard-mapped engine against the replicated oracle.

Multi-device cases run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (XLA fixes the host
device count at jax import); spec/tag logic and the flash-partial combine
algebra run in-process on one device.
"""
import os
import pathlib
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh

from repro.dist import sharding as shd
from repro.dist.axes import make_rules, use_rules


def _run_forced_4dev(code: str) -> None:
    """Run ``code`` under 4 forced host devices; assert it prints 'ok'."""
    prelude = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
    """)
    env = {**os.environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c",
                        prelude + textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=str(pathlib.Path(__file__).parent.parent),
                       timeout=1200)
    assert r.returncode == 0 and "ok" in r.stdout, (r.stdout, r.stderr)


# ---------------------------------------------------------------------------
# Tag derivation (pure spec logic, abstract meshes)
# ---------------------------------------------------------------------------

def _pack(key, shape, idx_bits=2):
    from repro.kernels import ref as kref
    from repro.sparse import pack
    w = jax.random.normal(jax.random.key(key), shape, jnp.float32)
    if len(shape) == 2:
        mask = kref.nm_mask_ref(w)
    else:
        mask = jnp.stack([kref.nm_mask_ref(w[i]) for i in range(shape[0])])
    return pack.pack_nm(w, mask, idx_bits=idx_bits)


def test_tag_compressed_stamps_site_and_k_axis():
    """A K-shardable leaf gets (site, *entries) with the K mesh axis at
    [-2]; the site comes from the leaf path; an unshardable leaf keeps
    shard=None and passes through by identity (no spurious retrace)."""
    rules = make_rules(AbstractMesh((("data", 2), ("model", 2))))
    good = _pack(0, (64, 64))           # K=64 % (8*2) == 0 on either axis
    bad = _pack(1, (8, 64))             # K=8: no K shard possible
    tree = {"mlp": {"down": {"kernel": good}},
            "attn": {"wo": {"kernel": bad}}}
    axes = {"mlp": {"down": {"kernel": "mlp|embed"}},
            "attn": {"wo": {"kernel": "qkv|embed"}}}
    out = shd.tag_compressed(axes, tree, rules)
    tag = out["mlp"]["down"]["kernel"].shard
    assert tag == ("mlp", "model", "data")
    assert out["mlp"]["down"]["kernel"].k_shard == "model"
    assert out["mlp"]["down"]["kernel"].shard_site == "mlp"
    # no warning from the quiet pass, leaf untouched by identity
    assert out["attn"]["wo"]["kernel"] is bad
    assert out["attn"]["wo"]["kernel"].shard is None


def test_tag_compressed_strips_scanned_layers_axis():
    """Scan-stacked leaves (layers, K, N): the tag covers the *executed*
    dims only - lax.scan slices the layers axis away before dispatch, so a
    layers entry in the tag would misalign every executed-dim lookup."""
    rules = make_rules(AbstractMesh((("data", 2), ("model", 2))))
    st = _pack(2, (3, 64, 64))
    out = shd.tag_compressed({"kernel": "layers|embed|mlp"},
                             {"kernel": st}, rules)
    tag = out["kernel"].shard
    assert tag is not None and len(tag) == 3    # (site, k, n): no layers
    assert out["kernel"].k_shard == "data"      # embed -> data


def test_tag_survives_tree_flatten_and_device_put_roundtrip():
    """The tag is static pytree aux: flatten/unflatten preserves it, and
    params_sharding mirrors the input leaf's aux verbatim so a tagged tree
    device_puts against its own sharding tree (treedefs must match)."""
    rules = make_rules(AbstractMesh((("data", 2), ("model", 2))))
    st = _pack(3, (64, 64))
    tagged = shd.tag_compressed({"kernel": "mlp|embed"}, {"kernel": st},
                                rules)["kernel"]
    leaves, treedef = jax.tree_util.tree_flatten(tagged)
    rt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rt.shard == tagged.shard
    sh = shd.sparse_leaf_sharding("mlp|embed", tagged, rules)
    assert (jax.tree_util.tree_structure(sh)
            == jax.tree_util.tree_structure(tagged))


def test_k_sharded_gates_on_rules_tag_and_env(monkeypatch):
    """Dispatch routes shard-mapped only when a tag is present AND rules
    are installed; REPRO_FORCE_REPLICATED kills the route everywhere."""
    from repro.kernels import shard as ksh
    st = _pack(4, (64, 64))
    tagged = st.with_shard(("mlp", "model", None))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert not ksh.k_sharded(tagged)            # no rules installed
    with use_rules(make_rules(mesh)):
        assert ksh.k_sharded(tagged)
        assert not ksh.k_sharded(st)            # untagged leaf
        assert ksh.pair_k_sharded(tagged, tagged)
        other = st.with_shard(("mlp", "data", None))
        assert not ksh.pair_k_sharded(tagged, other)   # different K axes
        monkeypatch.setenv(ksh.FORCE_REPLICATED_ENV, "1")
        assert not ksh.k_sharded(tagged)


def test_divisibility_fallback_is_all_or_nothing_and_loud():
    """K % (group * devices) != 0: BOTH components replicate along K (a
    vals-only K shard feeds no kernel) and the structured warning names the
    leaf path; byte-padded packed planes (K % 8 != 0) never qualify."""
    rules = make_rules(AbstractMesh((("data", 1), ("model", 4))))
    st = _pack(5, (72, 128))            # 72 % 8 == 0 but 72 % 32 != 0
    from jax.sharding import PartitionSpec as P
    with pytest.warns(UserWarning, match="cannot shard over mesh axis"):
        out = shd.params_sharding({"kernel": "mlp|embed"}, {"kernel": st},
                                  rules)
    assert out["kernel"].vals.spec == P(None, "data")   # K replicated
    assert out["kernel"].idx.spec == P(None, "data")
    tagged = shd.tag_compressed({"kernel": "mlp|embed"}, {"kernel": st},
                                rules)["kernel"]
    assert tagged.shard is None


# ---------------------------------------------------------------------------
# Flash-partial combine algebra (single device)
# ---------------------------------------------------------------------------

def test_flash_partial_shard_combine_matches_full_softmax():
    """Splitting the capacity into shards, running the partial oracle per
    shard, and combining with the pmax/psum recipe the shard_map uses
    (corr = exp(m - m_global), one rescaled (l, acc) sum) reproduces the
    full-capacity softmax - including a fully-masked shard, whose m=-1e30
    makes its correction exactly zero."""
    from repro.kernels.flash_decode import (flash_decode_partial_ref,
                                            flash_decode_ref)
    B, C, K, G, D = 2, 32, 2, 2, 8
    kq, kk, kv = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(kq, (B, K, G, D), jnp.float32)
    k = jax.random.normal(kk, (B, C, K, D), jnp.float32)
    v = jax.random.normal(kv, (B, C, K, D), jnp.float32)
    bias = jnp.zeros((B, C), jnp.float32)
    # mask the whole last quarter: shard 3 becomes all-masked
    bias = bias.at[:, 24:].set(-1e30)
    want = flash_decode_ref(q, k, v, bias)

    parts = [flash_decode_partial_ref(q, k[:, s:s + 8], v[:, s:s + 8],
                                      bias[:, s:s + 8])
             for s in range(0, C, 8)]
    mg = parts[0][1]
    for _, m, _ in parts[1:]:
        mg = jnp.maximum(mg, m)
    l_tot = sum(l * jnp.exp(m - mg) for _, m, l in parts)
    acc_tot = sum(acc * jnp.exp(m - mg) for acc, m, _ in parts)
    got = acc_tot / jnp.maximum(l_tot, 1e-30)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_partial_ref_all_masked_shard_contributes_zero():
    """An entirely-masked shard flushes finite garbage (p = exp(0) once m
    clamps at -1e30) - what protects the combine is the flushed m itself:
    against any shard holding one real slot, corr = exp(-1e30 - m_global)
    is exactly 0, so the garbage partial is annihilated, not psummed."""
    from repro.kernels.flash_decode import flash_decode_partial_ref
    q = jnp.ones((1, 1, 2, 4), jnp.float32)
    k = jnp.ones((1, 8, 1, 4), jnp.float32)
    v = jnp.ones((1, 8, 1, 4), jnp.float32)
    bias = jnp.full((1, 8), -1e30, jnp.float32)
    acc, m, l = flash_decode_partial_ref(q, k, v, bias)
    assert np.isfinite(np.asarray(acc)).all()
    np.testing.assert_allclose(np.asarray(m), -1e30)
    live_m = jnp.zeros_like(m)          # any shard with a real slot
    corr = jnp.exp(m - jnp.maximum(m, live_m))
    np.testing.assert_allclose(np.asarray(corr), 0.0)


def test_infer_layout_is_shard_local():
    """Layout inference works from local shapes alone: the vals/idx row
    ratio (4:1 packed, 1:1 int8) is invariant under K sharding."""
    from repro.kernels.nm_spmm import infer_layout
    assert infer_layout(64, (8, 64)) == infer_layout(16, (2, 64))
    assert infer_layout(64, (32, 64)) == infer_layout(16, (8, 64))


# ---------------------------------------------------------------------------
# End-to-end token parity on a forced 4-device host mesh (subprocess)
# ---------------------------------------------------------------------------

_SPARSE_SETUP = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import get_smoke_config
    from repro.core import masks as masks_mod, metrics as metrics_mod
    from repro.core.prunable import prunable_map
    from repro.dist.axes import make_rules
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    from repro.sparse import apply as apply_mod

    def sparse_smoke(arch, cfg=None):
        cfg = cfg or get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.key(0))
        pr = prunable_map(params)
        scores = metrics_mod.metric_tree(
            "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
        masks = masks_mod.nm_masks(scores)
        sparse = apply_mod.sparsify_params(
            params, masks, axes=M.param_axes(cfg), idx_bits=2,
            dtype=jnp.bfloat16)
        return cfg, sparse

    def serve(cfg, sparse, rules, prompts, n=6, slots=2, capacity=32):
        eng = ServeEngine(cfg, sparse, slots=slots, capacity=capacity,
                          rules=rules)
        rids = [eng.submit(p, n) for p in prompts]
        out = eng.run()
        return [out[r] for r in rids]
"""


def test_tp_token_parity_llama_4dev():
    """K-sharded 2:4 llama-smoke engine decodes token-identically to the
    replicated oracle on (1, 4) (K over "model": wo + down shard) and
    (2, 2) ("data" K-shards qkv and the fused up/gate pair too) meshes;
    REPRO_FORCE_REPLICATED=1 under the same rules also holds parity."""
    _run_forced_4dev(_SPARSE_SETUP + """
    cfg, sparse = sparse_smoke("llama3.2-1b")
    prompts = [np.arange(1, 9) % cfg.vocab_size,
               (np.arange(3, 13) * 7) % cfg.vocab_size]
    want = serve(cfg, sparse, None, prompts)
    for shape in [(1, 4), (2, 2)]:
        mesh = jax.make_mesh(shape, ("data", "model"))
        got = serve(cfg, sparse, make_rules(mesh), prompts)
        assert got == want, (shape, got, want)
    import os
    os.environ["REPRO_FORCE_REPLICATED"] = "1"
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    got = serve(cfg, sparse, make_rules(mesh), prompts)
    assert got == want, ("forced-replicated", got, want)
    print("ok")
    """)


def test_tp_psum_counters_static_per_decode_trace():
    """The collective counters advance at trace time, so the per-decode
    static invariant is directly assertable: on (2, 2) one decode trace
    costs mlp=2 psums (ONE for the fused up/gate pair + one for down),
    attn=4 (q/k/v/o), attn_kv=2 (CPU exact-mimic softmax combine); a second
    decode with the same shapes adds zero (no retrace, no extra
    collectives)."""
    _run_forced_4dev(_SPARSE_SETUP + """
    from repro import obs
    obs.configure(enabled=True)
    cfg, sparse = sparse_smoke("llama3.2-1b")
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    eng = ServeEngine(cfg, sparse, slots=2, capacity=32,
                      rules=make_rules(mesh))
    toks = jnp.zeros((2,), jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    sites = ("mlp", "attn", "attn_kv", "moe")
    def snap():
        return {s: obs.counter_value("dist.psum", site=s) for s in sites}
    c0 = snap()
    logits, caches = eng._decode(eng.params, toks, eng.caches, pos)
    jax.block_until_ready(logits)
    c1 = snap()
    delta = {s: c1[s] - c0[s] for s in sites}
    assert delta == {"mlp": 2, "attn": 4, "attn_kv": 2, "moe": 0}, delta
    logits, _ = eng._decode(eng.params, toks, caches, pos + 1)
    jax.block_until_ready(logits)
    c2 = snap()
    assert c2 == c1, (c1, c2)
    assert obs.counter_value("dist.psum_bytes", site="mlp") > 0
    assert "dist.psum" in str(obs.summary())
    print("ok")
    """)


def test_tp_padding_edge_replicates_loudly_and_holds_parity():
    """d_ff=72: the packed plane exists (72 % 8 == 0) but 72 % (8*4) != 0,
    so the down kernels cannot K-shard over model=4 - construction warns
    with the leaf path, BOTH components replicate, and the engine still
    matches the replicated oracle token-for-token (the shardable leaves
    keep their shard-mapped route)."""
    _run_forced_4dev(_SPARSE_SETUP + """
    import dataclasses, warnings
    cfg = dataclasses.replace(get_smoke_config("llama3.2-1b"), d_ff=72)
    cfg, sparse = sparse_smoke(None, cfg=cfg)
    prompts = [np.arange(1, 9) % cfg.vocab_size]
    want = serve(cfg, sparse, None, prompts)
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        got = serve(cfg, sparse, make_rules(mesh), prompts)
    assert any("cannot shard over mesh axis" in str(w.message) for w in rec)
    assert got == want, (got, want)
    print("ok")
    """)


def test_tp_token_parity_moe_expert_banks_4dev():
    """mixtral-smoke expert banks (E, K, N): the down bank K-shards over
    "model" on (1, 4) (one psum for the whole expert grid) and the up/gate
    banks pair-fuse over "data" on (2, 2); both meshes hold token parity
    with the replicated oracle through sliding-window decode."""
    _run_forced_4dev(_SPARSE_SETUP + """
    from repro.dist import sharding as shd
    cfg, sparse = sparse_smoke("mixtral-8x22b")
    mesh = jax.make_mesh((1, 4), ("data", "model"))
    rules = make_rules(mesh)
    tagged = shd.tag_compressed(M.param_axes(cfg), sparse, rules)
    down = None
    def find(kp, leaf):
        global down
        from repro.sparse.formats import SparseTensor
        path = jax.tree_util.keystr(kp)
        if isinstance(leaf, SparseTensor) and "moe" in path \\
                and "down" in path:
            down = leaf
    jax.tree_util.tree_map_with_path(
        find, tagged,
        is_leaf=lambda x: getattr(x, "idx_bits", None) is not None)
    assert down is not None and down.shard is not None, "down bank untagged"
    assert down.shard_site == "moe" and down.k_shard == "model", down.shard
    prompts = [np.arange(1, 9) % cfg.vocab_size,
               (np.arange(2, 10) * 5) % cfg.vocab_size]
    want = serve(cfg, sparse, None, prompts)
    for shape in [(1, 4), (2, 2)]:
        mesh = jax.make_mesh(shape, ("data", "model"))
        got = serve(cfg, sparse, make_rules(mesh), prompts)
        assert got == want, (shape, got, want)
    print("ok")
    """)
