"""Sharding-rule derivation (no multi-device needed: pure spec logic)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, SHAPE_CELLS
from repro.dist import sharding as shd
from repro.dist.axes import ShardingRules, make_rules


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_dedupes_repeated_mesh_axes(mesh):
    rules = ShardingRules(mesh=mesh, rules={"a": "model", "b": "model"})
    spec = rules.spec(["a", "b"])
    assert spec == P("model", None)


def test_params_sharding_divisibility_fallback(mesh):
    rules = make_rules(mesh)
    # 3 not divisible by model axis of a >1 mesh; with size-1 axes all pass,
    # so emulate via a fake shape check on the spec helper
    axes = {"k": "embed|mlp"}
    shapes = {"k": jax.ShapeDtypeStruct((6, 4), jnp.float32)}
    out = shd.params_sharding(axes, shapes, rules)
    assert out["k"].spec == P("data", "model")


def test_make_rules_seq_parallel_toggle(mesh):
    r1 = make_rules(mesh, seq_parallel=False)
    r2 = make_rules(mesh, seq_parallel=True)
    assert r1.rules["act_seq"] is None
    assert r2.rules["act_seq"] == "model"


def test_cache_sharding_layouts(mesh):
    cs = {
        "0": {"k": jax.ShapeDtypeStruct((4, 8, 4096, 2, 64), jnp.bfloat16),
              "v": jax.ShapeDtypeStruct((4, 8, 4096, 2, 64), jnp.bfloat16)},
    }
    out = shd.cache_sharding(cs, mesh)
    spec = out["0"]["k"].spec
    assert spec[0] is None              # layers axis never sharded
    assert spec[1] in ("data", ("data",))  # batch over dp
    assert spec[2] == "model"           # capacity TP (partial softmax)
    # long-context batch=1 -> seq sharded over every divisible axis
    cs2 = {"0": {"k": jax.ShapeDtypeStruct((4, 1, 8192, 2, 64),
                                           jnp.bfloat16)}}
    out2 = shd.cache_sharding(cs2, mesh)
    assert out2["0"]["k"].spec[2] is not None


def test_all_full_configs_have_valid_stages():
    from repro.models import model as M
    for arch in ["yi-6b", "mixtral-8x22b", "zamba2-7b", "gemma3-1b",
                 "deepseek-v2-lite-16b"]:
        cfg = get_config(arch)
        total = sum(len(p) * r for p, r in M.make_stages(cfg))
        assert total == cfg.num_layers


def test_param_axes_structure_matches_params():
    from repro.configs.base import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config("llama3.2-1b")
    shapes = M.param_shapes(cfg)
    axes = M.param_axes(cfg)
    sf = jax.tree_util.tree_structure(shapes)
    af = jax.tree_util.tree_structure(axes)
    assert sf == af
    for s, a in zip(jax.tree.leaves(shapes), jax.tree.leaves(axes)):
        assert len(a.split("|")) == len(s.shape), (a, s.shape)
