"""Sharding-rule derivation (no multi-device needed: pure spec logic)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, SHAPE_CELLS
from repro.dist import sharding as shd
from repro.dist.axes import ShardingRules, make_rules


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_dedupes_repeated_mesh_axes(mesh):
    rules = ShardingRules(mesh=mesh, rules={"a": "model", "b": "model"})
    spec = rules.spec(["a", "b"])
    assert spec == P("model", None)


def test_params_sharding_divisibility_fallback(mesh):
    rules = make_rules(mesh)
    # 3 not divisible by model axis of a >1 mesh; with size-1 axes all pass,
    # so emulate via a fake shape check on the spec helper
    axes = {"k": "embed|mlp"}
    shapes = {"k": jax.ShapeDtypeStruct((6, 4), jnp.float32)}
    out = shd.params_sharding(axes, shapes, rules)
    assert out["k"].spec == P("data", "model")


def test_make_rules_seq_parallel_toggle(mesh):
    r1 = make_rules(mesh, seq_parallel=False)
    r2 = make_rules(mesh, seq_parallel=True)
    assert r1.rules["act_seq"] is None
    assert r2.rules["act_seq"] == "model"


def test_cache_sharding_layouts(mesh):
    cs = {
        "0": {"k": jax.ShapeDtypeStruct((4, 8, 4096, 2, 64), jnp.bfloat16),
              "v": jax.ShapeDtypeStruct((4, 8, 4096, 2, 64), jnp.bfloat16)},
    }
    out = shd.cache_sharding(cs, mesh)
    spec = out["0"]["k"].spec
    assert spec[0] is None              # layers axis never sharded
    assert spec[1] in ("data", ("data",))  # batch over dp
    assert spec[2] == "model"           # capacity TP (partial softmax)
    # long-context batch=1 -> seq sharded over every divisible axis
    cs2 = {"0": {"k": jax.ShapeDtypeStruct((4, 1, 8192, 2, 64),
                                           jnp.bfloat16)}}
    out2 = shd.cache_sharding(cs2, mesh)
    assert out2["0"]["k"].spec[2] is not None


@pytest.fixture(scope="module")
def mesh22():
    """2x2 multi-device mesh (abstract: spec derivation is pure logic, the
    divisibility checks see real axis sizes > 1)."""
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", 2), ("model", 2)))


def test_params_sharding_sparse_leaves_2d_mesh(mesh22):
    """SparseTensor components inherit the dense kernel's (K, N) axes:
    vals/idx take the N sharding; the K sharding survives the halved (vals)
    and packed-eighthed (idx) dims exactly when they still divide."""
    from repro.kernels import ref as kref
    from repro.sparse import pack
    rules = make_rules(mesh22)
    w = jax.random.normal(jax.random.key(0), (64, 64), jnp.float32)
    st = pack.pack_nm(w, kref.nm_mask_ref(w), idx_bits=2)
    out = shd.params_sharding({"kernel": "embed|mlp"}, {"kernel": st}, rules)
    sh = out["kernel"]
    assert sh.vals.spec == P("data", "model")   # (32, 64): K/2 divides dp=2
    assert sh.idx.spec == P("data", "model")    # (8, 64): K/8 divides dp=2
    assert sh.idx_bits == 2                     # tree node mirrors the leaf


def test_params_sharding_sparse_idx_divisibility_fallback(mesh22):
    """K = 8 cannot K-shard over data=2 for a packed2 plane (needs K % 16
    == 0): BOTH planes replicate along K (all-or-nothing - a vals-only K
    shard could never feed the shard-local kernel) and a structured warning
    names the leaf; the N sharding survives."""
    from repro.kernels import ref as kref
    from repro.sparse import pack
    rules = make_rules(mesh22)
    w = jax.random.normal(jax.random.key(1), (8, 64), jnp.float32)
    st = pack.pack_nm(w, kref.nm_mask_ref(w), idx_bits=2)
    with pytest.warns(UserWarning, match="cannot shard over mesh axis"):
        out = shd.params_sharding({"kernel": "embed|mlp"}, {"kernel": st},
                                  rules)
    assert out["kernel"].vals.spec == P(None, "model")
    assert out["kernel"].idx.spec == P(None, "model")


def test_params_sharding_stacked_sparse_and_bitmask(mesh22):
    """Scan-stacked compressed leaves keep the unsharded layers axis;
    BitMask buffers (flat bytes, no meaningful axis) replicate."""
    from repro.kernels import ref as kref
    from repro.sparse import pack
    from repro.sparse.formats import BitMask
    rules = make_rules(mesh22)
    w = jax.random.normal(jax.random.key(2), (3, 64, 64), jnp.float32)
    mask = jnp.stack([kref.nm_mask_ref(w[i]) for i in range(3)])
    st = pack.pack_nm(w, mask, idx_bits=2)
    bm = BitMask.pack(mask[0])
    out = shd.params_sharding({"kernel": "layers|embed|mlp", "mask": None},
                              {"kernel": st, "mask": bm}, rules)
    assert out["kernel"].vals.spec == P(None, "data", "model")
    assert out["kernel"].idx.spec == P(None, "data", "model")
    assert out["mask"].bits.spec == P()


def test_params_sharding_expert_bank_leaves(mesh22):
    """Expert-banked compressed leaves (layers, E, K, N): the leading expert
    axis maps to the "experts" logical axis (-> "model"), and the (K, N)
    component rules apply per expert - vals K/2 and idx K/8 keep their
    sharding when they still divide, with the usual fallback."""
    from repro.kernels import ref as kref
    from repro.sparse import pack
    rules = make_rules(mesh22)
    w = jax.random.normal(jax.random.key(3), (2, 2, 32, 64), jnp.float32)
    mask = jnp.stack([jnp.stack([kref.nm_mask_ref(w[l, e])
                                 for e in range(2)]) for l in range(2)])
    st = pack.pack_nm(w, mask, idx_bits=2)
    # expert-parallel bank (deepseek-style): experts -> model, so the
    # per-expert N dim ("mlp" -> model too) falls back to replicated
    out = shd.params_sharding({"kernel": "layers|experts|embed|mlp"},
                              {"kernel": st}, rules)
    assert out["kernel"].vals.spec == P(None, "model", "data", None)
    assert out["kernel"].idx.spec == P(None, "model", "data", None)
    # tensor-parallel bank (mixtral-style, expert axis unsharded): the
    # trailing dims keep the plain (K, N) component rules per expert
    out2 = shd.params_sharding({"kernel": "layers||embed|mlp"},
                               {"kernel": st}, rules)
    assert out2["kernel"].vals.spec == P(None, None, "data", "model")
    assert out2["kernel"].idx.spec == P(None, None, "data", "model")


def test_sparse_leaf_device_put_multidevice():
    """End-to-end placement on a real 2x2 mesh (forced host devices in a
    subprocess: XLA device count is fixed at jax import): the compressed
    tree device_puts with the derived shardings, every component lands
    sharded, and the sharded tensor still decompresses exactly."""
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        os.environ["JAX_PLATFORMS"] = "cpu"
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist import sharding as shd
        from repro.dist.axes import make_rules
        from repro.kernels import ref as kref
        from repro.sparse import pack
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        rules = make_rules(mesh)
        w = jax.random.normal(jax.random.key(0), (64, 64), jnp.float32)
        st = pack.pack_nm(w, kref.nm_mask_ref(w), idx_bits=2)
        dense0 = np.asarray(st.to_dense())
        tree = {"kernel": st}
        sh = shd.params_sharding({"kernel": "embed|mlp"}, tree, rules)
        placed = jax.device_put(tree, sh)
        pst = placed["kernel"]
        assert len(pst.vals.addressable_shards) == 4
        assert pst.vals.addressable_shards[0].data.shape == (16, 32)
        assert pst.idx.addressable_shards[0].data.shape == (4, 32)
        np.testing.assert_array_equal(np.asarray(pst.to_dense()), dense0)
        print("ok")
    """)
    env = {**__import__("os").environ, "PYTHONPATH": "src"}
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=str(
                           __import__("pathlib").Path(__file__).parent.parent))
    assert r.returncode == 0 and "ok" in r.stdout, (r.stdout, r.stderr)


def test_all_full_configs_have_valid_stages():
    from repro.models import model as M
    for arch in ["yi-6b", "mixtral-8x22b", "zamba2-7b", "gemma3-1b",
                 "deepseek-v2-lite-16b"]:
        cfg = get_config(arch)
        total = sum(len(p) * r for p, r in M.make_stages(cfg))
        assert total == cfg.num_layers


def test_param_axes_structure_matches_params():
    from repro.configs.base import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config("llama3.2-1b")
    shapes = M.param_shapes(cfg)
    axes = M.param_axes(cfg)
    sf = jax.tree_util.tree_structure(shapes)
    af = jax.tree_util.tree_structure(axes)
    assert sf == af
    for s, a in zip(jax.tree.leaves(shapes), jax.tree.leaves(axes)):
        assert len(a.split("|")) == len(s.shape), (a, s.shape)
