"""End-to-end behaviour: train a tiny LM on the synthetic corpus, run the
full UniPruning pipeline, and check the paper's qualitative claims hold:

* one search yields masks at several sparsity levels (one-shot export),
* UniPruning's global budget stays finite where naive baselines degrade,
* W0 is never modified by the search,
* 2:4 mode produces hardware-valid masks + the compressed kernel format
  reproduces the pruned matmul.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import calibrate, mirror, masks as masks_mod
from repro.data.synthetic import batches_for
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.optim.losses import eval_ppl, lm_loss

CFG = ModelConfig(name="sys", family="dense", d_model=96, num_layers=3,
                  num_heads=4, num_kv_heads=2, head_dim=24, d_ff=256,
                  vocab_size=512)


@pytest.fixture(scope="module")
def trained():
    params = M.init_params(CFG, jax.random.key(0))
    train = batches_for(CFG, n=40, batch=12, seq=96, split="train")
    ocfg = opt.AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=200)
    ostate = opt.adamw_init(params)

    @jax.jit
    def step(params, ostate, batch):
        (l, m), g = jax.value_and_grad(
            lambda p, b: lm_loss(CFG, p, b), has_aux=True)(params, batch)
        params, ostate, _ = opt.adamw_update(ocfg, g, ostate, params)
        return params, ostate, l

    for i in range(200):
        params, ostate, loss = step(params, ostate, train[i % len(train)])
    valid = batches_for(CFG, n=3, batch=12, seq=96, split="valid")
    return params, valid


def test_end_to_end_pruning_quality(trained):
    params, valid = trained
    dense_ppl = eval_ppl(CFG, params, valid)
    assert dense_ppl < 60, dense_ppl  # learned the synthetic structure

    calib = batches_for(CFG, n=8, batch=8, seq=96, split="calib")
    stats = calibrate.collect_stats(CFG, params, calib[:3])

    pcfg = PruneConfig(local_metric="stochria", steps=40)
    pruned, state, hist = calibrate.unipruning_prune(
        CFG, pcfg, params, calib, sparsities=[0.5, 0.6])

    ppl50 = eval_ppl(CFG, pruned[0.5], valid)
    ppl60 = eval_ppl(CFG, pruned[0.6], valid)
    assert np.isfinite(ppl50) and np.isfinite(ppl60)
    assert dense_ppl <= ppl50 <= ppl60 * 1.05  # monotone degradation
    assert ppl60 < 40 * dense_ppl              # no collapse at 60%

    # magnitude baseline degrades at least as much at 60%
    mb = calibrate.baseline_masks("magnitude", params, stats, 0.6)
    mag_ppl = eval_ppl(CFG, masks_mod.apply_masks(params, mb), valid)
    assert ppl60 <= mag_ppl * 1.10, (ppl60, mag_ppl)

    # exact budgets
    m60 = mirror.export_masks(pcfg, state.Gamma, 0.6, V=state.V)
    assert abs(masks_mod.sparsity_of(m60) - 0.6) < 0.01


def test_nm_pipeline_and_kernel_consistency(trained):
    params, valid = trained
    calib = batches_for(CFG, n=6, batch=8, seq=96, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=25)
    pruned, state, _ = calibrate.unipruning_prune(
        CFG, pcfg, params, calib, sparsities=[0.5])
    masks = mirror.export_masks(pcfg, state.Gamma, 0.5, V=state.V)
    sp = masks_mod.sparsity_of(masks)
    assert abs(sp - 0.5) < 1e-6
    ppl = eval_ppl(CFG, pruned[0.5], valid)
    assert np.isfinite(ppl)

    # 2:4-compressed kernel format reproduces the pruned dense matmul
    from repro.kernels import ref as kref
    from repro.kernels.nm_spmm import nm_matmul
    flatm, _ = jax.tree_util.tree_flatten_with_path(
        masks, is_leaf=lambda x: x is None)
    flatw, _ = jax.tree_util.tree_flatten_with_path(pruned[0.5])
    done = False
    for (kp, mk) in flatm:
        if mk is None or mk.shape[-2] % 4:
            continue
        w = None  # find matching pruned weight by path
        for kp2, w2 in flatw:
            if kp2 == kp:
                w = w2
                break
        if w is None:
            continue
        while mk.ndim > 2:  # stacked layer kernels: take layer 0
            mk, w = mk[0], w[0]
        vals, idx = kref.compress_24(jnp.asarray(w, jnp.float32))
        x = 0.1 * jax.random.normal(jax.random.key(1), (16, w.shape[0]))
        y1 = nm_matmul(x, vals, idx, bm=16, bk=w.shape[0],
                       bn=w.shape[1], interpret=True)
        y2 = x @ jnp.asarray(w, jnp.float32)
        np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
        done = True
        break
    assert done


def test_search_never_touches_w0(trained):
    params, _ = trained
    before = jax.tree.map(lambda x: np.asarray(x).copy(), params)
    calib = batches_for(CFG, n=4, batch=4, seq=64, split="calib")
    pcfg = PruneConfig(local_metric="wanda", steps=5)
    calibrate.unipruning_prune(CFG, pcfg, params, calib, sparsities=[0.5])
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(params)):
        np.testing.assert_array_equal(a, np.asarray(b))
