"""Flight recorder: spans, registry, JSONL trace, fleet percentiles."""
import json
import warnings

import jax
import numpy as np
import pytest

from repro import obs
from repro.configs.base import PruneConfig, get_smoke_config
from repro.core import calibrate
from repro.data.synthetic import batches_for
from repro.models import model as M
from repro.obs.registry import DEFAULT_MS_BUCKETS, Histogram, Registry
from repro.serve.fleet import SparsityFleet
from repro.sparse.bank import MaskBank

CFG = get_smoke_config("llama3.2-1b")


@pytest.fixture(autouse=True)
def clean_recorder():
    obs.reset()
    yield
    obs.reset()


# -- spans -------------------------------------------------------------------


def test_disabled_span_is_the_shared_noop_singleton():
    """The disabled hot path must not allocate: every span() call returns
    ONE shared object whose methods are constant no-ops."""
    assert not obs.enabled()
    assert obs.span("a") is obs.span("b")
    sp = obs.span("decode", slot=3)
    with sp as inner:
        assert inner is sp
        inner.set(bucket=64)   # all no-ops, no state
        inner.fence(None)
    assert sp.seconds is None
    assert obs.events() == []


def test_span_nesting_records_parent_and_depth():
    obs.configure()
    with obs.span("outer") as outer:
        with obs.span("inner") as inner:
            assert inner.parent_id == outer.span_id
            assert inner.depth == 1
        with obs.span("inner2") as inner2:
            assert inner2.parent_id == outer.span_id
    assert outer.parent_id is None and outer.depth == 0
    ev = {e["name"]: e for e in obs.events() if e["kind"] == "span"}
    assert ev["inner"]["parent_id"] == ev["outer"]["span_id"]
    assert ev["inner"]["depth"] == 1 and ev["outer"]["depth"] == 0
    # children exit (and land in the buffer) before their parent
    names = [e["name"] for e in obs.events()]
    assert names.index("inner") < names.index("outer")
    assert all(e["dur_ms"] >= 0 and e["ok"] for e in ev.values())


def test_span_fence_blocks_on_pending_device_work():
    obs.configure()
    x = jax.numpy.ones((64, 64))
    with obs.span("matmul") as sp:
        y = x @ x
        sp.fence(y)
    assert sp.seconds is not None and sp.seconds >= 0
    assert np.asarray(y)[0, 0] == 64.0


def test_timer_measures_even_while_disabled():
    """Stage timings feed artifact metadata whether or not the recorder is
    on - timer() must always return a real measuring span."""
    assert not obs.enabled()
    with obs.timer("stage") as t:
        pass
    assert t.seconds is not None and t.seconds >= 0
    assert obs.events() == []   # but it still emits nothing while disabled


def test_span_records_exception_and_unwinds_stack():
    obs.configure()
    with pytest.raises(RuntimeError):
        with obs.span("boom"):
            raise RuntimeError("x")
    (ev,) = [e for e in obs.events() if e["kind"] == "span"]
    assert ev["name"] == "boom" and ev["ok"] is False
    with obs.span("after") as sp:
        assert sp.depth == 0   # failed span did not leak onto the stack


# -- structured logs + warnings contract -------------------------------------


def test_log_warn_preserves_stdlib_warning_semantics():
    obs.configure()
    with pytest.warns(UserWarning, match="legacy"):
        obs.log("bank.legacy", level="warning", warn="legacy artifact")
    # info-level logs never warn, even under -W error
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        obs.log("calibrate.done", steps=4)
    events = [e for e in obs.events() if e["kind"] == "log"]
    assert {e["event"] for e in events} == {"bank.legacy", "calibrate.done"}
    # the warning fires even with the recorder off (no event, same warning)
    obs.reset()
    with pytest.warns(UserWarning, match="legacy"):
        obs.log("bank.legacy", level="warning", warn="legacy artifact")
    assert obs.events() == []


# -- registry ----------------------------------------------------------------


def test_histogram_bucket_edges_follow_le_convention():
    h = Histogram((1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 2.0, 5.0, 7.0):
        h.observe(v)
    # le semantics: bucket i counts edges[i-1] < v <= edges[i]
    assert h.counts == [2, 2, 1, 1]   # (<=1], (1,2], (2,5], overflow
    assert h.count == 6 and h.sum == pytest.approx(17.0)
    assert h.min == 0.5 and h.max == 7.0
    snap = h.snapshot()
    assert snap["buckets"]["+Inf"] == 1
    assert snap["buckets"]["1.0"] == 2


def test_histogram_percentiles_clamped_to_observed_range():
    h = Histogram((1.0, 10.0, 100.0))
    for v in (3.0, 4.0, 5.0):
        h.observe(v)
    p50, p99 = h.percentile(50), h.percentile(99)
    # interpolation may not leave the observed data range
    assert 3.0 <= p50 <= 5.0 and 3.0 <= p99 <= 5.0
    assert Histogram().percentile(50) is None  # empty -> None, not 0


def test_registry_counters_gauges_and_label_separation():
    r = Registry()
    r.inc("req", 1, {"budget": "0.5"})
    r.inc("req", 2, {"budget": "0.5"})
    r.inc("req", 5, {"budget": "2:4"})
    r.set_gauge("depth", 7, {"budget": "0.5"})
    assert r.counter_value("req", {"budget": "0.5"}) == 3
    assert r.counter_value("req", {"budget": "2:4"}) == 5
    assert r.counter_value("req", {"budget": "0.0"}) == 0
    assert r.gauge_value("depth", {"budget": "0.5"}) == 7
    assert r.gauge_value("depth") is None


def test_registry_declared_edges_and_prometheus_exposition():
    r = Registry()
    r.declare_hist("agree", (0.5, 1.0))
    r.observe("agree", 0.75)
    r.observe("lat_ms", 3.0)
    assert r.hist("agree").edges == (0.5, 1.0)
    assert r.hist("lat_ms").edges == DEFAULT_MS_BUCKETS
    text = r.expose()
    assert '# TYPE agree histogram' in text
    assert 'agree_bucket{le="1"} 1' in text      # cumulative le buckets
    assert 'agree_bucket{le="+Inf"} 1' in text
    assert 'agree_count 1' in text
    r.inc("tok", 4, {"budget": "2:4"})
    assert 'tok{budget="2:4"} 4' in r.expose()


def test_metric_writes_are_noops_while_disabled():
    assert not obs.enabled()
    obs.inc("serve.tokens_decoded", 4)
    obs.observe("serve.decode_step_ms", 1.5)
    obs.set_gauge("serve.slot_util", 0.5)
    assert obs.counter_value("serve.tokens_decoded") == 0
    assert obs.percentile("serve.decode_step_ms", 50) is None
    assert obs.gauge_value("serve.slot_util") is None


# -- JSONL export ------------------------------------------------------------


def test_jsonl_schema_round_trip(tmp_path):
    obs.configure(trace_dir=tmp_path)
    with obs.span("prefill", slot=2, prompt_len=7):
        pass
    obs.log("calibrate.search_chunk", start=0, steps=2,
            loss=[1.0, 0.5], sparsity=np.float32(0.25))
    obs.flush()
    events = list(obs.read_jsonl(tmp_path / "events.jsonl"))
    assert [e["kind"] for e in events] == ["span", "log"]
    span, log = events
    assert span["name"] == "prefill" and span["dur_ms"] >= 0
    assert span["attrs"] == {"slot": 2, "prompt_len": 7}
    assert span["parent_id"] is None and span["depth"] == 0
    assert "ts" in span and "ts" in log
    # numpy scalars serialized as plain JSON numbers
    assert log["sparsity"] == pytest.approx(0.25)
    assert log["loss"] == [1.0, 0.5]
    assert obs.trace_path() == tmp_path / "events.jsonl"


def test_jsonl_reader_skips_partial_last_line(tmp_path):
    p = tmp_path / "events.jsonl"
    p.write_text(json.dumps({"kind": "log", "event": "a"}) + "\n"
                 + '{"kind": "log", "ev')   # crash mid-write
    events = list(obs.read_jsonl(p))
    assert len(events) == 1 and events[0]["event"] == "a"


# -- end-to-end: fleet percentiles + search series ---------------------------


@pytest.fixture(scope="module")
def bank_setup(tmp_path_factory):
    params = M.init_params(CFG, jax.random.key(0))
    calib = batches_for(CFG, n=2, batch=2, seq=16, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=2)
    stats = calibrate.collect_stats(CFG, params, calib)
    state, _ = calibrate.run_search(CFG, pcfg, params, calib, stats)
    d = tmp_path_factory.mktemp("obs_fleet") / "bank"
    MaskBank.save(d, arch="llama3.2-1b", smoke=True, state=state,
                  stats=stats, pcfg=pcfg)
    return params, d


def test_fleet_report_percentiles_populated_after_smoke_run(bank_setup):
    params, d = bank_setup
    obs.configure()
    fleet = SparsityFleet.from_artifact(d, params, ["0.0", "2:4"], slots=4,
                                        capacity=32)
    for p in [np.array([5, 6, 7, 8]), np.array([9, 10, 11])]:
        for name in ("0.0", "2:4"):
            fleet.submit(p, 4, budget=name)
    fleet.run()
    rep = fleet.report()
    for name in ("0.0", "2:4"):
        r = rep["budgets"][name]
        assert r["decode_ms_p50"] is not None, name
        assert r["decode_ms_p95"] is not None, name
        assert 0 < r["decode_ms_p50"] <= r["decode_ms_p95"]
        assert r["cumulative"]["tokens"] == r["tokens"] > 0
    assert obs.counter_value("serve.tokens_decoded", budget="2:4") > 0


def test_fleet_report_percentiles_none_without_recorder(bank_setup):
    params, d = bank_setup
    assert not obs.enabled()
    fleet = SparsityFleet.from_artifact(d, params, ["0.0"], slots=2,
                                        capacity=32)
    fleet.submit(np.array([5, 6, 7]), 3, budget="0.0")
    out = fleet.run()
    assert all(len(v) == 3 for v in out.values())   # serving unaffected
    rep = fleet.report()["budgets"]["0.0"]
    assert rep["decode_ms_p50"] is None and rep["decode_ms_p95"] is None


def test_run_search_emits_per_chunk_series(tmp_path, bank_setup):
    params, _ = bank_setup
    obs.configure(trace_dir=tmp_path)
    calib = batches_for(CFG, n=2, batch=2, seq=16, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=4,
                       scan_chunk=2)
    stats = calibrate.collect_stats(CFG, params, calib)
    calibrate.run_search(CFG, pcfg, params, calib, stats)
    obs.flush()
    chunks = [e for e in obs.read_jsonl(tmp_path / "events.jsonl")
              if e.get("kind") == "log"
              and e.get("event") == "calibrate.search_chunk"]
    assert len(chunks) == 2   # 4 steps / scan_chunk=2
    for c in chunks:
        for k in ("loss", "sparsity", "mask_churn", "gamma_entropy"):
            assert len(c[k]) == c["steps"] == 2, k
        assert all(0.0 <= v <= 1.0 for v in c["gamma_entropy"])
        assert all(0.0 <= v <= 1.0 for v in c["mask_churn"])
    assert obs.counter_value("calibrate.search_steps") == 4
