"""Activation-statistics tape.

UniPruning's local metrics S(W, X) need, per prunable projection, the L2 norm
of each *input feature* over the calibration set (Wanda's ||X_j||_2).  The
tape intercepts ``repro.models.common.dense`` (and the MoE expert einsums)
during an **eager, unrolled** calibration pass and accumulates per-feature
sum-of-squares.

Keying: scan-stacked layer parameters are sliced per layer during the
unrolled pass, so leaf ``id()`` alone cannot name them.  The model registers
each sliced layer tree under a (path, layer_index) tag; stats for stacked
leaves are re-stacked along the layer axis at resolve time.

At production scale the same statistics come out of the jitted per-layer
pass (:class:`JitTape` + ``models.model.stats_sumsq``, driven by
``core.calibrate.collect_stats(impl="jit")``); the eager tape is the parity
oracle, asserted against the jitted pass in tests.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

_local = threading.local()


def _paths(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class StatsTape:
    def __init__(self):
        # id(kernel) -> (pathstr, layer_idx)
        self.registry: dict[int, tuple[str, int]] = {}
        # (pathstr, layer_idx) -> sumsq fp64, shape kernel.shape[:-1]
        self.sumsq: dict[tuple[str, int], np.ndarray] = {}

    def register_layer(self, tree: Any, prefix: str, layer_idx: int) -> None:
        for pathstr, leaf in _paths(tree):
            if isinstance(leaf, (jax.Array, np.ndarray)):
                self.registry[id(leaf)] = (prefix + pathstr, layer_idx)

    def record(self, kernel, x, *, count=None, ref_count=None) -> None:
        """Accumulate stats with shape kernel.shape[:-1].

        count / ref_count: actual contributing rows per leading-dim entry
        and the reference token count of the pass.  MoE dispatch buffers are
        capacity-padded with zero rows, so the summed-axes size G*C is NOT
        the sample size; the caller passes the per-expert routed-row counts
        (an array broadcast against the leading stat dims) plus the token
        count T of the batch, and the accumulated sum of squares is rescaled
        by ref_count / count.  The resolved ||X_j||_2 then reads as the RMS
        over actually-routed rows scaled to the same token count a dense-FFN
        layer sees - without it, per-expert saliency is systematically
        diluted under one global budget simply because each expert receives
        ~T*k/E of the tokens.  Experts that received nothing stay at 0.
        """
        key = self.registry.get(id(kernel))
        if key is None:
            return
        nlead = kernel.ndim - 2
        axes = tuple(range(nlead, x.ndim - 1))
        ss = np.asarray(jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axes),
                        np.float64)
        if count is not None:
            c = np.asarray(count, np.float64)
            scale = float(ref_count) / np.maximum(c, 1.0)
            ss = ss * scale.reshape(scale.shape + (1,) * (ss.ndim - c.ndim))
        if key in self.sumsq:
            self.sumsq[key] = self.sumsq[key] + ss
        else:
            self.sumsq[key] = ss


class JitTape(StatsTape):
    """Trace-compatible tape: accumulates *traced* fp32 sum-of-squares.

    Installed (via ``recording``) inside a function being jit-traced, it
    records through the exact same ``dense``/``moe_apply`` hooks as the
    eager tape, but keeps the per-kernel statistics as jax values so the
    enclosing function can RETURN them (``stats()``) - under ``lax.scan``
    the per-layer stats come back stacked along the scan axis for free.

    Registration happens during tracing, so ``id(kernel)`` keys refer to
    tracers; a jit cache hit replays the recorded program without re-running
    the Python side effects, which is exactly why the stats must flow out as
    function outputs rather than host-side state.
    """

    def __init__(self):
        super().__init__()
        self.out: dict[tuple[str, int], jax.Array] = {}

    def record(self, kernel, x, *, count=None, ref_count=None) -> None:
        key = self.registry.get(id(kernel))
        if key is None:
            return
        nlead = kernel.ndim - 2
        axes = tuple(range(nlead, x.ndim - 1))
        ss = jnp.sum(jnp.square(x.astype(jnp.float32)), axis=axes)
        if count is not None:
            c = jnp.asarray(count, jnp.float32)
            scale = jnp.asarray(ref_count, jnp.float32) / jnp.maximum(c, 1.0)
            ss = ss * scale.reshape(scale.shape + (1,) * (ss.ndim - c.ndim))
        prev = self.out.get(key)
        self.out[key] = ss if prev is None else prev + ss

    def stats(self, layer_idx: int) -> dict[str, jax.Array]:
        """{pathstr: sumsq} for keys registered under ``layer_idx``."""
        return {p: v for (p, li), v in self.out.items() if li == layer_idx}


def current_tape() -> StatsTape | None:
    return getattr(_local, "tape", None)


@contextlib.contextmanager
def recording(tape: StatsTape):
    prev = current_tape()
    _local.tape = tape
    try:
        yield tape
    finally:
        _local.tape = prev


def resolve_stats(tape: StatsTape, params: Any) -> Any:
    """Build a stats pytree matching ``params``.

    For every kernel leaf seen by the tape: per-input-feature activation
    norm a_j = ||X_j||_2 over the whole calibration set (Wanda's statistic,
    unnormalized) with shape kernel.shape[:-1]; stacked leaves get their
    layer axis back.  Unseen leaves -> None.
    """
    by_path: dict[str, dict[int, np.ndarray]] = {}
    for (pathstr, layer_idx), ss in tape.sumsq.items():
        by_path.setdefault(pathstr, {})[layer_idx] = ss

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for kp, leaf in flat:
        pathstr = jax.tree_util.keystr(kp)
        rec = by_path.get(pathstr)
        if rec is None:
            out.append(None)
            continue
        idxs = sorted(rec)
        # Wanda-faithful: UNnormalized ||X_j||_2 over the calibration set
        arrs = [np.sqrt(rec[i]) for i in idxs]
        if len(idxs) == 1 and idxs[0] == -1:       # unstacked leaf
            a = arrs[0]
        else:                                      # re-stack layer axis
            a = np.stack(arrs, axis=0)
        out.append(jnp.asarray(a, jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, out)
