"""Mask export: one-shot extraction at arbitrary sparsity from saliency maps.

* ``global_threshold``  - exact: one global sort/quantile of |Gamma|.
* ``threshold_bisect``  - scalable: histogram bisection using only full
  reductions (each round lowers to one tiny all-reduce under pjit), usable
  across pods where a global sort is not.
* ``unstructured_masks``- scope = global | layer | row.
* ``nm_masks``          - N:M per-group top-N along the input (reduction) dim.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flat_abs(tree: Any) -> jax.Array:
    leaves = [jnp.abs(x.astype(jnp.float32)).reshape(-1)
              for x in jax.tree.leaves(tree) if x is not None]
    return jnp.concatenate(leaves)


def global_threshold(score_tree: Any, sparsity: float) -> jax.Array:
    """Exact tau: |score| < tau is pruned; keeps top (1-sparsity) fraction."""
    flat = _flat_abs(score_tree)
    return jnp.quantile(flat, sparsity)


def threshold_bisect(score_tree: Any, sparsity: float, *, iters: int = 40,
                     hi: float | None = None) -> jax.Array:
    """Distributed-friendly tau via bisection on P(|s| <= tau).

    Uses only sum-reductions over each (possibly sharded) leaf, so under pjit
    every round is a scalar all-reduce; no gather/sort of Gamma ever happens.
    """
    leaves = [x for x in jax.tree.leaves(score_tree) if x is not None]
    total = sum(x.size for x in leaves)
    if hi is None:
        hi = sum(jnp.max(jnp.abs(l.astype(jnp.float32))) for l in leaves)

    def count_le(tau):
        return sum(jnp.sum(jnp.abs(l.astype(jnp.float32)) <= tau)
                   for l in leaves)

    def body(i, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        frac = count_le(mid) / total
        return jnp.where(frac < sparsity, mid, lo), \
            jnp.where(frac < sparsity, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body,
                               (jnp.zeros((), jnp.float32),
                                jnp.asarray(hi, jnp.float32)))
    return 0.5 * (lo + hi)


def unstructured_masks(score_tree: Any, sparsity: float, *,
                       scope: str = "global", exact: bool = True) -> Any:
    """Binary keep-masks matching score_tree (None leaves stay None).

    scope: 'global' (one budget, UniPruning), 'layer' (per-tensor budget),
    'row'  (per-output-column budget along d_in - Wanda's comparison group).
    """
    is_none = lambda x: x is None

    if scope == "global":
        tau = (global_threshold(score_tree, sparsity) if exact
               else threshold_bisect(score_tree, sparsity))
        return jax.tree.map(
            lambda s: None if s is None else jnp.abs(s) >= tau,
            score_tree, is_leaf=is_none)

    def layer_mask(s):
        if s is None:
            return None
        tau = jnp.quantile(jnp.abs(s.astype(jnp.float32)), sparsity)
        return jnp.abs(s) >= tau

    def row_mask(s):
        if s is None:
            return None
        a = jnp.abs(s.astype(jnp.float32))
        # comparison group: all inputs feeding one output unit (axis -2)
        k = max(1, int(round(s.shape[-2] * (1.0 - sparsity))))
        kth = -jnp.sort(-a, axis=-2)[..., k - 1:k, :]
        return a >= kth

    fn = layer_mask if scope == "layer" else row_mask
    return jax.tree.map(fn, score_tree, is_leaf=is_none)


def nm_masks(score_tree: Any, n: int = 2, m: int = 4) -> Any:
    """Keep top-n of every m contiguous entries along the input dim.

    Rank-based with deterministic tie-break (earlier position wins) - a
    late-arriving group maximum can never be dropped.
    """
    def leaf(s):
        if s is None:
            return None
        *lead, d_in, d_out = s.shape
        assert d_in % m == 0, (d_in, m)
        g = jnp.abs(s.astype(jnp.float32)).reshape(*lead, d_in // m, m, d_out)
        g = jnp.moveaxis(g, -2, -1)              # (*lead, d_in//m, d_out, m)
        gi = g[..., :, None]
        gj = g[..., None, :]
        pos = jnp.arange(m)
        j_earlier = pos[None, :] < pos[:, None]  # [i, j]: j < i
        rank = jnp.sum((gj > gi) | ((gj == gi) & j_earlier), axis=-1)
        mask = rank < n
        return jnp.moveaxis(mask, -1, -2).reshape(*lead, d_in, d_out)

    return jax.tree.map(leaf, score_tree, is_leaf=lambda x: x is None)


def apply_masks(params: Any, masks: Any) -> Any:
    """W0 ⊙ M with None masks passing weights through untouched."""
    def leaf(w, m):
        return w if m is None else w * m.astype(w.dtype)

    return jax.tree.map(leaf, params, masks,
                        is_leaf=lambda x: x is None)


def sparsity_of(masks: Any) -> float:
    tot = kept = 0
    for m in jax.tree.leaves(masks):
        if m is None:
            continue
        tot += m.size
        kept += int(jnp.sum(m))
    return 1.0 - kept / max(tot, 1)
