"""UniPruning mirror-descent search (paper Algorithm 1, Eqs. 5-7).

State: a trainable copy W of the pretrained weights, the saliency variable
Gamma and its dual V (both only on prunable leaves).  Per step:

  S      = S(W^n, X)                        local metric at current W
  g_task = grad_W L_task(W^n)
  g_align= rho * grad_W 0.5||Gamma - S(W)||^2        (exact, via autodiff)
  W     <- W - kappa*alpha*(g_task + g_align)
  W     <- Prox_{R_{2:4}}(W)                          [N:M mode only]
  V     <- V - alpha*rho*(Gamma - S)
  Gamma <- soft_threshold(V, lam)                     Prox of lam*L1

The pretrained W0 is never touched; masks are extracted from Gamma and
applied to W0 (core/masks.py).
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import PruneConfig
from repro.core import masks as masks_mod
from repro.core import metrics as metrics_mod
from repro.core import prox as prox_mod
from repro.core.prunable import prunable_map

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SearchState:
    W: PyTree          # trainable copy (full params tree)
    Gamma: PyTree      # saliency variable (prunable leaves, else None)
    V: PyTree          # dual variable (prunable leaves, else None)
    step: jax.Array    # scalar int32
    rng: jax.Array


def _zeros_like_prunable(params: PyTree, prunable: PyTree) -> PyTree:
    return jax.tree.map(
        lambda w, p: jnp.zeros(w.shape, jnp.float32) if p else None,
        params, prunable)


def init_search(params0: PyTree, key: jax.Array) -> SearchState:
    pr = prunable_map(params0)
    # jnp.array (copy semantics), NOT astype: same-dtype astype aliases the
    # input buffer, and the search donates its state buffers into the jitted
    # scan - donating an alias of W0 would invalidate the pretrained params.
    return SearchState(
        W=jax.tree.map(lambda x: jnp.array(x, jnp.float32), params0),
        Gamma=_zeros_like_prunable(params0, pr),
        V=_zeros_like_prunable(params0, pr),
        step=jnp.zeros((), jnp.int32),
        rng=key)


def _tree_sub(a, b, scale):
    return jax.tree.map(lambda x, y: x - scale * y, a, b)


def _align_value_and_grad(pcfg: PruneConfig, W, Gamma, stats, prunable, key):
    """0.5*rho*sum_leaves ||Gamma - S(W)||_F^2 and its W-gradient."""
    def val(Wp):
        S = metrics_mod.metric_tree(pcfg.local_metric, Wp, stats, prunable,
                                    key=key, stoch_frac=pcfg.stoch_frac,
                                    norm=pcfg.score_norm)
        acc = [jnp.zeros((), jnp.float32)]

        def leaf(g, s):  # tree.map: structural alignment enforced
            if g is not None and s is not None:
                acc[0] = acc[0] + jnp.sum(jnp.square(g - s))

        jax.tree.map(leaf, Gamma, S, is_leaf=lambda x: x is None)
        return 0.5 * pcfg.rho * acc[0]

    return jax.value_and_grad(val)(W)


def _task_value_and_grad(pcfg: PruneConfig, loss_fn: Callable, W: PyTree,
                         batch: dict):
    """(loss, metrics), grad - optionally accumulated over microbatches.

    grad_accum > 1 splits the batch dim into microbatch slices and runs the
    backward once per slice under lax.scan, so peak activation memory is
    that of one microbatch while the averaged gradient matches the full
    batch (token weights permitting).
    """
    accum = max(1, int(pcfg.grad_accum))
    if accum == 1:
        return jax.value_and_grad(loss_fn, has_aux=True)(W, batch)

    def split(x):
        assert x.shape[0] % accum == 0, (
            f"grad_accum={accum} must divide the calibration batch dim "
            f"{x.shape[0]}")
        return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

    micro = jax.tree.map(split, batch)
    one = lambda b: jax.value_and_grad(loss_fn, has_aux=True)(W, b)
    shapes = jax.eval_shape(one, jax.tree.map(lambda x: x[0], micro))
    zero = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes)

    def body(carry, b):
        return jax.tree.map(jnp.add, carry, one(b)), None

    summed, _ = jax.lax.scan(body, zero, micro)
    return jax.tree.map(lambda x: x / accum, summed)


def search_step(pcfg: PruneConfig, loss_fn: Callable, state: SearchState,
                batch: dict, stats: PyTree, prunable: PyTree):
    """One mirror-descent iteration. loss_fn(W, batch) -> (loss, metrics)."""
    key = jax.random.fold_in(state.rng, state.step)
    (loss, loss_metrics), g_task = _task_value_and_grad(
        pcfg, loss_fn, state.W, batch)
    align, g_align = _align_value_and_grad(
        pcfg, state.W, state.Gamma, stats, prunable, key)

    lr = pcfg.lr
    W = jax.tree.map(
        lambda w, gt, ga: (w - pcfg.kappa * lr *
                           (gt.astype(jnp.float32) + ga.astype(jnp.float32))),
        state.W, g_task, g_align)

    if pcfg.mode == "nm":
        W = jax.tree.map(
            lambda w, p: prox_mod.prox_nm24(w, pcfg.nm_prox_weight)
            if (p and w.shape[-2] % 4 == 0) else w,
            W, prunable)

    S = metrics_mod.metric_tree(pcfg.local_metric, W, stats, prunable,
                                key=key, stoch_frac=pcfg.stoch_frac,
                                norm=pcfg.score_norm)

    def upd_v(v, g, s):
        if v is None:
            return None
        return v - pcfg.v_lr * (g - s)  # v_lr == alpha*rho (paper Eq. 6)

    V = jax.tree.map(upd_v, state.V, state.Gamma, S,
                     is_leaf=lambda x: x is None)
    Gamma = jax.tree.map(
        lambda v: None if v is None else prox_mod.soft_threshold(v, pcfg.lam),
        V, is_leaf=lambda x: x is None)

    # convergence observables, all device-side scalars: they ride out of the
    # jitted lax.scan as stacked outputs (no host callbacks mid-search).
    #   nz      - Gamma support size (1 - nz/tot = live sparsity trajectory)
    #   flips   - support entries that changed old->new Gamma this step;
    #             flips/tot is the mask-churn rate the trace records
    #   absum/abslogsum - accumulators for the Gamma-simplex entropy
    #             H(|Gamma|/Z) = log Z - (1/Z) sum |g| log |g|, normalized
    #             by log(tot) to [0, 1] (1 = uniform saliency, 0 = a single
    #             spike; collapse shows up as a dive long before masks stop
    #             moving)
    nz = jnp.zeros((), jnp.float32)
    flips = jnp.zeros((), jnp.float32)
    absum = jnp.zeros((), jnp.float32)
    abslogsum = jnp.zeros((), jnp.float32)
    tot = 0
    for g_old, g in zip(
            jax.tree.leaves(state.Gamma, is_leaf=lambda x: x is None),
            jax.tree.leaves(Gamma, is_leaf=lambda x: x is None),
            strict=True):
        if g is None:
            continue
        nz += jnp.sum(g != 0)
        flips += jnp.sum((g_old != 0) != (g != 0))
        a = jnp.abs(g)
        absum += jnp.sum(a)
        abslogsum += jnp.sum(jnp.where(a > 0, a * jnp.log(
            jnp.where(a > 0, a, 1.0)), 0.0))
        tot += g.size
    z = jnp.maximum(absum, 1e-30)
    entropy = jnp.where(absum > 0, jnp.log(z) - abslogsum / z, 0.0)
    entropy = entropy / jnp.log(jnp.float32(max(tot, 2)))
    new_state = SearchState(W=W, Gamma=Gamma, V=V, step=state.step + 1,
                            rng=state.rng)
    metrics = {"loss": loss, "align": align,
               "gamma_nonzero_frac": nz / max(tot, 1),
               "mask_churn": flips / max(tot, 1),
               "gamma_entropy": entropy, **loss_metrics}
    return new_state, metrics


def no_mirror_step(pcfg: PruneConfig, loss_fn: Callable, W: PyTree,
                   batch: dict, stats: PyTree, prunable: PyTree,
                   rng: jax.Array, step: jax.Array, *, l2: float):
    """Ablation (paper Eq. 8 / Table 5): direct objective without the
    saliency variable or mirror descent - L_task + rho/2||S(W)||^2 + l2||W||^2.
    Final scores are S(W_final)."""
    key = jax.random.fold_in(rng, step)

    def total(Wp):
        loss, aux = loss_fn(Wp, batch)
        S = metrics_mod.metric_tree(pcfg.local_metric, Wp, stats, prunable,
                                    key=key, stoch_frac=pcfg.stoch_frac)
        # tree.map (not zipped leaf lists): the S/W/prunable trees must
        # agree structurally, and a mismatch raises instead of silently
        # regularizing the wrong leaves.
        acc = [jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)]

        def leaf(s, w, p):
            if s is not None and p:
                acc[0] = acc[0] + jnp.sum(jnp.square(s))
                acc[1] = acc[1] + jnp.sum(jnp.square(w))

        jax.tree.map(leaf, S, Wp, prunable, is_leaf=lambda x: x is None)
        return loss + 0.5 * pcfg.rho * acc[0] + l2 * acc[1], aux

    (loss, _), g = jax.value_and_grad(total, has_aux=True)(W)
    W = jax.tree.map(lambda w, gg: w - pcfg.kappa * pcfg.lr * gg, W, g)
    return W, loss


@jax.jit
def _absmax_fused(leaves: tuple) -> jax.Array:
    """max_i ||leaf_i||_inf in one compiled dispatch (no host pulls)."""
    return functools.reduce(
        jnp.maximum, [jnp.max(jnp.abs(x)) for x in leaves])


def export_masks(pcfg: PruneConfig, Gamma: PyTree, sparsity: float,
                 *, V: PyTree | None = None, exact: bool = True) -> PyTree:
    """One-shot mask extraction from the final Gamma (any sparsity level).

    Soft-thresholded-to-zero entries are tied at |Gamma|=0; the dual V
    retains their sub-threshold saliency, so it breaks ties at an epsilon
    scale that cannot reorder any nonzero Gamma entries.  The epsilon is
    computed DEVICE-side: gmax/vmax come out of one fused jitted reduction
    over all leaves, so a bank re-thresholding at many budgets never pays a
    per-leaf host sync for the tie-break.
    """
    scores = Gamma
    if V is not None:
        gl = tuple(g for g in
                   jax.tree.leaves(Gamma, is_leaf=lambda x: x is None)
                   if g is not None)
        vl = tuple(v for v in
                   jax.tree.leaves(V, is_leaf=lambda x: x is None)
                   if v is not None)
        gmax = _absmax_fused(gl) if gl else jnp.float32(0.0)
        vmax = _absmax_fused(vl) if vl else jnp.float32(1.0)
        vsafe = jnp.maximum(vmax, 1e-30)
        eps = jnp.where(gmax > 0,
                        1e-6 * jnp.maximum(gmax, 1e-30) / vsafe,
                        1.0 / vsafe)
        scores = jax.tree.map(
            lambda g, v: None if g is None else jnp.abs(g) + eps * jnp.abs(v),
            Gamma, V, is_leaf=lambda x: x is None)
    if pcfg.mode == "nm":
        return masks_mod.nm_masks(scores, pcfg.nm_n, pcfg.nm_m)
    return masks_mod.unstructured_masks(scores, sparsity, scope="global",
                                        exact=exact)
