"""UniPruning mirror-descent search (paper Algorithm 1, Eqs. 5-7).

State: a trainable copy W of the pretrained weights, the saliency variable
Gamma and its dual V (both only on prunable leaves).  Per step:

  S      = S(W^n, X)                        local metric at current W
  g_task = grad_W L_task(W^n)
  g_align= rho * grad_W 0.5||Gamma - S(W)||^2        (exact, via autodiff)
  W     <- W - kappa*alpha*(g_task + g_align)
  W     <- Prox_{R_{2:4}}(W)                          [N:M mode only]
  V     <- V - alpha*rho*(Gamma - S)
  Gamma <- soft_threshold(V, lam)                     Prox of lam*L1

The pretrained W0 is never touched; masks are extracted from Gamma and
applied to W0 (core/masks.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import PruneConfig
from repro.core import masks as masks_mod
from repro.core import metrics as metrics_mod
from repro.core import prox as prox_mod
from repro.core.prunable import prunable_map

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SearchState:
    W: PyTree          # trainable copy (full params tree)
    Gamma: PyTree      # saliency variable (prunable leaves, else None)
    V: PyTree          # dual variable (prunable leaves, else None)
    step: jax.Array    # scalar int32
    rng: jax.Array


def _zeros_like_prunable(params: PyTree, prunable: PyTree) -> PyTree:
    return jax.tree.map(
        lambda w, p: jnp.zeros(w.shape, jnp.float32) if p else None,
        params, prunable)


def init_search(params0: PyTree, key: jax.Array) -> SearchState:
    pr = prunable_map(params0)
    return SearchState(
        W=jax.tree.map(lambda x: x.astype(jnp.float32), params0),
        Gamma=_zeros_like_prunable(params0, pr),
        V=_zeros_like_prunable(params0, pr),
        step=jnp.zeros((), jnp.int32),
        rng=key)


def _tree_sub(a, b, scale):
    return jax.tree.map(lambda x, y: x - scale * y, a, b)


def _align_value_and_grad(pcfg: PruneConfig, W, Gamma, stats, prunable, key):
    """0.5*rho*sum_leaves ||Gamma - S(W)||_F^2 and its W-gradient."""
    def val(Wp):
        S = metrics_mod.metric_tree(pcfg.local_metric, Wp, stats, prunable,
                                    key=key, stoch_frac=pcfg.stoch_frac,
                                    norm=pcfg.score_norm)
        tot = jnp.zeros((), jnp.float32)
        for g, s in zip(jax.tree.leaves(Gamma, is_leaf=lambda x: x is None),
                        jax.tree.leaves(S, is_leaf=lambda x: x is None)):
            if g is None or s is None:
                continue
            tot += jnp.sum(jnp.square(g - s))
        return 0.5 * pcfg.rho * tot

    return jax.value_and_grad(val)(W)


def search_step(pcfg: PruneConfig, loss_fn: Callable, state: SearchState,
                batch: dict, stats: PyTree, prunable: PyTree):
    """One mirror-descent iteration. loss_fn(W, batch) -> (loss, metrics)."""
    key = jax.random.fold_in(state.rng, state.step)
    (loss, loss_metrics), g_task = jax.value_and_grad(
        loss_fn, has_aux=True)(state.W, batch)
    align, g_align = _align_value_and_grad(
        pcfg, state.W, state.Gamma, stats, prunable, key)

    lr = pcfg.lr
    W = jax.tree.map(
        lambda w, gt, ga: (w - pcfg.kappa * lr *
                           (gt.astype(jnp.float32) + ga.astype(jnp.float32))),
        state.W, g_task, g_align)

    if pcfg.mode == "nm":
        W = jax.tree.map(
            lambda w, p: prox_mod.prox_nm24(w, pcfg.nm_prox_weight)
            if (p and w.shape[-2] % 4 == 0) else w,
            W, prunable)

    S = metrics_mod.metric_tree(pcfg.local_metric, W, stats, prunable,
                                key=key, stoch_frac=pcfg.stoch_frac,
                                norm=pcfg.score_norm)

    def upd_v(v, g, s):
        if v is None:
            return None
        return v - pcfg.v_lr * (g - s)  # v_lr == alpha*rho (paper Eq. 6)

    V = jax.tree.map(upd_v, state.V, state.Gamma, S,
                     is_leaf=lambda x: x is None)
    Gamma = jax.tree.map(
        lambda v: None if v is None else prox_mod.soft_threshold(v, pcfg.lam),
        V, is_leaf=lambda x: x is None)

    nz = jnp.zeros((), jnp.float32)
    tot = 0
    for g in jax.tree.leaves(Gamma, is_leaf=lambda x: x is None):
        if g is None:
            continue
        nz += jnp.sum(g != 0)
        tot += g.size
    new_state = SearchState(W=W, Gamma=Gamma, V=V, step=state.step + 1,
                            rng=state.rng)
    metrics = {"loss": loss, "align": align,
               "gamma_nonzero_frac": nz / max(tot, 1), **loss_metrics}
    return new_state, metrics


def no_mirror_step(pcfg: PruneConfig, loss_fn: Callable, W: PyTree,
                   batch: dict, stats: PyTree, prunable: PyTree,
                   rng: jax.Array, step: jax.Array, *, l2: float):
    """Ablation (paper Eq. 8 / Table 5): direct objective without the
    saliency variable or mirror descent - L_task + rho/2||S(W)||^2 + l2||W||^2.
    Final scores are S(W_final)."""
    key = jax.random.fold_in(rng, step)

    def total(Wp):
        loss, aux = loss_fn(Wp, batch)
        S = metrics_mod.metric_tree(pcfg.local_metric, Wp, stats, prunable,
                                    key=key, stoch_frac=pcfg.stoch_frac)
        reg = jnp.zeros((), jnp.float32)
        wreg = jnp.zeros((), jnp.float32)
        for s, (w, p) in zip(
                jax.tree.leaves(S, is_leaf=lambda x: x is None),
                zip(jax.tree.leaves(Wp), jax.tree.leaves(prunable))):
            if s is None or not p:
                continue
            reg += jnp.sum(jnp.square(s))
            wreg += jnp.sum(jnp.square(w))
        return loss + 0.5 * pcfg.rho * reg + l2 * wreg, aux

    (loss, _), g = jax.value_and_grad(total, has_aux=True)(W)
    W = jax.tree.map(lambda w, gg: w - pcfg.kappa * pcfg.lr * gg, W, g)
    return W, loss


def export_masks(pcfg: PruneConfig, Gamma: PyTree, sparsity: float,
                 *, V: PyTree | None = None, exact: bool = True) -> PyTree:
    """One-shot mask extraction from the final Gamma (any sparsity level).

    Soft-thresholded-to-zero entries are tied at |Gamma|=0; the dual V
    retains their sub-threshold saliency, so it breaks ties at an epsilon
    scale that cannot reorder any nonzero Gamma entries.
    """
    scores = Gamma
    if V is not None:
        gmax = max((float(jnp.max(jnp.abs(g))) for g in
                    jax.tree.leaves(Gamma, is_leaf=lambda x: x is None)
                    if g is not None), default=0.0)
        vmax = max((float(jnp.max(jnp.abs(v))) for v in
                    jax.tree.leaves(V, is_leaf=lambda x: x is None)
                    if v is not None), default=1.0)
        eps = 1e-6 * max(gmax, 1e-30) / max(vmax, 1e-30) if gmax > 0 \
            else 1.0 / max(vmax, 1e-30)
        scores = jax.tree.map(
            lambda g, v: None if g is None else jnp.abs(g) + eps * jnp.abs(v),
            Gamma, V, is_leaf=lambda x: x is None)
    if pcfg.mode == "nm":
        return masks_mod.nm_masks(scores, pcfg.nm_n, pcfg.nm_m)
    return masks_mod.unstructured_masks(scores, sparsity, scope="global",
                                        exact=exact)
