"""Proximal operators.

* ``soft_threshold``    - Prox of lam*||.||_1 (the paper's Omega on Gamma).
* ``prox_nm24``         - Prox of the 2:4-inducing regularizer (Kuebler et
  al., arXiv:2501.18015)  R(w) = |w1||w2||w3| + |w2||w3||w4| + |w3||w4||w1| +
  |w4||w1||w2| applied to each contiguous group of 4 along the input dim.
  Solved per group by a damped Jacobi fixed point on the KKT system
      u_i = max(0, |w_i| - lam * sum_{pairs (j,k) != i} u_j u_k),
  signs restored afterwards.  For lam -> inf this zeroes all but the two
  largest magnitudes (exact 2:4); for small lam it shrinks toward it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def soft_threshold(v: jax.Array, lam: float) -> jax.Array:
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - lam, 0.0)


def _pairsum_others(u: jax.Array) -> jax.Array:
    """For u (..., 4): dR/du_i = sum of products of pairs of the other 3."""
    u1, u2, u3, u4 = [u[..., i] for i in range(4)]
    g1 = u2 * u3 + u3 * u4 + u4 * u2
    g2 = u1 * u3 + u3 * u4 + u4 * u1
    g3 = u1 * u2 + u2 * u4 + u4 * u1
    g4 = u1 * u2 + u2 * u3 + u3 * u1
    return jnp.stack([g1, g2, g3, g4], axis=-1)


def prox_nm24(w: jax.Array, lam: float, *, iters: int = 12,
              damping: float = 0.7) -> jax.Array:
    """Prox of lam*R_{2:4} on groups of 4 along the second-to-last dim.

    w: (*lead, d_in, d_out) with d_in % 4 == 0.  Groups are contiguous along
    d_in (the GEMM reduction dim, matching 2:4 hardware layout).
    """
    *lead, d_in, d_out = w.shape
    assert d_in % 4 == 0, d_in
    wf = w.astype(jnp.float32)
    g = jnp.moveaxis(wf.reshape(*lead, d_in // 4, 4, d_out), -2, -1)
    absw = jnp.abs(g)  # (*lead, d_in//4, d_out, 4)

    def body(u, _):
        u_new = jnp.maximum(absw - lam * _pairsum_others(u), 0.0)
        return damping * u_new + (1 - damping) * u, None

    u, _ = jax.lax.scan(body, absw, None, length=iters)
    out = jnp.sign(g) * u
    out = jnp.moveaxis(out, -1, -2).reshape(*lead, d_in, d_out)
    return out.astype(w.dtype)


def prox_nm24_ref(w: jax.Array, lam: float) -> jax.Array:
    """Brute-force oracle: joint gradient projection on the 4-vector prox
    objective 0.5||u - |w|||^2 + lam R(u), u >= 0 (tests only)."""
    *lead, d_in, d_out = w.shape
    g = jnp.moveaxis(
        w.astype(jnp.float32).reshape(*lead, d_in // 4, 4, d_out), -2, -1)
    absw = jnp.abs(g)

    def obj(u):
        u1, u2, u3, u4 = [u[..., i] for i in range(4)]
        r = u1 * u2 * u3 + u2 * u3 * u4 + u3 * u4 * u1 + u4 * u1 * u2
        return 0.5 * jnp.sum((u - absw) ** 2) + lam * jnp.sum(r)

    u = absw
    lr = 0.05
    for _ in range(2000):
        u = jnp.maximum(u - lr * jax.grad(obj)(u), 0.0)
    out = jnp.sign(g) * u
    return jnp.moveaxis(out, -1, -2).reshape(*lead, d_in, d_out).astype(w.dtype)
