"""Which parameters UniPruning prunes.

The paper targets "MLP layers and attention projection layers": every 2-D+
projection kernel, excluding embeddings, routers, convs, norms, positional
tables and small adapters.  Expert tensors (E, d_in, d_out) are included with
their leading expert dim treated as batch.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

EXCLUDE_SUBSTRINGS = (
    "embed", "lm_head", "router", "conv", "pos_embed", "vit_proj",
    "frame_proj", "lora_", "['r']",  # sLSTM recurrent gate kernel: kept dense
)


def is_prunable_path(pathstr: str, leaf: Any) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if "kernel" not in pathstr:
        return False
    return not any(s in pathstr for s in EXCLUDE_SUBSTRINGS)


def prunable_map(params: Any) -> Any:
    """Pytree of bools (True = prunable) matching params."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = [is_prunable_path(jax.tree_util.keystr(kp), leaf)
           for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def count_prunable(params: Any) -> tuple[int, int]:
    """(prunable_param_count, total_param_count)."""
    pm = prunable_map(params)
    tot = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    pru = sum(int(np.prod(x.shape))
              for x, m in zip(jax.tree.leaves(params), jax.tree.leaves(pm),
                              strict=True)
              if m)
    return pru, tot
