"""Local saliency metrics S(W, X).

All metrics operate on a kernel W of shape (*lead, d_in, d_out) with optional
activation stats a of shape (*lead, d_in) = per-input-feature RMS norm over
the calibration set.  When a is None they gracefully degrade to their
weight-only form (magnitude).

  magnitude : |W|                                     (Zhu & Gupta 2017)
  wanda     : |W| * a[..., None]                      (Sun et al. 2024)
  ria       : (|W|/rowsum + |W|/colsum) * a^0.5       (Zhang et al. 2024)
  stochria  : RIA with subsampled row/col sums        (Yi & Richtarik 2025)

These are differentiable in W (abs subgradient), which the mirror-descent
alignment term relies on.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

METRICS = ("magnitude", "wanda", "ria", "stochria")


def magnitude(w: jax.Array, a=None, *, key=None) -> jax.Array:
    return jnp.abs(w.astype(jnp.float32))


def wanda(w: jax.Array, a=None, *, key=None) -> jax.Array:
    s = jnp.abs(w.astype(jnp.float32))
    if a is not None:
        s = s * a[..., None]
    return s


def _ria_core(w, a, row_w=None, col_w=None, eps=1e-12):
    aw = jnp.abs(w.astype(jnp.float32))
    # rowsum: over d_out for each input row; colsum: over d_in per output col
    if row_w is None:
        rowsum = jnp.sum(aw, axis=-1, keepdims=True)
        colsum = jnp.sum(aw, axis=-2, keepdims=True)
    else:
        rowsum = jnp.sum(aw * row_w, axis=-1, keepdims=True) / \
            jnp.mean(row_w)
        colsum = jnp.sum(aw * col_w, axis=-2, keepdims=True) / \
            jnp.mean(col_w)
    s = aw / (rowsum + eps) + aw / (colsum + eps)
    if a is not None:
        s = s * jnp.sqrt(jnp.maximum(a, 1e-12))[..., None]
    return s


def ria(w: jax.Array, a=None, *, key=None) -> jax.Array:
    return _ria_core(w, a)


def stochria(w: jax.Array, a=None, *, key=None, frac: float = 0.9) -> jax.Array:
    """RIA with Bernoulli-subsampled row/col sums (stochastic normalizers)."""
    if key is None:
        return _ria_core(w, a)
    k1, k2 = jax.random.split(key)
    row_w = jax.random.bernoulli(k1, frac, w.shape[-1:]).astype(jnp.float32)
    col_w = jax.random.bernoulli(k2, frac, w.shape[-2:-1]).astype(jnp.float32)
    return _ria_core(w, a, row_w=row_w, col_w=col_w[..., :, None])


def get_metric(name: str, stoch_frac: float = 0.9):
    if name == "magnitude":
        return magnitude
    if name == "wanda":
        return wanda
    if name == "ria":
        return ria
    if name == "stochria":
        return partial(stochria, frac=stoch_frac)
    raise ValueError(f"unknown metric {name!r}; options: {METRICS}")


def normalize_scores(s: jax.Array, how: str) -> jax.Array:
    """Per-tensor scale normalization: makes saliency cross-layer comparable
    so ONE global budget can redistribute sparsity across layers (the
    paper's 'global controller'; see DESIGN.md #8 and EXPERIMENTS.md)."""
    if how == "none":
        return s
    # The normalizer is a per-tensor scale CONSTANT (not part of the
    # saliency geometry): stop_gradient keeps the alignment gradient on the
    # scores themselves and avoids differentiating through sort.
    if how == "mean":
        return s / (jax.lax.stop_gradient(jnp.mean(s)) + 1e-12)
    if how == "median":
        # jnp.median's quantile->gather lowering is broken in this jaxlib;
        # sort + static middle index is equivalent for our (flat) use.
        flat = jax.lax.stop_gradient(s.reshape(-1))
        med = jnp.sort(flat)[flat.size // 2]
        return s / (med + 1e-12)
    raise ValueError(how)


def metric_tree(name: str, params: Any, stats: Any, prunable: Any,
                key: jax.Array | None = None, stoch_frac: float = 0.9,
                norm: str = "none") -> Any:
    """Apply the metric leafwise over prunable kernels; None elsewhere."""
    fn = get_metric(name, stoch_frac)
    leaves, treedef = jax.tree_util.tree_flatten(params)
    flat_stats, _ = jax.tree_util.tree_flatten(
        stats, is_leaf=lambda x: x is None)
    flat_pr, _ = jax.tree_util.tree_flatten(prunable)
    # stats now come from two implementations (jitted pass / eager tape) and
    # from persisted bank artifacts: refuse silent leaf misalignment.
    if len(flat_stats) != len(leaves) or len(flat_pr) != len(leaves):
        raise ValueError(
            f"metric_tree leaf mismatch: params={len(leaves)} "
            f"stats={len(flat_stats)} prunable={len(flat_pr)} leaves - the "
            "stats/prunable trees must mirror the params structure")
    out = []
    for i, (w, a, pr) in enumerate(zip(leaves, flat_stats, flat_pr)):
        if not pr:
            out.append(None)
            continue
        k = None if key is None else jax.random.fold_in(key, i)
        out.append(normalize_scores(fn(w, a, key=k), norm))
    return jax.tree_util.tree_unflatten(treedef, out)
