"""End-to-end UniPruning calibration drivers.

collect_stats   - one eager, unrolled pass over the calibration set with the
                  stats tape (Algorithm 1, line 1).
run_search      - N jitted mirror-descent steps (lines 3-12).
unipruning_prune- full pipeline: stats -> search -> Gamma -> masks(W0) at any
                  requested sparsity levels (one search, many budgets).
baseline_masks  - one-shot local-metric baselines (Magnitude/Wanda/RIA/
                  stochRIA) sharing the same stats and mask machinery.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import masks as masks_mod
from repro.core import metrics as metrics_mod
from repro.core import mirror
from repro.core import tape as tape_mod
from repro.core.prunable import prunable_map
from repro.optim.losses import lm_loss

PyTree = Any


def collect_stats(cfg: ModelConfig, params: PyTree,
                  batches: Iterable[dict]) -> PyTree:
    t = tape_mod.StatsTape()
    with tape_mod.recording(t):
        for b in batches:
            lm_loss(cfg, params, b, unroll=True)
    return tape_mod.resolve_stats(t, params)


def run_search(cfg: ModelConfig, pcfg: PruneConfig, params0: PyTree,
               batches: list[dict], stats: PyTree, *,
               log_every: int = 0, loss_fn: Callable | None = None):
    """Returns (final state, history)."""
    prunable = prunable_map(params0)
    loss_fn = loss_fn or partial(lm_loss, cfg)
    state = mirror.init_search(params0, jax.random.key(17))
    # prunable (static bools) and stats close over the jitted step
    step_fn = jax.jit(lambda st, b: mirror.search_step(
        pcfg, loss_fn, st, b, stats, prunable))
    history = []
    for n in range(pcfg.steps):
        batch = batches[n % len(batches)]
        state, m = step_fn(state, batch)
        if log_every and n % log_every == 0:
            history.append({k: float(v) for k, v in m.items()})
    return state, history


def unipruning_prune(cfg: ModelConfig, pcfg: PruneConfig, params0: PyTree,
                     calib_batches: list[dict],
                     sparsities: Iterable[float] = (0.5,),
                     loss_fn: Callable | None = None):
    """Full pipeline. Returns {sparsity: pruned_params}, Gamma, history."""
    stats = collect_stats(cfg, params0, calib_batches[:4])
    state, history = run_search(cfg, pcfg, params0, calib_batches, stats,
                                log_every=10, loss_fn=loss_fn)
    out = {}
    for s in sparsities:
        masks = mirror.export_masks(pcfg, state.Gamma, s, V=state.V)
        out[s] = masks_mod.apply_masks(params0, masks)
    return out, state, history


def baseline_masks(method: str, params0: PyTree, stats: PyTree,
                   sparsity: float, *, mode: str = "unstructured",
                   scope: str = "row", nm: tuple[int, int] = (2, 4),
                   key: jax.Array | None = None) -> PyTree:
    """Local-metric one-shot baselines (no search stage)."""
    prunable = prunable_map(params0)
    S = metrics_mod.metric_tree(method, params0, stats, prunable, key=key)
    if mode == "nm":
        return masks_mod.nm_masks(S, *nm)
    if method == "magnitude" and scope == "row":
        scope = "layer"  # magnitude baseline is layer-wise in the paper
    return masks_mod.unstructured_masks(S, sparsity, scope=scope)
