"""End-to-end UniPruning calibration drivers.

collect_stats   - activation stats over the calibration set (Algorithm 1,
                  line 1).  impl="jit" (default): the mesh-shardable
                  ``models.model.stats_sumsq`` pass, one compiled dispatch
                  per batch with per-layer stats stacked by ``lax.scan``.
                  impl="tape": the eager, unrolled StatsTape pass - the
                  parity oracle, asserted against the jitted pass in tests.
run_search      - N mirror-descent steps (lines 3-12), executed as
                  ``lax.scan``-chunked jitted dispatches with donated state
                  buffers; pass ``rules`` to run the whole search with
                  W/Gamma/V sharded on the mesh via ``dist.sharding``.
unipruning_prune- full pipeline: stats -> search -> Gamma -> masks(W0) at any
                  requested sparsity levels (one search, many budgets).
baseline_masks  - one-shot local-metric baselines (Magnitude/Wanda/RIA/
                  stochRIA) sharing the same stats and mask machinery.

Process-level entry point: ``repro.launch.calibrate`` runs stats -> search
once and persists the result as a ``sparse.bank.MaskBank`` artifact that
serving and the benchmarks consume without ever re-running this module.
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Any, Callable, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis import recompile
from repro.configs.base import ModelConfig, PruneConfig
from repro.core import masks as masks_mod
from repro.core import metrics as metrics_mod
from repro.core import mirror
from repro.core import tape as tape_mod
from repro.core.prunable import prunable_map
from repro.optim.losses import lm_loss

PyTree = Any

_is_none = lambda x: x is None


@functools.lru_cache(maxsize=None)
def _jit_stats_fn(cfg: ModelConfig):
    from repro.models import model as M
    return jax.jit(lambda p, b: M.stats_sumsq(cfg, p, b))


def collect_stats(cfg: ModelConfig, params: PyTree, batches: Iterable[dict],
                  *, impl: str = "jit", pcfg: PruneConfig | None = None,
                  rules=None) -> PyTree:
    """Per-input-feature ||X_j||_2 over the calibration set.

    pcfg: when given, only the first ``pcfg.stats_batches`` batches feed the
    pass (the one place that policy lives).  rules: installed sharding rules
    for the jitted pass - batches are device_put over the data axes and the
    model's own constraints shard the activations.
    """
    batches = list(batches)
    if pcfg is not None:
        batches = batches[:pcfg.stats_batches]
    assert batches, "collect_stats needs at least one calibration batch"

    if impl == "tape":
        t = tape_mod.StatsTape()
        with tape_mod.recording(t):
            for b in batches:
                lm_loss(cfg, params, b, unroll=True)
        return tape_mod.resolve_stats(t, params)
    if impl != "jit":
        raise ValueError(f"unknown stats impl {impl!r}; options: jit, tape")

    from repro.dist import axes as axes_mod
    from repro.dist import sharding as sharding_mod
    from repro.models import model as M
    fwd = _jit_stats_fn(cfg) if rules is None else \
        jax.jit(lambda p, b: M.stats_sumsq(cfg, p, b))
    ctx = axes_mod.use_rules(rules) if rules is not None else None
    acc = None
    try:
        if ctx is not None:
            ctx.__enter__()
        for b in batches:
            b = {k: jnp.asarray(v) for k, v in b.items()}
            if rules is not None:
                b = jax.device_put(b, sharding_mod.batch_sharding_tree(
                    b, rules.mesh))
            ss = fwd(params, b)
            acc = ss if acc is None else jax.tree.map(
                lambda a, s: None if a is None else a + s, acc, ss,
                is_leaf=_is_none)
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
    return jax.tree.map(lambda a: None if a is None else jnp.sqrt(a),
                        acc, is_leaf=_is_none)


def stats_parity(tape_stats: PyTree, jit_stats: PyTree, prunable: PyTree,
                 *, tol: float = 5e-2) -> tuple[float, bool, int]:
    """(worst per-prunable-leaf relative Frobenius error, pass flag, leaves).

    The shared parity criterion between the jitted pass and the tape
    oracle, used by both the test suite and the calibrate bench gate.
    Aggregate (not elementwise) on purpose: eager-vs-compiled execution can
    flip MoE top-k routing for near-tied experts, moving single rows
    between expert stats; the norm bounds that noise while catching real
    bugs (e.g. a dropped per-expert rescale shifts whole rows ~2x).
    """
    worst = 0.0
    checked = 0
    for t, j, p in zip(jax.tree.leaves(tape_stats, is_leaf=_is_none),
                       jax.tree.leaves(jit_stats, is_leaf=_is_none),
                       jax.tree.leaves(prunable), strict=True):
        if not p:
            continue
        assert t is not None, "tape missed a prunable leaf"
        assert j is not None, "jitted pass missed a prunable leaf"
        t, j = np.asarray(t, np.float64), np.asarray(j, np.float64)
        assert t.shape == j.shape, (t.shape, j.shape)
        worst = max(worst, float(np.linalg.norm(t - j) /
                                 (np.linalg.norm(t) + 1e-12)))
        checked += 1
    return worst, bool(worst <= tol) and checked > 0, checked


def _stack_chunk(batches: list[dict], start: int, length: int) -> dict:
    """Host-side stack of the next ``length`` calibration batches (cycled)."""
    sel = [batches[(start + j) % len(batches)] for j in range(length)]
    return jax.tree.map(
        lambda *xs: jnp.asarray(np.stack([np.asarray(x) for x in xs])), *sel)


def make_chunk_fn(pcfg: PruneConfig, loss_fn: Callable, stats: PyTree,
                  prunable: PyTree) -> Callable:
    """The search-chunk hot path: (state, stacked_batches) -> (state, ms).

    Exposed standalone so ``repro.analysis`` can register the exact function
    ``run_search`` jits (with ``donate_argnums=0``) as an audit surface -
    contract checks walk the jaxpr of THIS fn, not a lookalike.
    """
    def chunk_fn(st, stacked):
        return jax.lax.scan(
            lambda s, b: mirror.search_step(pcfg, loss_fn, s, b, stats,
                                            prunable),
            st, stacked)
    return chunk_fn


def run_search(cfg: ModelConfig, pcfg: PruneConfig, params0: PyTree,
               batches: list[dict], stats: PyTree, *,
               log_every: int = 0, loss_fn: Callable | None = None,
               rules=None, scan_chunk: int | None = None):
    """Returns (final state, history).

    The search runs as jitted ``lax.scan`` chunks of ``pcfg.scan_chunk``
    steps (override with ``scan_chunk``; <= 1 falls back to one dispatch
    per step) with the SearchState donated into each dispatch, so the three
    fp32 trees are updated in place instead of double-buffered.  With
    ``rules`` the state is placed via ``dist.sharding.search_state_sharding``
    and every chunk's stacked batches shard over the data axes - W/Gamma/V
    live distributed on the mesh for the whole search.
    """
    prunable = prunable_map(params0)
    loss_fn = loss_fn or partial(lm_loss, cfg)
    state = mirror.init_search(params0, jax.random.key(17))
    if rules is not None:
        from repro.dist import sharding as sharding_mod
        from repro.models import model as M
        state = jax.device_put(state, sharding_mod.search_state_sharding(
            M.param_axes(cfg), state, rules))
    batches = list(batches)
    chunk = pcfg.scan_chunk if scan_chunk is None else scan_chunk
    chunk = max(int(chunk), 0)
    history: list[dict] = []
    # series keys the flight recorder traces per chunk (convergence is the
    # paper's whole argument for global feedback - the trajectory must be
    # observable without re-running the search)
    _TRACE = ("loss", "align", "mask_churn", "gamma_entropy")

    def record(metrics_stack, start, length):
        """Fold one chunk's stacked metrics into history + the trace.

        Called with per-step metric arrays of shape (length,) - both the
        scanned path (real lax.scan outputs) and the eager path (a stack of
        one) land here, so logging and tracing behave identically.  Pulls
        to host exactly once per chunk, and only when someone is listening.
        """
        emit = obs.enabled()
        if not log_every and not emit:
            return
        host = {k: np.asarray(v) for k, v in metrics_stack.items()}
        if emit:
            sparsity = [float(1.0 - v) for v in host["gamma_nonzero_frac"]]
            obs.log("calibrate.search_chunk", start=start, steps=length,
                    sparsity=sparsity,
                    **{k: [float(x) for x in host[k]] for k in _TRACE
                       if k in host})
            obs.inc("calibrate.search_steps", length)
            obs.set_gauge("calibrate.gamma_entropy",
                          float(host["gamma_entropy"][-1]))
            obs.set_gauge("calibrate.mask_churn",
                          float(host["mask_churn"][-1]))
            obs.set_gauge("calibrate.sparsity", sparsity[-1])
        if log_every:
            for j in range(length):
                if (start + j) % log_every == 0:
                    history.append({k: float(v[j]) for k, v in host.items()})

    if chunk <= 1:  # eager: one jitted dispatch per step
        step_fn = jax.jit(
            lambda st, b: mirror.search_step(pcfg, loss_fn, st, b, stats,
                                             prunable),
            donate_argnums=0)
        for n in range(pcfg.steps):
            b = batches[n % len(batches)]
            recompile.note("search_step", (state, b))
            sp = obs.span("calibrate.search_step", step=n)
            with sp:
                state, m = step_fn(state, b)
                sp.fence(m)
            record({k: jnp.asarray(v)[None] for k, v in m.items()}, n, 1)
        return state, history

    chunk_jit = jax.jit(make_chunk_fn(pcfg, loss_fn, stats, prunable),
                        donate_argnums=0)
    n = 0
    while n < pcfg.steps:
        c = min(chunk, pcfg.steps - n)
        stacked = _stack_chunk(batches, n, c)
        if rules is not None:
            from repro.dist import sharding as sharding_mod
            stacked = jax.device_put(
                stacked,
                sharding_mod.stacked_batch_sharding(stacked, rules.mesh))
        recompile.note("search_chunk", (state, stacked))
        # fencing on the chunk's metric stack charges device time to the
        # chunk span; with the recorder off there is no fence and dispatch
        # stays fully async (record() then pulls nothing either)
        sp = obs.span("calibrate.search_chunk", start=n, steps=c)
        with sp:
            state, ms = chunk_jit(state, stacked)
            sp.fence(ms)
        record(ms, n, c)
        n += c
    return state, history


def unipruning_prune(cfg: ModelConfig, pcfg: PruneConfig, params0: PyTree,
                     calib_batches: list[dict],
                     sparsities: Iterable[float] = (0.5,),
                     loss_fn: Callable | None = None, *,
                     stats_impl: str = "jit", rules=None):
    """Full pipeline. Returns {sparsity: pruned_params}, Gamma, history."""
    stats = collect_stats(cfg, params0, calib_batches, pcfg=pcfg,
                          impl=stats_impl, rules=rules)
    state, history = run_search(cfg, pcfg, params0, calib_batches, stats,
                                log_every=10, loss_fn=loss_fn, rules=rules)
    out = {}
    for s in sparsities:
        masks = mirror.export_masks(pcfg, state.Gamma, s, V=state.V)
        out[s] = masks_mod.apply_masks(params0, masks)
    return out, state, history


def baseline_masks(method: str, params0: PyTree, stats: PyTree,
                   sparsity: float, *, mode: str = "unstructured",
                   scope: str = "row", nm: tuple[int, int] = (2, 4),
                   key: jax.Array | None = None) -> PyTree:
    """Local-metric one-shot baselines (no search stage)."""
    prunable = prunable_map(params0)
    S = metrics_mod.metric_tree(method, params0, stats, prunable, key=key)
    if mode == "nm":
        return masks_mod.nm_masks(S, *nm)
    if method == "magnitude" and scope == "row":
        scope = "layer"  # magnitude baseline is layer-wise in the paper
    return masks_mod.unstructured_masks(S, sparsity, scope=scope)
