"""Whisper-small backbone: 12L enc + 12L dec, layernorm/gelu, conv frontend
stubbed as precomputed frame embeddings [arXiv:2212.04356]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", d_model=768, num_layers=12,
    num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072, vocab_size=51865,
    pattern=("dec",), encoder_layers=12, norm="layernorm", act="gelu",
    use_rope=False, tie_embeddings=True, norm_eps=1e-5,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=2, encoder_layers=2, num_heads=4,
    num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512)
