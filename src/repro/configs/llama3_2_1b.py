"""Llama-3.2-1B [hf:meta-llama/Llama-3.2-1B] - the paper's own eval model."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", d_model=2048, num_layers=16,
    num_heads=32, num_kv_heads=8, head_dim=64, d_ff=8192, vocab_size=128256,
    rope_theta=500000.0, tie_embeddings=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=4, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512)
