"""Yi-6B: llama-arch dense GQA [arXiv:2403.04652; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense", d_model=4096, num_layers=32, num_heads=32,
    num_kv_heads=4, head_dim=128, d_ff=11008, vocab_size=64000,
    rope_theta=5e6, tie_embeddings=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=4, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512)
