"""Mixtral 8x22B: 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe", d_model=6144, num_layers=56,
    num_heads=48, num_kv_heads=8, head_dim=128, d_ff=16384, vocab_size=32768,
    pattern=("moe_local",), sliding_window=4096,
    num_experts=8, top_k=2, moe_d_ff=16384, rope_theta=1e6,
    tie_embeddings=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=4, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, moe_d_ff=256, vocab_size=512, num_experts=4,
    sliding_window=16)
