"""Model / run configuration dataclasses and the architecture registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|hybrid|ssm|audio|vlm
    d_model: int
    num_layers: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # layer-kind pattern, cycled over num_layers (see models/blocks.py)
    pattern: tuple[str, ...] = ("attn",)
    pattern_prefix: tuple[str, ...] = ()   # e.g. deepseek first-dense layer
    # attention
    rope_theta: float = 10000.0
    local_rope_theta: float = 0.0   # 0 -> use rope_theta for local layers too
    sliding_window: int = 0
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    qk_norm: bool = False
    attn_scale: float = 0.0         # 0 -> head_dim**-0.5
    sandwich_norm: bool = False     # gemma2-style post-block norms
    tie_embeddings: bool = True
    scale_embed: bool = False       # gemma: embed * sqrt(d_model)
    # MLA (deepseek)
    kv_lora: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    # xLSTM
    lstm_heads: int = 4
    lstm_proj_factor: float = 2.0
    # zamba-style shared attention block
    lora_rank: int = 0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    # VLM (pixtral)
    vit_dim: int = 0
    num_image_tokens: int = 0
    # norms / activations
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "silu"
    use_rope: bool = True
    norm_eps: float = 1e-6
    # serving: end-of-sequence token id terminating a decode slot
    # (None -> generation stops on max_tokens only)
    eos_id: int | None = None

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        n = self.num_layers - len(self.pattern_prefix)
        return self.pattern_prefix + tuple(
            self.pattern[i % len(self.pattern)] for i in range(n))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """True if a 500k-token decode is sub-quadratic-serviceable: SSM /
        hybrid state or bounded sliding windows on most layers."""
        kinds = set(self.layer_kinds)
        if kinds & {"mamba", "mamba_shared", "mlstm", "slstm"}:
            return True
        return "local" in kinds  # gemma-style alternating local layers


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str                        # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_serve(self) -> bool:
        return self.kind != "train"


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "zamba2-7b", "mixtral-8x22b", "deepseek-v2-lite-16b", "whisper-small",
    "yi-6b", "gemma2-2b", "llama3.2-1b", "gemma3-1b", "pixtral-12b",
    "xlstm-125m",
]


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{arch.replace('-', '_').replace('.', '_')}")
    return mod.SMOKE_CONFIG


@dataclasses.dataclass(frozen=True)
class PruneConfig:
    """UniPruning search-stage hyperparameters (paper §5: lr 1e-4, λ=1e-3)."""
    local_metric: str = "stochria"   # magnitude | wanda | ria | stochria
    mode: str = "unstructured"       # unstructured | nm
    nm_n: int = 2
    nm_m: int = 4
    rho: float = 1e-5                # alignment weight (paper Table 5)
    lam: float = 1e-3                # Omega = lam * L1 (paper A.3.3)
    kappa: float = 1.0
    lr: float = 1e-4                 # alpha
    # Effective dual step alpha*rho for the V update.  The paper's raw
    # product (1e-9) needs ~1e5 steps at LLM activation scales; v_lr plays
    # the same role with a calibration-friendly default (see DESIGN.md #8).
    v_lr: float = 0.1
    steps: int = 100
    # Per-tensor score normalization anchoring Gamma to cross-layer-
    # comparable saliency; "none" = paper-faithful raw scores.
    score_norm: str = "median"
    nm_prox_weight: float = 1e-2     # strength of R_{2:4} prox on W
    stoch_frac: float = 0.9          # stochRIA row/col sampling fraction
    # -- calibration-pipeline execution knobs (PR 5) ------------------------
    # How many calibration batches feed the stats pass (the single source of
    # truth for what used to be ad-hoc calib[:4] / calib[:3] slicing).
    stats_batches: int = 4
    # Mirror-descent steps per jitted lax.scan dispatch; <= 1 keeps the
    # eager one-dispatch-per-step loop (debug / bench baseline).
    scan_chunk: int = 8
    # Microbatches per search step: the task gradient is accumulated over
    # batch-dim slices of each calibration batch, shrinking activation
    # memory at fixed effective batch.  1 = off.
    grad_accum: int = 1
