"""xLSTM-125M: alternating mLSTM / sLSTM blocks [arXiv:2405.04517]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm", d_model=768, num_layers=12,
    num_heads=4, num_kv_heads=4, head_dim=192, d_ff=0, vocab_size=50304,
    pattern=("mlstm", "slstm"), lstm_heads=4, lstm_proj_factor=2.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=64, num_layers=4, num_heads=2, num_kv_heads=2,
    head_dim=32, vocab_size=512, lstm_heads=2)
