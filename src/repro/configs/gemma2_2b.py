"""Gemma-2 2B: local/global alternation, logit softcaps, sandwich norms
[arXiv:2408.00118; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense", d_model=2304, num_layers=26,
    num_heads=8, num_kv_heads=4, head_dim=256, d_ff=9216, vocab_size=256000,
    pattern=("local", "attn"), sliding_window=4096,
    attn_softcap=50.0, final_softcap=30.0, sandwich_norm=True,
    scale_embed=True, act="gelu", tie_embeddings=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=4, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512, sliding_window=16)
