"""Gemma-3 1B: 5:1 local:global, window 512, QK-norm, dual rope thetas
[hf:google/gemma-3-1b-pt]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense", d_model=1152, num_layers=26,
    num_heads=4, num_kv_heads=1, head_dim=256, d_ff=6912, vocab_size=262144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    sliding_window=512, rope_theta=1e6, local_rope_theta=1e4, qk_norm=True,
    scale_embed=True, act="gelu", tie_embeddings=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=8, num_heads=4, num_kv_heads=1,
    head_dim=32, d_ff=256, vocab_size=512, sliding_window=16)
