"""Pixtral-12B backbone: mistral-nemo decoder + stubbed pixtral-ViT patch
embeddings [hf:mistralai/Pixtral-12B-2409]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm", d_model=5120, num_layers=40,
    num_heads=32, num_kv_heads=8, head_dim=128, d_ff=14336,
    vocab_size=131072, rope_theta=1e6, vit_dim=1024, num_image_tokens=256,
    tie_embeddings=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=4, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512, vit_dim=64, num_image_tokens=8)
