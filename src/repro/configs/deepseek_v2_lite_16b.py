"""DeepSeek-V2-Lite 16B: MLA (kv_lora=512) + 2 shared / 64 routed top-6 MoE,
first layer dense (d_ff 10944) [arXiv:2405.04434; hf]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe", d_model=2048, num_layers=27,
    num_heads=16, num_kv_heads=16, head_dim=128, d_ff=10944,
    vocab_size=102400, pattern=("mla_moe",), pattern_prefix=("mla_dense",),
    kv_lora=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    num_experts=64, top_k=6, moe_d_ff=1408, num_shared_experts=2,
    tie_embeddings=False,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=3, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, moe_d_ff=64, vocab_size=512, kv_lora=32,
    qk_nope_dim=32, qk_rope_dim=16, v_head_dim=32, num_experts=8, top_k=2,
    num_shared_experts=1)
