"""Zamba2-7B: Mamba2 backbone + weight-shared attention block (every 6th
layer) with per-invocation LoRA [arXiv:2411.15242]."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", d_model=3584, num_layers=81,
    num_heads=32, num_kv_heads=32, head_dim=112, d_ff=14336,
    vocab_size=32000,
    pattern=("mamba", "mamba", "mamba", "mamba", "mamba", "mamba_shared"),
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=64,
    lora_rank=64, tie_embeddings=True,
)

SMOKE_CONFIG = dataclasses.replace(
    CONFIG, d_model=128, num_layers=6, num_heads=4, num_kv_heads=4,
    head_dim=32, d_ff=256, vocab_size=512, ssm_state=16, ssm_head_dim=16,
    ssm_chunk=32, lora_rank=8)
