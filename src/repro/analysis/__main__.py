"""One entry point for the static-analysis toolchain.

  python -m repro.analysis lint src/                     # AST linter
  python -m repro.analysis audit --arch llama3.2-1b \
      --devices 4 --mesh 2x2                             # jaxpr audit
  python -m repro.analysis contracts --arch llama3.2-1b \
      --devices 4 --mesh 2x2 [--update] [--diff-out d.json]
  python -m repro.analysis hlo results/dryrun/tag.hlo.gz # dump attribution
  python -m repro.analysis zoo [--devices 4 --mesh 2x2] \
      [--arch f ...] [--update] [--diff-out d.json]      # whole-zoo dry-run
  python -m repro.analysis zoo --cells --devices 512 \
      --all --out results/dryrun                         # production AOT loop
  python -m repro.analysis memplan --arch llama3.2-1b \
      [--compile] [--fit]                                # memory planner
  python -m repro.analysis shardcheck --arch llama3.2-1b \
      --devices 4 --mesh 2x2                             # sharding checker

``--devices N`` forces N host devices; it MUST be consumed before jax is
imported (XLA fixes the device count at import), which is why this module
parses it by hand first and only then dispatches to subcommands.
"""
from __future__ import annotations

import json
import sys

_USAGE = __doc__


def _force_devices(argv: list[str]) -> list[str]:
    if "--devices" not in argv:
        return argv
    import os
    i = argv.index("--devices")
    n = int(argv[i + 1])
    del argv[i:i + 2]
    assert "jax" not in sys.modules, \
        "--devices must be handled before anything imports jax"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={n}")
    return argv


def _parse_mesh(s: str | None):
    if s in (None, "none", "1dev"):
        return None
    return tuple(int(x) for x in s.split("x"))


def _cmd_audit(rest: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis audit")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--mesh", default="2x2", help="DxM or 'none'")
    ap.add_argument("--search", action="store_true",
                    help="include the calibration search-chunk surface")
    ap.add_argument("--donation", action="store_true",
                    help="compile and report donation aliasing too")
    ap.add_argument("--json", dest="out", default=None)
    a = ap.parse_args(rest)
    from repro.analysis import contracts, surfaces
    mesh = _parse_mesh(a.mesh)
    surfs = surfaces.all_surfaces(a.arch, mesh_shape=mesh,
                                  include_search=a.search or None)
    man = contracts.build_manifest(a.arch, surfs, mesh_shape=mesh,
                                   donation=a.donation)
    text = json.dumps(man, indent=1, sort_keys=True)
    if a.out:
        with open(a.out, "w") as f:
            f.write(text + "\n")
    print(text)
    viols = contracts.policy_violations(man)
    for v in viols:
        print(f"POLICY {v['surface']}.{v['field']}: got {v['got']!r}, "
              f"allowed {v['allowed']!r}", file=sys.stderr)
    return 1 if viols else 0


def _cmd_contracts(rest: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis contracts")
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; default llama3.2-1b")
    ap.add_argument("--mesh", default="2x2")
    ap.add_argument("--dir", default="results/contracts")
    ap.add_argument("--update", action="store_true",
                    help="regenerate goldens instead of checking")
    ap.add_argument("--diff-out", default=None,
                    help="write the structured diff JSON here on failure")
    a = ap.parse_args(rest)
    from repro.analysis import contracts, surfaces
    mesh = _parse_mesh(a.mesh)
    rc = 0
    all_diffs = []
    for arch in (a.arch or ["llama3.2-1b"]):
        surfs = surfaces.all_surfaces(arch, mesh_shape=mesh)
        man = contracts.build_manifest(arch, surfs, mesh_shape=mesh)
        path = contracts.manifest_path(a.dir, arch, mesh)
        if a.update:
            contracts.save(path, man)
            print(f"wrote {path}")
            continue
        ok, diffs = contracts.check(path, man)
        if ok:
            print(f"{path}: OK "
                  f"({len(man['surfaces'])} surfaces, no drift)")
        else:
            rc = 1
            all_diffs.extend(diffs)
            print(f"{path}: CONTRACT DRIFT", file=sys.stderr)
            for d in diffs:
                print(f"  {d['surface']}.{d['field']}: golden="
                      f"{d['golden']!r} current={d['current']!r}",
                      file=sys.stderr)
    if all_diffs and a.diff_out:
        with open(a.diff_out, "w") as f:
            json.dump(all_diffs, f, indent=1)
        print(f"diff written to {a.diff_out}", file=sys.stderr)
    return rc


def _cmd_hlo(rest: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis hlo")
    ap.add_argument("path")
    ap.add_argument("--top", type=int, default=14)
    a = ap.parse_args(rest)
    from repro.launch import hlo_analysis as H
    text = H.load_text(a.path)
    rows = sorted(H.attribution(text), reverse=True)
    print(f"{'bytes':>12s} {'dotflops':>12s} {'coll':>12s} {'mult':>8s} name")
    for b, f, c, m, n in rows[:a.top]:
        print(f"{b:12.3e} {f:12.3e} {c:12.3e} {m:8.0f} {n[:70]}")
    s = H.analyze(text)
    print(f"\nTOTAL bytes {s.bytes_out:.3e} dotflops {s.dot_flops:.3e} "
          f"coll {s.coll_bytes:.3e} whiles {s.n_while} "
          f"trips {sorted(set(s.trip_counts))[:12]}")
    aliases = H.parse_input_output_aliases(text)
    if aliases:
        print(f"input_output_aliases: {len(aliases)}")
    return 0


def _cmd_zoo(rest: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis zoo")
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; default all ten families")
    ap.add_argument("--mesh", default=None,
                    help="DxM (needs --devices DxM's product) or 'none'; "
                         "default none (single device)")
    ap.add_argument("--dir", default="results/contracts/zoo")
    ap.add_argument("--update", action="store_true",
                    help="regenerate goldens instead of checking")
    ap.add_argument("--diff-out", default=None,
                    help="write the structured diff JSON here on failure")
    ap.add_argument("--cells", action="store_true",
                    help="run the production AOT lower/compile loop "
                         "(formerly launch/dryrun.py) instead of the "
                         "abstract dry-run")
    ap.add_argument("--cell", default=None, help="--cells: shape cell name")
    ap.add_argument("--all", action="store_true",
                    help="--cells: every (arch x cell)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--accum", type=int, default=0)
    ap.add_argument("--bf16-cast", action="store_true")
    ap.add_argument("--out", default="results/dryrun",
                    help="--cells: output directory")
    a = ap.parse_args(rest)
    from repro.analysis import zoo
    if a.cells:
        a.arch = a.arch[0] if a.arch else None
        return zoo.run_cells_main(a)
    return zoo.run_zoo(a.arch, mesh_shape=_parse_mesh(a.mesh),
                       zoo_dir=a.dir, update=a.update, diff_out=a.diff_out)


def _cmd_memplan(rest: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis memplan")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--fit", action="store_true",
                    help="print the whole-zoo SearchState fit table "
                         "(full configs) instead of one arch's surfaces")
    ap.add_argument("--full", action="store_true",
                    help="--fit on full (non-smoke) configs")
    ap.add_argument("--compile", action="store_true",
                    help="also compile and report static-vs-compiled drift")
    ap.add_argument("--budget-gb", type=float, default=16.0)
    a = ap.parse_args(rest)
    from repro.analysis import memplan, surfaces
    if a.fit:
        rows = memplan.fit_table(smoke=not a.full, budget_gb=a.budget_gb)
        print(memplan.format_fit_table(rows))
        return 0
    for s in surfaces.serve_surfaces(a.arch, mesh_shape=None, sparse=False):
        if a.compile:
            res = memplan.crosscheck(s.fn, *s.args, surface=s.name,
                                     donate_argnums=s.donate_argnums)
            print(f"{s.name}: static={res['static']} "
                  f"compiled={res['compiled']} rel_err={res['rel_err']:+.3f}"
                  f" bf16_staging={res['bf16_staging_bytes']}")
        else:
            plan = memplan.plan_fn(s.fn, *s.args, surface=s.name,
                                   donate_argnums=s.donate_argnums)
            d = plan.to_dict()
            print(json.dumps(d, indent=1, sort_keys=True))
    sp = memplan.search_plan(a.arch, smoke=True, budget_gb=a.budget_gb)
    print(f"search_state_bytes={sp['state_bytes']}")
    return 0


def _cmd_shardcheck(rest: list[str]) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="repro.analysis shardcheck")
    ap.add_argument("--arch", action="append", default=None,
                    help="repeatable; default llama3.2-1b")
    ap.add_argument("--mesh", default="2x2", help="DxM or 'none'")
    ap.add_argument("--json", dest="out", default=None)
    a = ap.parse_args(rest)
    from repro.analysis import shardcheck
    mesh = _parse_mesh(a.mesh)
    rc = 0
    reports = []
    for arch in (a.arch or ["llama3.2-1b"]):
        rep = shardcheck.check_arch(arch, mesh_shape=mesh)
        reports.append(rep)
        print(shardcheck.format_report(rep))
        if not rep["clean"]:
            rc = 1
    if a.out:
        with open(a.out, "w") as f:
            json.dump(reports, f, indent=1)
    return rc


def main(argv: list[str] | None = None) -> int:
    argv = _force_devices(list(sys.argv[1:] if argv is None else argv))
    if not argv or argv[0] in ("-h", "--help"):
        print(_USAGE)
        return 0
    cmd, rest = argv[0], argv[1:]
    if cmd == "lint":
        from repro.analysis import lint
        return lint.main(rest)
    if cmd == "audit":
        return _cmd_audit(rest)
    if cmd == "contracts":
        return _cmd_contracts(rest)
    if cmd == "hlo":
        return _cmd_hlo(rest)
    if cmd == "zoo":
        return _cmd_zoo(rest)
    if cmd == "memplan":
        return _cmd_memplan(rest)
    if cmd == "shardcheck":
        return _cmd_shardcheck(rest)
    print(f"unknown subcommand {cmd!r}\n{_USAGE}", file=sys.stderr)
    return 2


if __name__ == "__main__":
    sys.exit(main())
