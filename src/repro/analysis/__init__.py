"""Static analysis & trace contracts for the jitted hot paths.

Seven tools, one package:

* :mod:`repro.analysis.lint` - a dependency-free AST linter with the
  repo-specific REPRO001-007 rules (host syncs in hot loops, wall-clock
  timing around async dispatch, silent fallback branches, ``np.`` inside
  kernel bodies, unhashable jit static args, zipped tree leaves,
  clobbered XLA_FLAGS).
* :mod:`repro.analysis.jaxpr_audit` - walks the ClosedJaxpr of a jit
  surface and extracts the primitive histogram, host-callback sites,
  dtype-promotion violations, per-site collective counts (via the
  ``site:`` named scopes the shard-mapped kernels install), and donation
  effectiveness from the compiled HLO's input-output aliasing.
* :mod:`repro.analysis.contracts` - declarative per-surface contract
  manifests with golden JSONs under ``results/contracts/``; drift fails
  loudly with a structured diff.
* :mod:`repro.analysis.recompile` - a recompile sentinel hashing abstract
  avals + static args per surface, asserting at-most-N distinct compiles
  per process (``analysis.recompiles`` obs gauge).
* :mod:`repro.analysis.memplan` - a jaxpr buffer-liveness walk computing
  per-surface peak live HBM bytes and per-pallas_call VMEM footprints
  without compiling, cross-checkable against ``memory_analysis()``, plus
  the SearchState fit table answering at what layer-group size O(sqrt N)
  calibration streaming becomes mandatory.
* :mod:`repro.analysis.shardcheck` - a partition-spec consistency checker
  proving every compressed leaf's K-shard layout divides its mesh axes
  and every shard_map body psum reduces exactly the sharded axes, with
  replicated fallbacks surfaced as structured findings.
* :mod:`repro.analysis.zoo` - the whole-zoo abstract dry-run: the
  calibrate -> bank -> sparsify -> engine-decode -> fleet pipeline traced
  or smoke-run for all ten config families, pinned by golden contracts
  under ``results/contracts/zoo/``; also hosts the production AOT
  lower/compile loop ``launch/dryrun.py`` shims to.

``python -m repro.analysis`` is the CLI: ``lint`` / ``audit`` /
``contracts`` / ``hlo`` / ``zoo`` / ``memplan`` / ``shardcheck``.

This module imports neither jax nor numpy; submodules that need jax
import it themselves, so the linter stays runnable in a bare interpreter
(and in CI jobs that never install the accelerator stack).
"""
