"""Static analysis & trace contracts for the jitted hot paths.

Four tools, one package:

* :mod:`repro.analysis.lint` - a dependency-free AST linter with the
  repo-specific REPRO001-006 rules (host syncs in hot loops, wall-clock
  timing around async dispatch, silent fallback branches, ``np.`` inside
  kernel bodies, unhashable jit static args, zipped tree leaves).
* :mod:`repro.analysis.jaxpr_audit` - walks the ClosedJaxpr of a jit
  surface and extracts the primitive histogram, host-callback sites,
  dtype-promotion violations, per-site collective counts (via the
  ``site:`` named scopes the shard-mapped kernels install), and donation
  effectiveness from the compiled HLO's input-output aliasing.
* :mod:`repro.analysis.contracts` - declarative per-surface contract
  manifests with golden JSONs under ``results/contracts/``; drift fails
  loudly with a structured diff.
* :mod:`repro.analysis.recompile` - a recompile sentinel hashing abstract
  avals + static args per surface, asserting at-most-N distinct compiles
  per process (``analysis.recompiles`` obs gauge).

``python -m repro.analysis`` is the CLI: ``lint`` / ``audit`` /
``contracts`` / ``hlo`` (the per-computation HLO attribution that used to
live in ``benchmarks/hlo_debug.py``).

This module imports neither jax nor numpy; submodules that need jax
import it themselves, so the linter stays runnable in a bare interpreter
(and in CI jobs that never install the accelerator stack).
"""
