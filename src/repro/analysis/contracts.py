"""Trace contracts: golden manifests per jit surface, checked statically.

A contract manifest pins what a surface's jaxpr is ALLOWED to look like:

* ``psums_by_site``  - collectives per traced call site (one scanned layer
  body), e.g. the (2,2)-mesh llama decode contract is
  ``{"mlp": 2, "attn": 4, "attn_kv": 2}`` - identical by construction to
  the flight recorder's trace-time ``dist.psum`` counters;
* ``collectives``    - total collective eqns by canonical primitive;
* ``host_callbacks`` - must be 0 on every hot path;
* ``large_f32_upcasts`` - silent bf16->f32 promotions of large tensors
  (K-partial accumulators inside tagged shard_map bodies are exempt);
* ``arg_bytes`` / ``out_bytes`` / ``dtypes`` - the live-bytes estimate and
  dtype set (catches silent widening of params or caches);
* ``donation_declared`` - leaves declared donated (aliasing effectiveness
  is platform-dependent and stays informational).

Goldens live under ``results/contracts/<arch>_<mesh>.json``.  ``check``
re-audits the surfaces and produces a structured diff against the golden;
any drift fails loudly (CI uploads the diff as an artifact).  Regenerate
on purpose with ``python -m repro.analysis contracts --update``.

Only fields whose values are semantically pinned by OUR code are compared;
volatile facts (primitive histogram, eqn counts - both move with jax/XLA
versions) are stored under ``info`` and ignored by the diff.
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Iterable

from repro.analysis import jaxpr_audit
from repro.analysis.surfaces import Surface

__all__ = ["COMPARE_FIELDS", "build_manifest", "diff_manifests", "check",
           "save", "load", "manifest_path", "policy_violations"]

COMPARE_FIELDS = ("psums_by_site", "collectives", "host_callbacks",
                  "large_f32_upcasts", "dtypes", "arg_bytes", "out_bytes",
                  "donation_declared", "policy")

# standing policy every hot surface must satisfy regardless of golden;
# the upcast ban applies to "serve" surfaces only - "train" surfaces
# upcast weight gradients to f32 in the backward by design, and their
# count is pinned by the golden instead (see surfaces.Surface.policy)
POLICY = {"host_callbacks": 0, "large_f32_upcasts": 0,
          "forbidden_dtypes": ("float64",)}


def _surface_entry(rep: jaxpr_audit.AuditReport, *, policy: str = "serve",
                   donate_declared: int = 0) -> dict:
    return {
        "policy": policy,
        "psums_by_site": dict(sorted(rep.psums_by_site.items())),
        "collectives": dict(sorted(rep.collectives.items())),
        "host_callbacks": len(rep.host_callbacks),
        "large_f32_upcasts": rep.large_f32_upcasts,
        "dtypes": rep.dtypes,
        "arg_bytes": rep.arg_bytes,
        "out_bytes": rep.out_bytes,
        "donation_declared": donate_declared,
        "info": {"n_eqns": rep.n_eqns,
                 "primitives": dict(sorted(rep.primitives.items())),
                 "upcasts": rep.upcasts,
                 "donation": rep.donation},
    }


def build_manifest(name: str, surfaces: Iterable[Surface], *,
                   mesh_shape: tuple | None = None,
                   donation: bool = False) -> dict:
    """Audit every surface and assemble one manifest dict."""
    import jax
    out: dict[str, Any] = {"name": name,
                           "mesh": list(mesh_shape) if mesh_shape else None,
                           "surfaces": {}}
    for s in surfaces:
        rep = jaxpr_audit.audit_fn(s.fn, *s.args, surface=s.name)
        if donation and s.donate_argnums:
            rep.donation = jaxpr_audit.audit_donation(
                s.fn, s.args, s.donate_argnums)
        out["surfaces"][s.name] = _surface_entry(
            rep, policy=s.policy, donate_declared=sum(
                len(jax.tree.leaves(s.args[i])) for i in s.donate_argnums))
    out["info"] = {"jax": jax.__version__,
                   "backend": jax.default_backend()}
    return out


def policy_violations(manifest: dict) -> list[dict]:
    """Standing-policy violations (independent of any golden)."""
    out = []
    for name, e in manifest.get("surfaces", {}).items():
        if e["host_callbacks"] > POLICY["host_callbacks"]:
            out.append({"surface": name, "field": "host_callbacks",
                        "got": e["host_callbacks"], "allowed": 0})
        if (e.get("policy", "serve") == "serve"
                and e["large_f32_upcasts"] > POLICY["large_f32_upcasts"]):
            out.append({"surface": name, "field": "large_f32_upcasts",
                        "got": e["large_f32_upcasts"], "allowed": 0})
        bad = sorted(set(e["dtypes"]) & set(POLICY["forbidden_dtypes"]))
        if bad:
            out.append({"surface": name, "field": "dtypes", "got": bad,
                        "allowed": f"none of {POLICY['forbidden_dtypes']}"})
    return out


def diff_manifests(golden: dict, current: dict,
                   fields: tuple = COMPARE_FIELDS) -> list[dict]:
    """Structured drift between a golden and a freshly-built manifest."""
    diffs = []
    gs = golden.get("surfaces", {})
    cs = current.get("surfaces", {})
    for name in sorted(set(gs) | set(cs)):
        if name not in cs:
            diffs.append({"surface": name, "field": "<surface>",
                          "golden": "present", "current": "missing"})
            continue
        if name not in gs:
            diffs.append({"surface": name, "field": "<surface>",
                          "golden": "missing", "current": "present"})
            continue
        for f in fields:
            g, c = gs[name].get(f), cs[name].get(f)
            if g != c:
                diffs.append({"surface": name, "field": f,
                              "golden": g, "current": c})
    return diffs


def manifest_path(contracts_dir, name: str,
                  mesh_shape: tuple | None) -> pathlib.Path:
    tag = "x".join(str(d) for d in mesh_shape) if mesh_shape else "1dev"
    return pathlib.Path(contracts_dir) / f"{name}_{tag}.json"


def save(path, manifest: dict) -> None:
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(manifest, indent=1, sort_keys=True) + "\n")


def load(path) -> dict:
    return json.loads(pathlib.Path(path).read_text())


def check(golden_path, current: dict) -> tuple[bool, list[dict]]:
    """(ok, diffs) of ``current`` vs the golden at ``golden_path``; a
    missing golden is itself a failure (contracts are committed)."""
    p = pathlib.Path(golden_path)
    if not p.exists():
        return False, [{"surface": "*", "field": "<golden>",
                        "golden": f"missing file {p}", "current": "built"}]
    diffs = diff_manifests(load(p), current)
    diffs.extend({"surface": v["surface"], "field": f"policy:{v['field']}",
                  "golden": v["allowed"], "current": v["got"]}
                 for v in policy_violations(current))
    return not diffs, diffs
