"""Registered jit surfaces for the static auditor and contract checks.

A *surface* is one jitted hot path plus concrete smoke arguments to trace
it with: the ServeEngine step functions (decode, bucketed prefill, the
slot write) and the calibration search chunk.  The registry builds each
exactly the way production does - sparse bf16 params through
``sparse.apply.sparsify_params``, K-shard tags + mesh rules through
``ServeEngine``, the search chunk through ``core.calibrate.make_chunk_fn``
with ``donate_argnums=0`` - so the audited jaxpr IS the served jaxpr, not
a lookalike.

Smoke configs keep tracing cheap (seconds on CPU); the *static* facts the
contracts gate on (collectives per site per layer, zero host callbacks, no
silent f32 upcasts, donation declared) are scale-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

__all__ = ["Surface", "serve_surfaces", "search_surface", "all_surfaces"]


@dataclasses.dataclass
class Surface:
    """One auditable jit entry point with trace-ready arguments.

    policy: "serve" surfaces must have ZERO large bf16->f32 upcasts;
    "train" surfaces legitimately upcast in the backward pass (weight
    gradients convert to f32 at the transpose of the intentional
    ``k.astype(COMPUTE_DTYPE)`` forward downcasts), so their upcast count
    is pinned by the golden instead of forced to zero.
    """
    name: str
    fn: Callable
    args: tuple
    donate_argnums: tuple = ()
    policy: str = "serve"


def _sparse_smoke(arch: str, *, idx_bits: int = 2):
    """Smoke config + 2:4-sparse bf16 compressed params (mirrors the
    serving tests' setup byte for byte)."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import get_smoke_config
    from repro.core import masks as masks_mod
    from repro.core import metrics as metrics_mod
    from repro.core.prunable import prunable_map
    from repro.models import model as M
    from repro.sparse import apply as apply_mod
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    masks = masks_mod.nm_masks(scores)
    sparse = apply_mod.sparsify_params(
        params, masks, axes=M.param_axes(cfg), idx_bits=idx_bits,
        dtype=jnp.bfloat16)
    return cfg, sparse


def serve_surfaces(arch: str = "llama3.2-1b", *,
                   mesh_shape: tuple | None = (2, 2), sparse: bool = True,
                   slots: int = 2, capacity: int = 32,
                   prefill_bucket: int = 8, spec_k: int = 4
                   ) -> list[Surface]:
    """decode / prefill_<bucket> / write_slot / verify_<k> for one smoke
    engine.

    ``verify_<k>`` is the speculative-decode verifier (teacher-forced
    batched pass over k fed tokens, ``serve.spec``); it registers only for
    archs whose layer kinds support spec mode (full-ring attention,
    ``serve.spec.SPEC_SAFE_KINDS``, no sliding window) - the same gate the
    decoder enforces, so the audited surface set matches what serving can
    actually dispatch.

    mesh_shape (data, model) requires that many devices (force host
    devices via ``python -m repro.analysis --devices N ...`` or the
    XLA_FLAGS env); None audits the single-device engine.
    """
    import jax
    import jax.numpy as jnp
    from repro.dist.axes import make_rules
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    from repro.serve.spec import SPEC_SAFE_KINDS
    if sparse:
        cfg, params = _sparse_smoke(arch)
    else:
        from repro.configs.base import get_smoke_config
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.key(0))
    rules = None
    if mesh_shape is not None:
        mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
        rules = make_rules(mesh)
    eng = ServeEngine(cfg, params, slots=slots, capacity=capacity,
                      rules=rules)
    toks = jnp.zeros((slots,), jnp.int32)
    pos = jnp.zeros((slots,), jnp.int32)
    ptoks = jnp.zeros((1, prefill_bucket), jnp.int32)
    # NOTE: the decode surface stays at index 0 (zoo dry-runs and the
    # memory planner key off it); new surfaces append at the end
    out = [
        Surface("decode", eng._decode, (eng.params, toks, eng.caches, pos)),
        Surface(f"prefill_{prefill_bucket}", eng.fns.prefill(prefill_bucket),
                (eng.params, ptoks)),
        Surface("write_slot", eng.fns.write_slot,
                (eng.caches, eng.fns.blank_row(), jnp.int32(0))),
    ]
    if set(cfg.layer_kinds) <= SPEC_SAFE_KINDS and not cfg.sliding_window:
        vtoks = jnp.zeros((slots, spec_k), jnp.int32)
        out.append(Surface(f"verify_{spec_k}", eng.fns.verify(spec_k),
                           (eng.params, vtoks, eng.caches, pos)))
    return out


def search_surface(arch: str = "llama3.2-1b", *, chunk: int = 2,
                   batch: int = 2, seq: int = 32,
                   metric: str = "wanda") -> Surface:
    """The calibration search chunk run_search jits (donated state)."""
    import jax
    from functools import partial
    from repro.configs.base import PruneConfig, get_smoke_config
    from repro.core import calibrate, mirror
    from repro.core.prunable import prunable_map
    from repro.data.synthetic import batches_for
    from repro.models import model as M
    from repro.optim.losses import lm_loss
    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    batches = batches_for(cfg, n=chunk, batch=batch, seq=seq, split="calib")
    pcfg = PruneConfig(local_metric=metric, steps=chunk, scan_chunk=chunk)
    stats = calibrate.collect_stats(cfg, params, batches, pcfg=pcfg)
    prunable = prunable_map(params)
    state = mirror.init_search(params, jax.random.key(17))
    stacked = calibrate._stack_chunk(batches, 0, chunk)
    fn = jax.jit(calibrate.make_chunk_fn(pcfg, partial(lm_loss, cfg), stats,
                                         prunable),
                 donate_argnums=0)
    return Surface("search_chunk", fn, (state, stacked),
                   donate_argnums=(0,), policy="train")


def all_surfaces(arch: str = "llama3.2-1b", *,
                 mesh_shape: tuple | None = (2, 2),
                 include_search: bool | None = None) -> list[Surface]:
    """The full registry for one arch.  The search surface runs on the
    default (replicated) placement, so it is only included when auditing
    without a mesh unless explicitly requested."""
    out = serve_surfaces(arch, mesh_shape=mesh_shape)
    if include_search is None:
        include_search = mesh_shape is None
    if include_search:
        out.append(search_surface(arch))
    return out
