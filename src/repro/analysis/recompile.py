"""Recompile sentinel: catch silent retrace storms on the jitted hot paths.

jax.jit retraces whenever the abstract signature of a call changes - a new
shape, a flipped dtype, a different pytree structure, or an unhashed static
argument.  On the serving and calibration hot paths every retrace is a
multi-second stall that the caller never sees attributed; historically these
only surfaced as mysterious tail latencies.

``note(surface, args)`` hashes the *abstract* signature (treedef + per-leaf
(shape, dtype), repr for non-array statics) of each dispatch and keeps the
set of distinct signatures per surface.  Crossing the surface's budget
raises ``RecompileBudgetError`` with both the budget and the newest
signature, and every new signature updates the ``analysis.recompiles`` obs
gauge (labelled by surface) so the flight recorder shows compile-cache
growth next to latency.

Disabled by default: ``note`` is a single bool check on the hot path.
Enable around tests/benches with::

    from repro.analysis import recompile
    recompile.enable(budgets={"decode": 1}, default_budget=4)
    ... run ...
    assert recompile.counts()["decode"] == 1
    recompile.disable()

Instrumented surfaces: ServeEngine decode / prefill_<bucket> / write_slot
(serve/engine.py) and the calibration search_chunk / search_step
(core/calibrate.py).
"""
from __future__ import annotations

import threading
from typing import Any, Hashable

from repro import obs

__all__ = ["enable", "disable", "enabled", "reset", "note", "counts",
           "signature", "RecompileBudgetError"]


class RecompileBudgetError(RuntimeError):
    """A surface exceeded its budget of distinct compile signatures."""


_lock = threading.Lock()
_enabled = False
_default_budget = 4
_budgets: dict[str, int] = {}
_seen: dict[str, dict[Hashable, int]] = {}  # surface -> {sig: first_seen_idx}


def enabled() -> bool:
    return _enabled


def enable(budgets: dict[str, int] | None = None, *,
           default_budget: int = 4) -> None:
    """Arm the sentinel. ``budgets`` maps surface name -> max distinct
    signatures; unlisted surfaces get ``default_budget``."""
    global _enabled, _default_budget
    with _lock:
        _budgets.clear()
        _budgets.update(budgets or {})
        _default_budget = int(default_budget)
        _seen.clear()
        _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def reset() -> None:
    """Forget all recorded signatures (budgets stay armed)."""
    with _lock:
        _seen.clear()


def counts() -> dict[str, int]:
    """Distinct signatures seen per surface since enable()/reset()."""
    with _lock:
        return {k: len(v) for k, v in _seen.items()}


def signature(args: Any) -> Hashable:
    """Abstract signature of a call: treedef + (shape, dtype) per array
    leaf, ``repr`` for everything else (mirrors what jit keys its cache on
    closely enough to count retraces)."""
    import jax  # deferred: the linter imports this module jax-free
    leaves, treedef = jax.tree.flatten(args)
    sig = []
    for x in leaves:
        if hasattr(x, "shape") and hasattr(x, "dtype"):
            sig.append((tuple(x.shape), str(x.dtype)))
        else:
            sig.append(("static", repr(x)))
    return (treedef, tuple(sig))


def note(surface: str, args: Any) -> bool:
    """Record one dispatch. Returns True iff the signature is new for this
    surface. Raises RecompileBudgetError past the surface's budget."""
    if not _enabled:
        return False
    sig = signature(args)
    with _lock:
        surf = _seen.setdefault(surface, {})
        if sig in surf:
            return False
        surf[sig] = len(surf)
        n = len(surf)
        budget = _budgets.get(surface, _default_budget)
    obs.set_gauge("analysis.recompiles", float(n), surface=surface)
    if n > budget:
        raise RecompileBudgetError(
            f"surface {surface!r} reached {n} distinct compile signatures "
            f"(budget {budget}); newest: {sig[1]!r}")
    return True
