"""Static memory planner: jaxpr buffer liveness, VMEM footprints, fit tables.

Answers "does this surface / this SearchState fit that mesh" without
compiling or executing anything, from three cooperating estimates:

* :func:`plan_fn` - a topological buffer-liveness sweep over the (recursive)
  jaxpr: every equation materializes its outputs while its inputs and all
  still-referenced earlier values are live, loop carries are double-buffered
  (XLA keeps the loop state separate from the entry buffers), and an
  in-place-capable update (``dynamic_update_slice`` / ``scatter`` /
  ``select_n``) whose operand dies at that equation reuses the operand's
  buffer.  The peak of that sweep is the static ``temp_bytes``; together
  with the argument / output aval bytes and the donation credit it yields
  ``total_bytes``, the static stand-in for XLA's
  ``memory_analysis()`` total (arguments + outputs + temp - aliased).
* per-``pallas_call`` VMEM footprints read off the BlockSpecs: each block
  mapping contributes ``prod(block_shape) * itemsize`` of VMEM per grid
  step - the number that decides whether a kernel tiling fits the ~16 MB
  v5e VMEM before a single lowering runs.
* :func:`search_plan` - an ``eval_shape`` of ``core.mirror.init_search``
  (zero FLOPs, zero allocation) giving the exact SearchState byte layout
  the calibration benchmark measures live (``BENCH_calibrate.json``'s
  ``search_state_bytes``), extended into a per-mesh fit table: at which
  layer-group size does SparseLLM-style O(sqrt N) streaming of the
  Gamma/V shadows become mandatory for a given HBM budget.

Model fidelity, measured against compiled ``memory_analysis()`` on the
smoke configs (see tests/test_analysis.py):

* serving surfaces with f32 params agree within ~6% on 1 device;
* bf16 surfaces compiled on CPU diverge upward on the compiled side
  because XLA *emulates* bf16 GEMMs there - every bf16 dot operand gets an
  f32 staging copy in temp (~2x the operand bytes) that does not exist on
  TPU.  :func:`crosscheck` reports that staging estimate alongside the
  relative error so the gap is attributable instead of mysterious;
* training surfaces (the search chunk) overestimate: the walk does not
  model XLA's elementwise buffer reuse in the backward pass, so the static
  number is a safe upper bound for fit decisions.

``python -m repro.analysis memplan --arch llama3.2-1b [--compile]`` prints
the per-surface table; ``--fit`` adds the whole-zoo SearchState fit table.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable

from repro.analysis.jaxpr_audit import _sub_jaxprs

__all__ = ["MemPlan", "PallasCall", "plan_jaxpr", "plan_fn", "crosscheck",
           "search_state_bytes", "search_plan", "fit_table"]

# primitives whose first operand's buffer XLA reuses for the output when the
# operand has no later use (the planner credits that reuse at the eqn)
_INPLACE = frozenset({"dynamic_update_slice", "scatter", "select_n"})
_LOOPS = frozenset({"scan", "while"})
_F16 = ("bfloat16", "float16")


def _aval_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    try:
        return math.prod(shape) * dtype.itemsize
    except TypeError:  # extended dtypes without itemsize: not HBM-resident
        return 0


def _is_var(v) -> bool:
    """Trackable jaxpr variable (Literals carry .val and own no buffer)."""
    return hasattr(v, "aval") and not hasattr(v, "val")


@dataclasses.dataclass
class PallasCall:
    """VMEM footprint of one ``pallas_call`` eqn, from its BlockSpecs."""
    name: str
    grid: tuple
    vmem_bytes: int
    n_blocks: int

    def to_dict(self) -> dict:
        return {"name": self.name, "grid": list(self.grid),
                "vmem_bytes": self.vmem_bytes, "n_blocks": self.n_blocks}


@dataclasses.dataclass
class MemPlan:
    """Static memory plan of one jit surface."""
    surface: str
    arg_bytes: int = 0
    out_bytes: int = 0
    temp_bytes: int = 0          # liveness peak of intermediate buffers
    alias_bytes: int = 0         # donation credit (declared or compiled)
    donation_declared: int = 0
    bf16_staging_bytes: int = 0  # CPU-only f32 copies of bf16 dot operands
    pallas: list = dataclasses.field(default_factory=list)

    @property
    def total_bytes(self) -> int:
        return self.arg_bytes + self.out_bytes + self.temp_bytes \
            - self.alias_bytes

    def per_device(self, n_devices: int) -> int:
        """Even-sharding estimate: the planner's per-device HBM figure.
        Replicated scalars are counted sharded too - at the table's GB
        scale the error is noise."""
        return -(-self.total_bytes // max(n_devices, 1))

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["pallas"] = [p.to_dict() if isinstance(p, PallasCall) else p
                       for p in self.pallas]
        d["total_bytes"] = self.total_bytes
        return d


def _pallas_vmem(eqn) -> PallasCall | None:
    """Read a pallas_call's VMEM bytes per grid step off its BlockSpecs."""
    gm = eqn.params.get("grid_mapping")
    if gm is None:
        return None
    total = 0
    n = 0
    for bm in getattr(gm, "block_mappings", ()) or ():
        shape = getattr(bm, "block_shape", None)
        sd = getattr(bm, "array_shape_dtype", None)
        if shape is None or sd is None:
            continue
        numel = 1
        for dim in shape:
            numel *= dim if isinstance(dim, int) else 1  # mapped dims: 1 row
        total += numel * sd.dtype.itemsize
        n += 1
    name = str(eqn.params.get("name_and_src_info", "pallas_call"))
    return PallasCall(name.split(" ")[0], tuple(getattr(gm, "grid", ()) or ()),
                      total, n)


def _walk(jaxpr, plan: MemPlan) -> tuple[int, int, int]:
    """(arg_bytes, out_bytes, temp_peak) of one (closed or open) jaxpr;
    pallas calls found anywhere are appended to ``plan``."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    invars = list(jaxpr.invars) + list(jaxpr.constvars)
    arg_b = sum(_aval_bytes(v) for v in invars)
    out_vs = [v for v in jaxpr.outvars if _is_var(v)]
    out_b = sum(_aval_bytes(v) for v in out_vs)
    inset = set(map(id, invars))
    outset = set(map(id, out_vs))
    last_use: dict[int, int] = {}
    for i, eqn in enumerate(jaxpr.eqns):
        for v in eqn.invars:
            if _is_var(v):
                last_use[id(v)] = i
    live: dict[int, int] = {}
    peak = 0
    for i, eqn in enumerate(jaxpr.eqns):
        name = eqn.primitive.name
        if name == "pallas_call":
            pc = _pallas_vmem(eqn)
            if pc is not None:
                plan.pallas.append(pc)
        inner = 0
        for sub in _sub_jaxprs(eqn.params):
            _, _, t = _walk(sub, plan)
            inner = max(inner, t)
        if name in _LOOPS:
            # the loop state buffer is temp, double-buffered vs the result
            nc = eqn.params.get("num_carry", len(eqn.outvars))
            inner += sum(_aval_bytes(v) for v in eqn.outvars[:nc])
        dies = {id(v) for v in eqn.invars
                if _is_var(v) and last_use.get(id(v)) == i}
        credit = 0
        if (name in _INPLACE and eqn.invars and _is_var(eqn.invars[0])
                and id(eqn.invars[0]) in dies and id(eqn.invars[0]) in live):
            credit = min(_aval_bytes(eqn.invars[0]),
                         sum(_aval_bytes(v) for v in eqn.outvars))
        for v in eqn.outvars:
            if id(v) not in inset and id(v) not in outset:
                live[id(v)] = _aval_bytes(v)
        peak = max(peak, sum(live.values()) - credit + inner)
        for v in eqn.invars:
            if _is_var(v) and last_use.get(id(v)) == i and id(v) in live:
                del live[id(v)]
    return arg_b, out_b, peak


def _bf16_dot_operands(jaxpr, seen: set[int]) -> int:
    """Bytes of distinct bf16/f16 buffers consumed by dot/conv eqns - the
    buffers XLA's CPU backend stages as f32 copies (2x these bytes land in
    compiled temp on CPU and nowhere else)."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in ("dot_general", "conv_general_dilated",
                                  "pallas_call"):
            for v in eqn.invars:
                if not _is_var(v) or id(v) in seen:
                    continue
                dt = getattr(getattr(v, "aval", None), "dtype", None)
                if dt is not None and str(dt) in _F16:
                    seen.add(id(v))
                    total += _aval_bytes(v)
        for sub in _sub_jaxprs(eqn.params):
            total += _bf16_dot_operands(sub, seen)
    return total


def plan_jaxpr(jaxpr, *, surface: str = "?") -> MemPlan:
    """Liveness-walk a traced jaxpr into a MemPlan (no compilation)."""
    plan = MemPlan(surface=surface)
    plan.arg_bytes, plan.out_bytes, plan.temp_bytes = _walk(jaxpr, plan)
    plan.bf16_staging_bytes = 2 * _bf16_dot_operands(jaxpr, set())
    return plan


def plan_fn(fn: Callable, *args, surface: str = "?",
            donate_argnums: tuple = ()) -> MemPlan:
    """Trace fn(*args) and plan it; declared donations credit the plan with
    ``min(donated arg bytes, out bytes)`` - the compiled alias map refines
    this in :func:`crosscheck`."""
    import jax
    closed = jax.make_jaxpr(fn)(*args)
    plan = plan_jaxpr(closed, surface=surface)
    flat = []
    for i in donate_argnums:
        flat.extend(jax.tree.leaves(args[i]))
    plan.donation_declared = len(flat)
    donated = sum(getattr(x, "nbytes", 0) or _aval_bytes(
        jax.ShapeDtypeStruct(x.shape, x.dtype)) for x in flat
        if hasattr(x, "shape"))
    plan.alias_bytes = min(donated, plan.out_bytes)
    return plan


def crosscheck(fn: Callable, *args, surface: str = "?",
               donate_argnums: tuple = ()) -> dict:
    """Static plan vs compiled ``memory_analysis()`` for one surface.

    Compiles once; the donation credit on BOTH sides comes from the
    compiled ``input_output_alias`` map (``launch.hlo_analysis``), so the
    comparison isolates the liveness model (args + out + temp), not the
    aliasing bookkeeping.  Returns the static and compiled breakdowns, the
    relative error, and the CPU bf16-staging estimate explaining the known
    divergence class on emulated-bf16 backends.
    """
    import jax
    from repro.launch.hlo_analysis import parse_input_output_aliases
    plan = plan_fn(fn, *args, surface=surface, donate_argnums=donate_argnums)
    jfn = fn if hasattr(fn, "lower") else \
        jax.jit(fn, donate_argnums=donate_argnums)
    compiled = jfn.lower(*args).compile()
    ma = compiled.memory_analysis()
    aliases = parse_input_output_aliases(compiled.as_text())
    comp = {"arg_bytes": ma.argument_size_in_bytes,
            "out_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes}
    comp["total_bytes"] = (comp["arg_bytes"] + comp["out_bytes"]
                           + comp["temp_bytes"] - comp["alias_bytes"])
    plan.alias_bytes = comp["alias_bytes"]
    static_total = plan.total_bytes
    rel = (static_total - comp["total_bytes"]) / max(comp["total_bytes"], 1)
    return {"surface": surface, "static": plan.to_dict(), "compiled": comp,
            "rel_err": rel, "n_aliases": len(aliases),
            "bf16_staging_bytes": plan.bf16_staging_bytes,
            "backend": jax.default_backend()}


# ---------------------------------------------------------------------------
# SearchState fit planning
# ---------------------------------------------------------------------------

def _state_shapes(arch: str, *, smoke: bool = True):
    """Abstract SearchState (eval_shape of init_search: zero allocation)."""
    import jax
    from repro.configs.base import get_config, get_smoke_config
    from repro.core import mirror
    from repro.models import model as M
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shapes = M.param_shapes(cfg)
    state = jax.eval_shape(
        lambda p: mirror.init_search(p, jax.random.key(17)), shapes)
    return cfg, state


def _tree_bytes(tree) -> int:
    import jax
    return sum(_aval_bytes_sd(x) for x in jax.tree.leaves(
        tree, is_leaf=lambda x: x is None) if x is not None)


def _aval_bytes_sd(x) -> int:
    if not hasattr(x, "shape"):
        return 0
    try:
        return math.prod(x.shape) * x.dtype.itemsize
    except TypeError:  # extended dtype (PRNG key): matches the live bench,
        return 0       # which also sees itemsize-less leaves as 0


def search_state_bytes(arch: str, *, smoke: bool = True) -> int:
    """Static SearchState bytes, leaf-for-leaf identical to the live figure
    ``benchmarks/bench_calibrate.py`` records as ``search_state_bytes``."""
    import jax
    _, state = _state_shapes(arch, smoke=smoke)
    total = 0
    for x in jax.tree.leaves(state, is_leaf=lambda x: x is None):
        if x is None or not hasattr(x, "shape"):
            continue
        try:
            isz = x.dtype.itemsize
        except TypeError:  # PRNG key leaf: no HBM itemsize, bench skips too
            continue
        total += math.prod(x.shape) * isz
    return total


def search_plan(arch: str, *, smoke: bool = False,
                device_counts: Iterable[int] = (1, 4, 16, 256),
                budget_gb: float = 16.0) -> dict:
    """Does config ``arch``'s SearchState fit, and if not, at what
    layer-group size does O(sqrt N) streaming become mandatory?

    The streaming model keeps the full fp32 W resident (the forward needs
    every layer) and pages the Gamma/V shadow trees in groups of ``g``
    layers: ``resident(g) = W + shadows * g / L``.  Per budget and device
    count the table reports the largest feasible ``g`` (None when even
    g=1 exceeds the budget), whether streaming is mandatory (g_max < L),
    and the sqrt(L) recommendation the roadmap item targets.
    """
    import jax
    cfg, state = _state_shapes(arch, smoke=smoke)
    w_bytes = _tree_bytes(state.W)
    shadow_bytes = _tree_bytes(state.Gamma) + _tree_bytes(state.V)
    total = search_state_bytes(arch, smoke=smoke)
    L = cfg.num_layers
    budget = budget_gb * 1e9
    rows = []
    for n in device_counts:
        per_dev_full = -(-total // n)
        w_dev = w_bytes / n
        sh_dev = shadow_bytes / n
        if w_dev + sh_dev / L > budget:
            g_max = None          # even one layer group overflows
        elif w_dev + sh_dev <= budget:
            g_max = L             # whole state fits: streaming optional
        else:
            g_max = max(1, int((budget - w_dev) * L // max(sh_dev, 1)))
        rows.append({"devices": n, "state_bytes_per_device": per_dev_full,
                     "fits": bool(per_dev_full <= budget),
                     "max_group_layers": g_max,
                     "streaming_mandatory": g_max is not None and g_max < L})
    return {"arch": arch, "smoke": smoke, "num_layers": L,
            "state_bytes": total, "w_bytes": w_bytes,
            "shadow_bytes": shadow_bytes, "budget_gb": budget_gb,
            "sqrt_group_layers": max(1, round(math.sqrt(L))),
            "per_mesh": rows}


def fit_table(archs: Iterable[str] | None = None, *, smoke: bool = False,
              device_counts: Iterable[int] = (1, 4, 16, 256),
              budget_gb: float = 16.0) -> list[dict]:
    """The whole-zoo SearchState fit table (static, zero FLOPs)."""
    from repro.configs.base import ARCH_IDS
    return [search_plan(a, smoke=smoke, device_counts=device_counts,
                        budget_gb=budget_gb)
            for a in (archs or ARCH_IDS)]


def format_fit_table(rows: list[dict]) -> str:
    """Fixed-width rendering of :func:`fit_table` for the CLI."""
    out = ["arch                    layers   state GB   " +
           "fit@1dev fit@16 fit@256   sqrtL  stream@16dev"]
    for r in rows:
        per = {x["devices"]: x for x in r["per_mesh"]}
        def flag(n):
            e = per.get(n)
            return "-" if e is None else ("yes" if e["fits"] else "NO")
        s16 = per.get(16)
        stream = "-" if s16 is None else (
            "mandatory" if s16["streaming_mandatory"] else "optional")
        out.append(f"{r['arch']:<22s} {r['num_layers']:>6d} "
                   f"{r['state_bytes'] / 1e9:>9.2f}   "
                   f"{flag(1):>8s} {flag(16):>6s} {flag(256):>7s}   "
                   f"{r['sqrt_group_layers']:>5d}  {stream}")
    return "\n".join(out)
