"""Whole-zoo abstract dry-run: calibrate -> bank -> sparsify -> decode -> fleet.

One static pass per config family proving the full UniPruning pipeline is
*feasible* before any mesh-hour burns: every stage either traces/evaluates
abstractly (``eval_shape`` / ``jax.make_jaxpr``, zero FLOPs at scale) or
runs at smoke scale where packing needs real values (mask thresholding,
2:4 compression - seconds on CPU).  The per-family facts that are pinned
by OUR code (prunable leaf counts, kernel layouts, compression ratio,
collectives per site, static memory totals, shardcheck findings) land in
golden contracts under ``results/contracts/zoo/`` that CI diffs; volatile
facts (jax version, backend) stay under ``info`` and are ignored.

Stages per family:

* ``calibrate``  - ``eval_shape`` of the stats pass + the exact SearchState
  byte layout (``memplan.search_state_bytes``, equal to the live
  ``BENCH_calibrate.json`` figure);
* ``bank``       - a MaskBank over magnitude scores re-thresholded at two
  budgets (2:4 + 0.5 unstructured): the one-calibration-many-budgets
  property, exercising the bounded mask cache;
* ``sparsify``   - 2:4 compression through ``sparse.apply``: kernel-native
  packed vs fallback leaf counts and the compressed-bytes ratio;
* ``engine_decode`` - the serving jaxpr audited statically (collectives
  per site, zero host callbacks) plus the static memory plan.
  Encoder-decoder families (whisper) cannot use the slot engine
  (``ServeEngine`` asserts decoder-only) - they emit a structured skip and
  audit ``models.model.decode_step`` directly, which supports
  encoder-decoder;
* ``fleet``      - N budgets from ONE bank share the untouched leaves by
  identity (``sparse.apply.shared_leaves``): the fleet memory invariant;
* ``shardcheck`` - the partition-spec consistency report (mesh runs only).

``python -m repro.analysis zoo [--update] [--arch f]`` checks/regenerates
the goldens; ``--devices 4 --mesh 2x2`` is the CI mesh variant.

The production AOT loop that used to live in ``launch/dryrun.py`` (lower +
compile every (arch x shape-cell) on the 256/512-device mesh, collect
``memory_analysis`` / collective traffic / fits-16GB) now lives here too
(:func:`build_cell` / :func:`run_cell`) behind ``zoo --cells``;
``launch/dryrun.py`` is a thin shim over it.
"""
from __future__ import annotations

import json
import pathlib
import re
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPE_CELLS, ModelConfig,
                                PruneConfig, ShapeCell, get_config,
                                get_smoke_config)

PyTree = Any

__all__ = ["family_report", "build_zoo_manifest", "zoo_diff", "golden_path",
           "run_zoo", "cell_skipped", "parse_collectives", "build_cell",
           "run_cell", "run_cells_main", "LONG_OK"]

# budgets every family's bank is re-thresholded at (stage: bank / fleet);
# families whose kernels cannot take 2:4 (a reduction dim % 4 != 0) swap
# the n:m budget for a second unstructured one
_BUDGETS = ((2, 4), 0.5)
_BUDGETS_UNSTRUCTURED = (0.25, 0.5)


# ---------------------------------------------------------------------------
# Per-family pipeline stages
# ---------------------------------------------------------------------------

def _surrogate_bank(cfg, params):
    """In-memory MaskBank over magnitude scores: the static stand-in for a
    calibrated bank (same tree structure, deterministic, no search)."""
    from repro.core import metrics as metrics_mod
    from repro.core.prunable import prunable_map
    from repro.sparse.bank import MaskBank
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    V = jax.tree.map(lambda g: None if g is None else jnp.zeros_like(g),
                     scores, is_leaf=lambda x: x is None)
    return MaskBank(cfg, PruneConfig(mode="nm"), scores, V, None,
                    {"surrogate": True})


def _stage_calibrate(cfg, arch: str) -> dict:
    from repro.analysis import memplan
    from repro.data.synthetic import batches_for
    from repro.models import model as M
    shapes = M.param_shapes(cfg)
    leaves = [x for x in jax.tree.leaves(shapes) if hasattr(x, "shape")]
    param_bytes = sum(
        int(jnp.dtype(x.dtype).itemsize) * int(jnp.prod(jnp.array(x.shape)))
        if x.shape else int(jnp.dtype(x.dtype).itemsize) for x in leaves)
    b = batches_for(cfg, n=1, batch=2, seq=16, split="calib")[0]
    abstract_b = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), b)
    stats = jax.eval_shape(lambda p, bb: M.stats_sumsq(cfg, p, bb),
                           shapes, abstract_b)
    n_stats = len([x for x in jax.tree.leaves(
        stats, is_leaf=lambda x: x is None) if x is not None])
    return {"status": "ok", "param_leaves": len(leaves),
            "param_bytes": int(param_bytes), "stats_leaves": n_stats,
            "search_state_bytes": memplan.search_state_bytes(arch)}


def _nm_infeasible(scores) -> str | None:
    """First prunable leaf whose reduction dim breaks 2:4 grouping, if any
    (e.g. xlstm's ff_down K=85): n:m masks cannot exist for the family."""
    from jax.tree_util import keystr, tree_flatten_with_path
    flat, _ = tree_flatten_with_path(scores, is_leaf=lambda x: x is None)
    for kp, leaf in flat:
        if leaf is not None and leaf.shape[-2] % 4:
            return f"{keystr(kp)} K={leaf.shape[-2]} % 4 != 0"
    return None


def _stage_bank(bank, budgets) -> dict:
    for budget in budgets:
        if isinstance(budget, tuple):
            bank.masks_at(nm=budget)
        else:
            bank.masks_at(sparsity=budget)
    n_prunable = len([x for x in jax.tree.leaves(
        bank.Gamma, is_leaf=lambda x: x is None) if x is not None])
    return {"status": "ok", "budgets": len(budgets),
            "prunable_leaves": n_prunable,
            "mask_cache_entries": len(bank._mask_cache)}


def _stage_sparsify(cfg, params, bank) -> tuple[dict, PyTree]:
    from repro.models import model as M
    from repro.sparse import apply as apply_mod
    masks = bank.masks_at(nm=_BUDGETS[0])
    sparse = apply_mod.sparsify_params(
        params, masks, axes=M.param_axes(cfg), idx_bits=2,
        dtype=jnp.bfloat16)
    rep = apply_mod.compressed_report(sparse, masks)
    return ({"status": "ok",
             "sparse_leaves": len(rep["layers"]),
             "kernel_native_packed": rep["kernel_native_packed"],
             "fallback_leaves": rep["fallback_leaves"],
             "bytes_compressed": rep["bytes_compressed"],
             "bytes_dense_bf16": rep["bytes_dense_bf16"],
             "ratio": round(rep["ratio"], 6) if rep["ratio"] else None},
            sparse)


def _stage_engine_decode(cfg, arch: str, sparse,
                         mesh_shape: tuple | None, *,
                         sparse_serve: bool = True) -> dict:
    from repro.analysis import jaxpr_audit, memplan, surfaces
    from repro.models import model as M
    if cfg.is_encoder_decoder:
        # ServeEngine asserts decoder-only; decode_step itself supports
        # encoder-decoder, so the serving jaxpr is audited directly.
        if sparse is None:  # nm-infeasible family: audit the dense path
            sparse = M.init_params(cfg, jax.random.key(0))
        caches = M.init_caches(cfg, 1, 32, enc_len=8)
        tok = jnp.zeros((1,), jnp.int32)
        closed = jax.make_jaxpr(partial(M.decode_step, cfg))(
            sparse, tok, caches, jnp.int32(0))
        rep = jaxpr_audit.audit_jaxpr(closed, surface="decode_step")
        plan = memplan.plan_jaxpr(closed, surface="decode_step")
        return {"status": "skip",
                "reason": "encoder-decoder: slot engine unsupported; "
                          "decode_step audited directly",
                "surface": "decode_step",
                "host_callbacks": len(rep.host_callbacks),
                "psums_by_site": dict(sorted(rep.psums_by_site.items())),
                "arg_bytes": rep.arg_bytes, "out_bytes": rep.out_bytes,
                "static_total_bytes": plan.total_bytes,
                "pallas_calls": len(plan.pallas),
                "fits_16gb": bool(plan.per_device(
                    _n_devices(mesh_shape)) < 16e9)}
    surf = surfaces.serve_surfaces(arch, mesh_shape=mesh_shape,
                                   sparse=sparse_serve)[0]
    closed = jax.make_jaxpr(surf.fn)(*surf.args)
    rep = jaxpr_audit.audit_jaxpr(closed, surface=surf.name)
    plan = memplan.plan_jaxpr(closed, surface=surf.name)
    return {"status": "ok", "surface": surf.name, "sparse": sparse_serve,
            "host_callbacks": len(rep.host_callbacks),
            "psums_by_site": dict(sorted(rep.psums_by_site.items())),
            "collectives": dict(sorted(rep.collectives.items())),
            "arg_bytes": rep.arg_bytes, "out_bytes": rep.out_bytes,
            "static_total_bytes": plan.total_bytes,
            "pallas_calls": len(plan.pallas),
            "fits_16gb": bool(plan.per_device(
                _n_devices(mesh_shape)) < 16e9)}


def _stage_fleet(cfg, params, bank) -> dict:
    from repro.core import masks as masks_mod
    from repro.sparse import apply as apply_mod
    masks = bank.masks_at(sparsity=0.5)
    variant = masks_mod.apply_masks(params, masks)
    shared = apply_mod.shared_leaves(params, variant)
    total = len(jax.tree.leaves(params))
    return {"status": "ok", "shared_leaves": shared,
            "total_leaves": total,
            "mask_cache_entries": len(bank._mask_cache)}


def _n_devices(mesh_shape: tuple | None) -> int:
    if not mesh_shape:
        return 1
    n = 1
    for d in mesh_shape:
        n *= d
    return n


def family_report(arch: str, *, mesh_shape: tuple | None = None) -> dict:
    """The full static pipeline dry-run for one config family."""
    from repro.analysis import shardcheck
    from repro.models import model as M
    cfg = get_smoke_config(arch)
    report: dict[str, Any] = {
        "family": arch, "model_family": cfg.family,
        "mesh": list(mesh_shape) if mesh_shape else None, "stages": {}}
    stages = report["stages"]
    stages["calibrate"] = _stage_calibrate(cfg, arch)
    params = M.init_params(cfg, jax.random.key(0))
    bank = _surrogate_bank(cfg, params)
    nm_block = _nm_infeasible(bank.Gamma)
    budgets = _BUDGETS_UNSTRUCTURED if nm_block else _BUDGETS
    stages["bank"] = _stage_bank(bank, budgets)
    if nm_block:
        # no 2:4 layout exists for the family: serve masked-dense instead
        stages["sparsify"] = {
            "status": "skip",
            "reason": f"2:4 infeasible ({nm_block}); serving masked-dense"}
        sparse = None
    else:
        stages["sparsify"], sparse = _stage_sparsify(cfg, params, bank)
    stages["engine_decode"] = _stage_engine_decode(
        cfg, arch, sparse, mesh_shape, sparse_serve=not nm_block)
    stages["fleet"] = _stage_fleet(cfg, params, bank)
    if mesh_shape is None:
        stages["shardcheck"] = {
            "status": "skip", "reason": "single device: nothing partitioned"}
    else:
        sc = shardcheck.check_arch(arch, mesh_shape=mesh_shape,
                                   trace_decode=not cfg.is_encoder_decoder,
                                   sparse=not nm_block)
        kinds: dict[str, int] = {}
        for f in sc.get("findings", []):
            kinds[f["kind"]] = kinds.get(f["kind"], 0) + 1
        stages["shardcheck"] = {"status": "ok", "clean": sc["clean"],
                                "findings": dict(sorted(kinds.items())),
                                "leaves": sc.get("leaves", {})}
    report["feasibility"] = {
        "traces": all(s.get("status") in ("ok", "skip")
                      for s in stages.values()),
        "fits_16gb": bool(stages["engine_decode"].get("fits_16gb", False)),
        "sharding_clean": (stages["shardcheck"].get("clean", True)
                           if stages["shardcheck"]["status"] == "ok"
                           else None),
    }
    return report


# ---------------------------------------------------------------------------
# Golden contracts
# ---------------------------------------------------------------------------

def build_zoo_manifest(arch: str, *, mesh_shape: tuple | None = None) -> dict:
    man = family_report(arch, mesh_shape=mesh_shape)
    man["info"] = {"jax": jax.__version__,
                   "backend": jax.default_backend()}
    return man


def _strip_info(d):
    if isinstance(d, dict):
        return {k: _strip_info(v) for k, v in d.items() if k != "info"}
    if isinstance(d, list):
        return [_strip_info(x) for x in d]
    return d


def zoo_diff(golden: dict, current: dict) -> list[dict]:
    """Structured drift, path-by-path, ``info`` subtrees ignored."""
    diffs: list[dict] = []

    def walk(g, c, path):
        if isinstance(g, dict) and isinstance(c, dict):
            for k in sorted(set(g) | set(c)):
                if k == "info":
                    continue
                if k not in c:
                    diffs.append({"path": f"{path}.{k}", "golden": g[k],
                                  "current": "<missing>"})
                elif k not in g:
                    diffs.append({"path": f"{path}.{k}",
                                  "golden": "<missing>", "current": c[k]})
                else:
                    walk(g[k], c[k], f"{path}.{k}")
        elif _strip_info(g) != _strip_info(c):
            diffs.append({"path": path, "golden": g, "current": c})

    walk(golden, current, current.get("family", "?"))
    return diffs


def golden_path(zoo_dir, arch: str, mesh_shape: tuple | None) -> pathlib.Path:
    tag = "x".join(str(d) for d in mesh_shape) if mesh_shape else "1dev"
    return pathlib.Path(zoo_dir) / f"{arch}_{tag}.json"


def run_zoo(archs=None, *, mesh_shape: tuple | None = None,
            zoo_dir="results/contracts/zoo", update: bool = False,
            diff_out=None) -> int:
    """Check (or ``update``) every family's golden; 0 iff no drift."""
    import sys
    rc = 0
    all_diffs = []
    for arch in (archs or ARCH_IDS):
        man = build_zoo_manifest(arch, mesh_shape=mesh_shape)
        path = golden_path(zoo_dir, arch, mesh_shape)
        if update:
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(json.dumps(man, indent=1, sort_keys=True) + "\n")
            print(f"wrote {path}")
            continue
        if not path.exists():
            rc = 1
            all_diffs.append({"path": str(path), "golden": "<missing file>",
                              "current": "built"})
            print(f"{path}: MISSING GOLDEN", file=sys.stderr)
            continue
        diffs = zoo_diff(json.loads(path.read_text()), man)
        feas = man["feasibility"]
        if diffs:
            rc = 1
            all_diffs.extend(diffs)
            print(f"{path}: ZOO CONTRACT DRIFT", file=sys.stderr)
            for d in diffs:
                print(f"  {d['path']}: golden={d['golden']!r} "
                      f"current={d['current']!r}", file=sys.stderr)
        else:
            print(f"{path}: OK (traces={feas['traces']} "
                  f"fits_16gb={feas['fits_16gb']} "
                  f"sharding_clean={feas['sharding_clean']})")
    if all_diffs and diff_out:
        pathlib.Path(diff_out).write_text(json.dumps(all_diffs, indent=1))
        print(f"diff written to {diff_out}", file=sys.stderr)
    return rc


# ---------------------------------------------------------------------------
# Production shape-cell AOT loop (moved here from launch/dryrun.py)
# ---------------------------------------------------------------------------

# long_500k requires sub-quadratic service; skipped for pure full-attention
# archs (see DESIGN.md section 6)
LONG_OK = {"zamba2-7b", "xlstm-125m", "gemma2-2b", "gemma3-1b"}

COLLECTIVE_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^ ]* (all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)\(")
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3fn": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2}


def cell_skipped(cfg: ModelConfig, cell: ShapeCell) -> str | None:
    if cell.name == "long_500k" and cfg.name not in LONG_OK:
        return "full-attention arch: 500k dense-KV decode not serviceable"
    return None


def parse_collectives(hlo: str) -> dict:
    """Sum per-device collective bytes from partitioned optimized HLO."""
    out: dict[str, float] = {}
    details = []
    for line in hlo.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        dt, dims, op = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        size = n * DTYPE_BYTES.get(dt, 4)
        g = GROUPS_RE.search(line)
        group_size = int(g.group(2)) if g else 0
        if op == "all-reduce":
            traffic = 2 * size  # ring: reduce-scatter + all-gather
        elif op == "reduce-scatter":
            traffic = size * max(group_size, 1)
        else:
            traffic = size
        out[op] = out.get(op, 0.0) + traffic
        details.append({"op": op, "bytes": size, "group_size": group_size})
    out["total_bytes"] = sum(v for k, v in out.items() if k != "total_bytes")
    out["ops"] = details[:512]
    return out


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh, pcfg=None,
               accum_override: int = 0, cast_bf16: bool = False):
    """Returns (fn, arg_specs, in_shardings, rules, extra) for the cell."""
    from repro.dist import sharding as shd
    from repro.launch import steps as steps_mod
    from repro.models import model as M
    from repro.optim import optimizers as opt
    kv_mode = "all" if cell.name == "long_500k" else (
        "model" if cell.is_serve else False)
    rules = shd.make_production_rules(
        mesh, seq_shard_kv=kv_mode, seq_parallel=cell.kind == "train")
    params_s = M.param_shapes(cfg)
    if cell.is_serve:  # deployment: bf16 weights
        params_s = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            params_s)
    axes = M.param_axes(cfg)
    p_sh = shd.params_sharding(axes, params_s, rules)
    if cell.is_serve:
        # serving layout: embedding table vocab-TP only (no FSDP dim) so the
        # tied unembed matmul shards cleanly instead of replicating
        p_sh["embed"]["table"] = NamedSharding(mesh, P("model", None))
    specs = steps_mod.input_specs(cfg, cell)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]

    if cell.kind == "train":
        accum = accum_override or steps_mod.choose_accum(cfg, cell, dp)
        ocfg = opt.AdamWConfig()
        fn = steps_mod.make_train_step(cfg, ocfg, accum=accum, remat=True,
                                       cast_bf16=cast_bf16)
        ostate_s = jax.eval_shape(opt.adamw_init, params_s)
        o_sh = jax.tree.map(lambda _: None, ostate_s)
        o_sh = opt.AdamWState(mu=p_sh, nu=p_sh,
                              count=NamedSharding(mesh, P()))
        b_sh = shd.batch_sharding_tree(specs["batch"], mesh)
        return (fn, (params_s, ostate_s, specs["batch"]),
                (p_sh, o_sh, b_sh), rules, {"accum": accum, "donate": (0, 1)})
    if cell.kind == "prefill":
        fn = steps_mod.make_prefill(cfg, cell)
        b_sh = shd.batch_sharding_tree(specs["batch"], mesh)
        return fn, (params_s, specs["batch"]), (p_sh, b_sh), rules, {}
    # decode: partial-softmax attention over sharded KV (seq or model axis)
    fn = steps_mod.make_decode(cfg, cell, seq_sharded=True)
    c_sh = shd.cache_sharding(specs["caches"], mesh)
    tok_sh = (NamedSharding(mesh, P(("pod", "data")
                                    if "pod" in mesh.axis_names else "data"))
              if cell.global_batch % dp == 0
              else NamedSharding(mesh, P(None)))
    return (fn, (params_s, specs["token"], specs["caches"], specs["t"]),
            (p_sh, tok_sh, c_sh, NamedSharding(mesh, P())), rules,
            {"donate": (2,)})


def run_cell(arch: str, cell_name: str, *, multi_pod: bool = False,
             hlo_path=None, accum_override: int = 0,
             cast_bf16: bool = False) -> dict:
    from repro.dist.axes import use_rules
    from repro.launch.mesh import make_production_mesh
    cfg = get_config(arch)
    cell = SHAPE_CELLS[cell_name]
    rec: dict = {"arch": arch, "cell": cell_name, "multi_pod": multi_pod,
                 "mesh": "(2,16,16)" if multi_pod else "(16,16)"}
    skip = cell_skipped(cfg, cell)
    if skip:
        rec["skipped"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    t0 = time.time()
    fn, arg_specs, in_sh, rules, extra = build_cell(
        cfg, cell, mesh, accum_override=accum_override, cast_bf16=cast_bf16)
    donate = extra.pop("donate", ())
    rec.update(extra)
    with mesh, use_rules(rules):
        lowered = jax.jit(fn, in_shardings=in_sh,
                          donate_argnums=donate).lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # jax<=0.4.x wraps the properties dict
            ca = ca[0] if ca else {}
        print({k: v for k, v in (ca or {}).items()
               if not k.startswith(("bytes accessed0", "bytes accessed1",
                                    "utilization"))})
        hlo = compiled.as_text()
    if hlo_path is not None:
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    rec.update({
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", 0),
        },
        "cost": {k: v for k, v in (ca or {}).items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": parse_collectives(hlo),
        "hlo_bytes": len(hlo),
    })
    per_dev = (rec["memory"]["argument_bytes"] - rec["memory"]["alias_bytes"]
               + rec["memory"]["temp_bytes"] + rec["memory"]["output_bytes"])
    rec["fits_16gb"] = bool(per_dev < 16e9)
    rec["per_device_hbm_bytes"] = per_dev
    return rec


def run_cells_main(args) -> int:
    """The old dryrun driver: every requested (arch x cell), JSON per cell.

    ``args`` carries arch/cell/all/multi_pod/accum/bf16_cast/out (the shim
    in ``launch/dryrun.py`` and ``zoo --cells`` both parse into this shape).
    """
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    jobs = []
    if args.all:
        for a in ARCH_IDS:
            for c in SHAPE_CELLS:
                jobs.append((a, c))
    else:
        assert args.arch and args.cell, "--arch/--cell or --all"
        jobs.append((args.arch, args.cell))

    for arch, cell in jobs:
        tag = f"{arch}__{cell}__{'multipod' if args.multi_pod else 'pod'}"
        print(f"=== {tag} ===", flush=True)
        try:
            rec = run_cell(arch, cell, multi_pod=args.multi_pod,
                           hlo_path=outdir / f"{tag}.hlo.gz",
                           accum_override=args.accum,
                           cast_bf16=args.bf16_cast)
        except Exception as e:  # a failure here is a bug in our sharding
            rec = {"arch": arch, "cell": cell, "multi_pod": args.multi_pod,
                   "error": f"{type(e).__name__}: {e}"}
            print("FAILED:", rec["error"], flush=True)
        (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
        ok = "SKIP" if rec.get("skipped") else (
            "ERROR" if rec.get("error") else "ok")
        print(f"--- {tag}: {ok} "
              f"compile={rec.get('compile_s', '-')}s "
              f"hbm/dev={rec.get('per_device_hbm_bytes', 0)/1e9:.2f}GB",
              flush=True)
    return 0
