"""Jaxpr auditor: static trace contracts for the jitted hot paths.

Walks the ClosedJaxpr of a registered surface (serve decode/prefill/
slot-write, the calibration search chunk) - recursing into every sub-jaxpr
(pjit, scan, while, cond, shard_map, custom_jvp) - and extracts the facts
the trace contracts gate on, without executing anything:

* primitive histogram and equation count;
* host-callback sites (``pure_callback`` / ``io_callback`` /
  ``debug_callback`` / infeed-outfeed) - forbidden on hot paths;
* per-site collective counts: ``kernels.shard`` wraps each shard_map local
  body in ``jax.named_scope("site:<site>")``, so every psum eqn carries its
  site in ``eqn.source_info.name_stack`` and the static count per site is
  directly comparable to the flight recorder's trace-time ``dist.psum``
  counters (both advance once per traced call site);
* dtype-promotion violations: ``convert_element_type`` of a large bf16/f16
  tensor to f32/f64.  Upcasts inside a ``site:``-tagged shard_map body are
  recorded but not counted as violations - those are the intentional
  K-partial f32 accumulators;
* live-bytes estimates (sum of input / output aval bytes) and the dtype set.

``audit_donation`` complements the jaxpr walk with the compiled view:
lower+compile the surface and read XLA's ``input_output_alias`` table
(``launch.hlo_analysis.parse_input_output_aliases``) plus any "donated
buffers were not usable" warnings, so declared ``donate_argnums`` that XLA
silently refused to alias are surfaced.
"""
from __future__ import annotations

import dataclasses
import math
import re
import warnings
from typing import Any, Callable, Iterable

import jax

__all__ = ["AuditReport", "audit_jaxpr", "audit_fn", "audit_donation",
           "PSUM_PRIMS", "COLLECTIVE_PRIMS", "CALLBACK_PRIMS"]

# psum shows up as "psum2" when shard_map's check_rep rewrite is active;
# both normalize to "psum" in reports so contracts survive jax upgrades.
PSUM_PRIMS = frozenset({"psum", "psum2"})
COLLECTIVE_PRIMS = PSUM_PRIMS | {
    "pmax", "pmin", "ppermute", "pshuffle", "all_gather", "all_to_all",
    "reduce_scatter"}
CALLBACK_PRIMS = frozenset({"infeed", "outfeed"})  # plus *callback* by name

_SITE = re.compile(r"site:([\w.\-]+)")
_F16 = {"bfloat16", "float16"}
_F32UP = {"float32", "float64"}


@dataclasses.dataclass
class AuditReport:
    """Everything the static walk extracts from one surface's jaxpr."""
    surface: str
    n_eqns: int = 0
    primitives: dict = dataclasses.field(default_factory=dict)
    host_callbacks: list = dataclasses.field(default_factory=list)
    collectives: dict = dataclasses.field(default_factory=dict)
    psums_by_site: dict = dataclasses.field(default_factory=dict)
    upcasts: list = dataclasses.field(default_factory=list)
    large_f32_upcasts: int = 0
    dtypes: list = dataclasses.field(default_factory=list)
    arg_bytes: int = 0
    out_bytes: int = 0
    donation: dict | None = None

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def _sub_jaxprs(params: dict) -> Iterable[Any]:
    """Yield every (open) sub-jaxpr referenced from an eqn's params.

    Duck-typed on purpose: ClosedJaxpr has .jaxpr/.consts, Jaxpr has
    .eqns/.invars - stable across jax versions without importing either
    class from a moving module path.
    """
    def walk(v):
        if hasattr(v, "jaxpr") and hasattr(v, "consts"):
            yield v.jaxpr
        elif hasattr(v, "eqns") and hasattr(v, "invars"):
            yield v
        elif isinstance(v, (list, tuple)):
            for x in v:
                yield from walk(x)
    for v in params.values():
        yield from walk(v)


def _aval_bytes(v) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return math.prod(shape) * dtype.itemsize


def _scope(eqn) -> str:
    si = getattr(eqn, "source_info", None)
    ns = getattr(si, "name_stack", None)
    return str(ns) if ns is not None else ""


def _site_of(eqn) -> str:
    m = _SITE.findall(_scope(eqn))
    return m[-1] if m else "unlabeled"


def audit_jaxpr(jaxpr: Any, *, surface: str = "?",
                upcast_numel: int = 1 << 14) -> AuditReport:
    """Walk a Jaxpr/ClosedJaxpr (recursively) into an AuditReport.

    upcast_numel: tensors at or above this element count are "large" for
    the bf16->f32 promotion check; tiny scalars/norm factors pass.
    """
    rep = AuditReport(surface=surface)
    dtypes: set[str] = set()

    if hasattr(jaxpr, "jaxpr"):  # ClosedJaxpr -> open jaxpr
        jaxpr = jaxpr.jaxpr

    for v in jaxpr.invars:
        rep.arg_bytes += _aval_bytes(v)
    for v in jaxpr.outvars:
        rep.out_bytes += _aval_bytes(v)

    def walk(j, in_shard_map: bool, depth: int) -> None:
        if depth > 128:
            return
        for eqn in j.eqns:
            name = eqn.primitive.name
            rep.n_eqns += 1
            rep.primitives[name] = rep.primitives.get(name, 0) + 1

            if "callback" in name or name in CALLBACK_PRIMS:
                cb = eqn.params.get("callback", None)
                rep.host_callbacks.append({
                    "primitive": name,
                    "callback": repr(cb) if cb is not None else "",
                    "scope": _scope(eqn)})

            if name in COLLECTIVE_PRIMS:
                canon = "psum" if name in PSUM_PRIMS else name
                rep.collectives[canon] = rep.collectives.get(canon, 0) + 1
                if name in PSUM_PRIMS:
                    site = _site_of(eqn)
                    rep.psums_by_site[site] = \
                        rep.psums_by_site.get(site, 0) + 1

            if name == "convert_element_type":
                old = getattr(getattr(eqn.invars[0], "aval", None),
                              "dtype", None)
                new = eqn.params.get("new_dtype", None)
                aval = getattr(eqn.invars[0], "aval", None)
                numel = math.prod(getattr(aval, "shape", ()) or ())
                if (old is not None and new is not None
                        and str(old) in _F16 and str(new) in _F32UP
                        and numel >= upcast_numel):
                    site = _site_of(eqn)
                    accum = in_shard_map and site != "unlabeled"
                    rep.upcasts.append({
                        "from": str(old), "to": str(new), "numel": numel,
                        "site": site, "kpartial_accum": accum})
                    if not accum:
                        rep.large_f32_upcasts += 1

            for v in eqn.outvars:
                dt = getattr(getattr(v, "aval", None), "dtype", None)
                if dt is not None:
                    dtypes.add(str(dt))

            inner = in_shard_map or name == "shard_map"
            for sub in _sub_jaxprs(eqn.params):
                walk(sub, inner, depth + 1)

    for v in jaxpr.invars:
        dt = getattr(getattr(v, "aval", None), "dtype", None)
        if dt is not None:
            dtypes.add(str(dt))
    walk(jaxpr, False, 0)
    rep.dtypes = sorted(dtypes)
    return rep


def audit_fn(fn: Callable, *args, surface: str = "?",
             upcast_numel: int = 1 << 14, **kwargs) -> AuditReport:
    """Trace fn(*args, **kwargs) to a jaxpr and audit it."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return audit_jaxpr(closed, surface=surface, upcast_numel=upcast_numel)


def audit_donation(fn: Callable, args: tuple,
                   donate_argnums: tuple = ()) -> dict:
    """Donation effectiveness: declared donations vs XLA's actual aliasing.

    Lowers+compiles the surface, parses ``input_output_alias`` out of the
    compiled HLO, and captures jax's "donated buffers were not usable"
    warnings.  ``fn`` may already be jit-wrapped (its own donate_argnums
    win); a bare callable is wrapped here with ``donate_argnums``.
    """
    from repro.launch.hlo_analysis import parse_input_output_aliases
    jfn = fn if hasattr(fn, "lower") else \
        jax.jit(fn, donate_argnums=donate_argnums)
    declared = sum(len(jax.tree.leaves(args[i])) for i in donate_argnums)
    with warnings.catch_warnings(record=True) as wl:
        warnings.simplefilter("always")
        compiled = jfn.lower(*args).compile()
    aliases = parse_input_output_aliases(compiled.as_text())
    undonated = [str(w.message) for w in wl
                 if "donated" in str(w.message).lower()]
    return {"declared": declared, "aliased": len(aliases),
            "aliases": aliases, "undonated_warnings": undonated,
            "platform": jax.default_backend()}
