"""Partition-spec consistency checker: prove the sharding layout executes.

Two static passes over one arch + mesh, no compilation:

* **Leaf layout proofs** - every compressed (SparseTensor) leaf's layout is
  decided once by ``dist.sharding.sparse_component_layout``; this pass
  re-derives the physical consequences and proves them:

  - vals/idx K specs agree (all-or-nothing K sharding - a split decision
    is a layout no kernel executes);
  - a K-sharded leaf's *stored* component rows actually divide over the K
    mesh axes: vals rows (K/2 for 2:4) and idx rows (K/8 packed bytes,
    K/4 int8 groups) per shard must be whole, and every leading dim
    (layers / experts) must divide its mapped axes;
  - every silent replicated-K fallback (the mesh maps K but the leaf
    cannot shard it) becomes a structured finding instead of only a
    trace-time warning.

* **shard_map/psum axis consistency** - walks the decode jaxpr's shard_map
  eqns (the ``kernels/shard.py`` wrappers) and checks each body psum
  reduces over axes that are (a) partitioned in at least one input spec
  and (b) absent from every output spec - i.e. the K-partial accumulation
  contracts what was sharded and nothing else.

Findings are structured dicts ``{leaf|surface, kind, severity, detail}``;
``severity == "error"`` means the static layout cannot execute and fails
the check (CI gates on it), ``"warn"`` marks working-but-degraded layouts
(replicated fallbacks).  ``python -m repro.analysis shardcheck --arch X
--mesh 2x2 --devices 4`` prints the report; exit code 1 on errors only.
"""
from __future__ import annotations

from typing import Any

from repro.analysis.jaxpr_audit import PSUM_PRIMS, _sub_jaxprs

__all__ = ["check_leaves", "check_psum_axes", "check_arch", "format_report"]


def _axes_size(mesh, entry) -> int:
    if entry is None:
        return 1
    n = 1
    for a in ((entry,) if isinstance(entry, str) else tuple(entry)):
        n *= mesh.shape[a]
    return n


def _finding(kind: str, severity: str, where: str, detail: str,
             **extra) -> dict:
    return {"kind": kind, "severity": severity, "where": where,
            "detail": detail, **extra}


def check_leaves(cfg, params, rules, *, quiet: bool = True
                 ) -> tuple[dict, list[dict]]:
    """Layout proofs for every compressed leaf of one params tree.

    Returns (counts, findings).  ``params`` is a sparsified tree (smoke
    scale is fine - divisibility is decided by real config shapes, which
    the smoke configs preserve modulo scale; the zoo goldens pin the smoke
    outcome, the CLI can run full configs).
    """
    import jax
    from jax.tree_util import keystr
    from repro.dist.sharding import sparse_component_layout
    from repro.models import model as M
    from repro.sparse.formats import SparseTensor
    mesh = rules.mesh
    axes_tree = M.param_axes(cfg)
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, SparseTensor))
    flat_a = jax.tree_util.tree_leaves(
        axes_tree, is_leaf=lambda x: x is None)
    assert len(flat_a) == len(flat_p), (len(flat_a), len(flat_p))
    counts = {"sparse_leaves": 0, "k_sharded": 0, "replicated_k": 0,
              "replicated_n": 0, "unmapped_k": 0}
    findings: list[dict] = []
    for (kp, leaf), axes_str in zip(flat_p, flat_a, strict=True):
        if not isinstance(leaf, SparseTensor):
            continue
        path = keystr(kp)
        counts["sparse_leaves"] += 1
        vals_spec, idx_spec, tag = sparse_component_layout(
            axes_str, leaf, rules, path=path, quiet=quiet)
        # all-or-nothing K: both components must agree on the K entry
        if tuple(vals_spec) != tuple(idx_spec):
            findings.append(_finding(
                "k_component_mismatch", "error", path,
                f"vals spec {tuple(vals_spec)} != idx spec "
                f"{tuple(idx_spec)}: a split K decision is not executable"))
            continue
        names = (axes_str or "").split("|") if axes_str else []
        dense = list(rules.spec(names)) if names else []
        dense += [None] * (len(leaf.shape) - len(dense))
        k_entry = dense[-2] if len(dense) >= 2 else None
        d = _axes_size(mesh, k_entry)
        K = leaf.shape[-2]
        group = 8 if leaf.idx_bits == 2 else 4
        if tag is not None:
            counts["k_sharded"] += 1
            # prove the stored planes divide: whole vals rows / idx rows
            # (bytes for packed, groups for int8) per K shard
            for comp, rows in (("vals", leaf.vals.shape[-2]),
                               ("idx", leaf.idx.shape[-2])):
                if rows % d != 0:
                    findings.append(_finding(
                        "divisibility", "error", path,
                        f"{comp} stores {rows} rows along K but the K mesh "
                        f"axes {k_entry!r} span {d} devices "
                        f"({rows} % {d} != 0): tagged layout cannot "
                        "place whole rows per shard", component=comp,
                        rows=rows, devices=d))
            # leading dims (layers scan axis / expert banks) must divide
            spec_t = tuple(vals_spec)
            for i, e in enumerate(spec_t[:-2]):
                sz = _axes_size(mesh, e)
                if sz > 1 and leaf.vals.shape[i] % sz != 0:
                    findings.append(_finding(
                        "divisibility", "error", path,
                        f"leading dim {i} ({leaf.vals.shape[i]}) does not "
                        f"divide mesh axes {e!r} ({sz} devices)", dim=i))
        elif k_entry is not None and d > 1:
            counts["replicated_k"] += 1
            findings.append(_finding(
                "replicated_k_fallback", "warn", path,
                f"K={K} cannot shard over {k_entry!r} ({d} devices, needs "
                f"K % {group * d} == 0 for idx_bits={leaf.idx_bits}): vals "
                "AND idx replicate along K - correct but every device "
                "holds the full reduction dim",
                K=K, devices=d, needs=group * d))
        else:
            counts["unmapped_k"] += 1
        n_entry = dense[-1] if dense else None
        n_sz = _axes_size(mesh, n_entry)
        if (n_entry is not None and n_sz > 1
                and tuple(vals_spec)[-1] is None):
            counts["replicated_n"] += 1
            findings.append(_finding(
                "replicated_n_fallback", "warn", path,
                f"N={leaf.shape[-1]} does not divide mesh axes "
                f"{n_entry!r} ({n_sz} devices): output dim replicates",
                N=leaf.shape[-1], devices=n_sz))
    return counts, findings


def _axis_names(names_entry) -> set[str]:
    """Flat mesh-axis names out of one shard_map in_names/out_names entry
    (a dict {dim: name-or-tuple} in current jax)."""
    out: set[str] = set()
    vals = names_entry.values() if hasattr(names_entry, "values") \
        else names_entry
    for v in vals:
        if isinstance(v, str):
            out.add(v)
        elif isinstance(v, (tuple, list)):
            out.update(x for x in v if isinstance(x, str))
    return out


def _collect_psum_axes(jaxpr, acc: list) -> None:
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in PSUM_PRIMS:
            axes = eqn.params.get("axes", ()) or ()
            acc.append(tuple(a for a in axes if isinstance(a, str)))
        for sub in _sub_jaxprs(eqn.params):
            _collect_psum_axes(sub, acc)


def check_psum_axes(jaxpr, *, surface: str = "?") -> tuple[dict, list[dict]]:
    """shard_map in/out specs vs the psum axes of each body.

    Every psum axis must be partitioned in at least one input spec (or the
    'reduction' never had partial values to combine) and in no output spec
    (or the combine left the result still sharded over a reduced axis).
    """
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    counts = {"shard_maps": 0, "psums": 0}
    findings: list[dict] = []

    def walk(j) -> None:
        if hasattr(j, "jaxpr"):
            j = j.jaxpr
        for eqn in j.eqns:
            if eqn.primitive.name == "shard_map":
                counts["shard_maps"] += 1
                in_axes: set[str] = set()
                for entry in eqn.params.get("in_names", ()) or ():
                    in_axes |= _axis_names(entry)
                out_axes: set[str] = set()
                for entry in eqn.params.get("out_names", ()) or ():
                    out_axes |= _axis_names(entry)
                psums: list[tuple] = []
                for sub in _sub_jaxprs(eqn.params):
                    _collect_psum_axes(sub, psums)
                counts["psums"] += len(psums)
                for axes in psums:
                    missing = [a for a in axes if a not in in_axes]
                    if missing:
                        findings.append(_finding(
                            "psum_axis_unpartitioned", "error", surface,
                            f"psum over {axes} but {missing} partition no "
                            "shard_map input: nothing partial to combine",
                            axes=list(axes)))
                    leaked = [a for a in axes if a in out_axes]
                    if leaked:
                        findings.append(_finding(
                            "psum_axis_in_output", "error", surface,
                            f"psum reduces {axes} yet {leaked} still "
                            "partitions an output spec: the combine "
                            "leaked a sharded reduction", axes=list(axes)))
            for sub in _sub_jaxprs(eqn.params):
                walk(sub)

    walk(jaxpr)
    return counts, findings


def check_arch(arch: str, *, mesh_shape: tuple | None = (2, 2),
               trace_decode: bool = True, sparse: bool = True) -> dict:
    """Full shardcheck report for one arch on one mesh.

    sparse=False audits the dense engine (families whose kernels cannot
    take 2:4, e.g. xlstm's K=85 ff_down): no compressed leaves to prove,
    the psum pass still runs.
    """
    import jax
    from repro.analysis import surfaces
    from repro.dist.axes import make_rules
    report: dict[str, Any] = {"arch": arch,
                              "mesh": list(mesh_shape) if mesh_shape
                              else None}
    if mesh_shape is None:
        report.update({"skipped": "single device: no partitioning to check",
                       "findings": [], "clean": True})
        return report
    mesh = jax.make_mesh(tuple(mesh_shape), ("data", "model"))
    rules = make_rules(mesh)
    if sparse:
        # families whose prunable kernels cannot take 2:4 (a reduction dim
        # % 4 != 0, e.g. xlstm's ff_down K=85) have no compressed layout to
        # prove; auto-fall back to auditing the dense engine
        from jax.tree_util import keystr, tree_flatten_with_path
        from repro.configs.base import get_smoke_config
        from repro.core.prunable import prunable_map
        from repro.models import model as M
        probe_cfg = get_smoke_config(arch)
        shapes = M.param_shapes(probe_cfg)
        flat, _ = tree_flatten_with_path(shapes)
        flags = jax.tree.leaves(prunable_map(shapes))
        for (kp, leaf), prunable in zip(flat, flags, strict=True):
            if prunable and leaf.shape[-2] % 4:
                sparse = False
                report["sparse_note"] = (
                    f"2:4 infeasible ({keystr(kp)} K={leaf.shape[-2]} % 4 "
                    "!= 0): auditing the dense engine")
                break
    if sparse:
        cfg, params = surfaces._sparse_smoke(arch)
        leaf_counts, findings = check_leaves(cfg, params, rules)
        report["leaves"] = leaf_counts
    else:
        from repro.configs.base import get_smoke_config
        cfg = get_smoke_config(arch)
        findings = []
        report["leaves"] = {"sparse_leaves": 0}
    if trace_decode and not cfg.is_encoder_decoder:
        surfs = surfaces.serve_surfaces(arch, mesh_shape=mesh_shape,
                                        sparse=sparse)
        for s in surfs:
            closed = jax.make_jaxpr(s.fn)(*s.args)
            c, f = check_psum_axes(closed, surface=s.name)
            report.setdefault("surfaces", {})[s.name] = c
            findings.extend(f)
    elif trace_decode:
        report["surfaces"] = {
            "skipped": "encoder-decoder: slot engine unsupported "
                       "(zoo audits decode_step directly)"}
    report["findings"] = findings
    report["clean"] = not any(f["severity"] == "error" for f in findings)
    return report


def format_report(report: dict) -> str:
    lines = [f"shardcheck {report['arch']} mesh={report.get('mesh')}"]
    if report.get("skipped"):
        lines.append(f"  SKIP: {report['skipped']}")
        return "\n".join(lines)
    if report.get("sparse_note"):
        lines.append(f"  NOTE: {report['sparse_note']}")
    lc = report.get("leaves", {})
    lines.append("  leaves: " + " ".join(f"{k}={v}" for k, v in lc.items()))
    for name, c in (report.get("surfaces") or {}).items():
        lines.append(f"  surface {name}: {c}")
    for f in report.get("findings", []):
        lines.append(f"  [{f['severity'].upper()}] {f['kind']} "
                     f"{f['where']}: {f['detail']}")
    lines.append(f"  clean={report['clean']}")
    return "\n".join(lines)
