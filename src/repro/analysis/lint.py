"""Repo-native AST linter: the REPRO rule set for jax hot paths.

Every rule encodes a bug class this repo has actually shipped and later
dug out of a trace by hand:

REPRO001  host sync on a traced value in a hot path - ``float()`` /
          ``int()`` / ``.item()`` / ``np.asarray()`` on the result of a
          jitted callable inside a loop, or anywhere inside a jit/scan
          body (the ``float(nll)`` per-eval-batch sync in optim/losses).
REPRO002  wall-clock timing around async dispatch - a ``time.time()`` /
          ``time.perf_counter()`` pair bracketing a jitted call with no
          fence (``block_until_ready`` / ``.fence(`` / ``obs.timer``) and
          no host sync between the clock reads (the PR 6 calibrate-stage
          timing bug); any wall clock read inside a traced body.
REPRO003  silent fallback branch - an ``except`` handler that neither
          raises, warns (``warnings.warn`` / ``obs.log`` / logging), nor
          carries an inline justification comment on the ``except`` line
          (the pre-PR 7 silent per-plane sharding fallback class).
REPRO004  ``np.`` inside a kernel compute body - host numpy in a
          ``kernels/`` Pallas kernel function (``*_kernel`` or a body
          referencing ``pl.``/``pltpu.``) traces to a constant or a
          host round-trip instead of device compute.
REPRO005  unhashable jit static args - a ``static_argnums`` position or
          ``static_argnames`` keyword fed a list/dict/set literal
          (TypeError at call time, or a retrace per call if coerced).
REPRO006  zipped tree leaves - ``zip(jax.tree.leaves(a),
          jax.tree.leaves(b))`` without ``strict=True`` silently
          truncates on structural divergence; use ``jax.tree.map`` or
          ``zip(..., strict=True)`` (the PR 5 misalignment class).
REPRO007  clobbered XLA_FLAGS - ``os.environ["XLA_FLAGS"] = ...`` with a
          value that never reads the existing variable drops every flag
          the user set before launch (the ``launch/dryrun.py``
          device-count forcing bug); fold the old value in
          (``os.environ.get("XLA_FLAGS", "") + " --new-flag"``).

Suppression: ``# noqa`` or ``# noqa: REPRO001[,REPRO006]`` on the
offending line.  The linter is dependency-free (stdlib ``ast`` only) so
it runs in CI before anything heavyweight is installed:

    python -m repro.analysis.lint src/
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib
import sys

RULES = {
    "REPRO001": "host sync on a traced value inside a hot path",
    "REPRO002": "wall-clock timing around async dispatch without a fence",
    "REPRO003": "silent fallback branch (except with no warn/raise/comment)",
    "REPRO004": "host numpy inside a kernels/ compute body",
    "REPRO005": "unhashable literal passed as a jit static arg",
    "REPRO006": "zip over tree leaves without strict=True",
    "REPRO007": "XLA_FLAGS assignment clobbers the user's existing flags",
}

_JIT_NAMES = {"jax.jit", "jax.pjit", "pjit.pjit"}
_PARTIAL_NAMES = {"functools.partial", "partial"}
_SCAN_NAMES = {"jax.lax.scan", "lax.scan"}
_TREE_LEAVES = {"jax.tree.leaves", "tree.leaves", "jax.tree_util.tree_leaves",
                "tree_util.tree_leaves"}
_CLOCK_NAMES = {"time.time", "time.perf_counter", "time.monotonic"}
_SYNC_CALLS = {"float", "int", "bool"}
_NP_SYNC = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
            "jax.device_get"}
_WARN_CALLS = {"warnings.warn", "obs.log"}
_WARN_ATTRS = {"warn", "log", "error", "warning", "exception", "info"}


@dataclasses.dataclass
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}")


def _dotted(node: ast.AST) -> str:
    """'jax.lax.scan' for Attribute chains, 'float' for Names, '' else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_jit_call(call: ast.Call) -> bool:
    """jax.jit(...) or functools.partial(jax.jit, ...)."""
    d = _dotted(call.func)
    if d in _JIT_NAMES:
        return True
    if d in _PARTIAL_NAMES and call.args:
        return _dotted(call.args[0]) in _JIT_NAMES
    return False


def _target_names(target: ast.AST) -> list[str]:
    """Flat Name ids bound by an assignment target (tuples included)."""
    if isinstance(target, ast.Name):
        return [target.id]
    if isinstance(target, (ast.Tuple, ast.List)):
        out = []
        for elt in target.elts:
            out.extend(_target_names(elt))
        return out
    return []


def _base_name(node: ast.AST) -> str:
    """Root Name id of x / x.attr / x[i] chains, '' otherwise."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _static_argnums(call: ast.Call):
    """The literal static_argnums of a jax.jit(...) call, as a set of ints
    (positions in the CALLER's frame: the jitted callable's own args)."""
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                return {e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)}
    return set()


def _static_argnames(call: ast.Call) -> set[str]:
    """The literal static_argnames of a jax.jit(...) call."""
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                return {e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
    return set()


def _unhashable_literal(node: ast.AST) -> bool:
    return isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                             ast.DictComp, ast.SetComp))


class _ModuleScan(ast.NodeVisitor):
    """First pass: which names are jitted callables, which function defs
    are traced contexts (jit-decorated, or passed to jax.jit / lax.scan)."""

    def __init__(self):
        self.jitted_names: set[str] = set()
        self.jit_static: dict[str, set[int]] = {}
        self.jit_static_names: dict[str, set[str]] = {}
        self.traced_def_names: set[str] = set()
        self.traced_nodes: set[ast.AST] = set()

    def visit_Assign(self, node: ast.Assign):
        if isinstance(node.value, ast.Call) and _is_jit_call(node.value):
            for name in _target_names(node.targets[0] if node.targets
                                      else ast.Tuple(elts=[])):
                self.jitted_names.add(name)
                st = _static_argnums(node.value)
                if st:
                    self.jit_static[name] = st
                sn = _static_argnames(node.value)
                if sn:
                    self.jit_static_names[name] = sn
            for a in node.value.args:
                if isinstance(a, ast.Name):
                    self.traced_def_names.add(a.id)
                elif isinstance(a, ast.Lambda):
                    self.traced_nodes.add(a)
        self.generic_visit(node)

    def _scan_decorators(self, node):
        for dec in node.decorator_list:
            if isinstance(dec, ast.Call) and _is_jit_call(dec):
                self.traced_nodes.add(node)
                self.jitted_names.add(node.name)
            elif _dotted(dec) in _JIT_NAMES:
                self.traced_nodes.add(node)
                self.jitted_names.add(node.name)

    def visit_FunctionDef(self, node):
        self._scan_decorators(node)
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Call(self, node: ast.Call):
        d = _dotted(node.func)
        if d in _SCAN_NAMES and node.args:
            body = node.args[0]
            if isinstance(body, ast.Name):
                self.traced_def_names.add(body.id)
            elif isinstance(body, ast.Lambda):
                self.traced_nodes.add(body)
        elif _is_jit_call(node):
            for a in node.args:
                if isinstance(a, ast.Name):
                    self.traced_def_names.add(a.id)
                elif isinstance(a, ast.Lambda):
                    self.traced_nodes.add(a)
        self.generic_visit(node)


class _FunctionLinter:
    """Second pass: per-function rule checks with scope-local dataflow."""

    def __init__(self, scan: _ModuleScan, path: str, lines: list[str],
                 in_kernels: bool):
        self.scan = scan
        self.path = path
        self.lines = lines
        self.in_kernels = in_kernels
        self.findings: list[Finding] = []

    # -- helpers -------------------------------------------------------------

    def _suppressed(self, line: int, rule: str) -> bool:
        if 1 <= line <= len(self.lines):
            text = self.lines[line - 1]
            if "noqa" in text:
                _, _, tail = text.partition("noqa")
                tail = tail.strip()
                if not tail.startswith(":"):
                    return True      # blanket noqa
                return rule in tail
        return False

    def _emit(self, node: ast.AST, rule: str, msg: str):
        line = getattr(node, "lineno", 0)
        if not self._suppressed(line, rule):
            self.findings.append(Finding(self.path, line,
                                         getattr(node, "col_offset", 0),
                                         rule, msg))

    def _is_jitted_callable(self, func: ast.AST) -> bool:
        if isinstance(func, ast.Name):
            return func.id in self.scan.jitted_names
        if isinstance(func, ast.Call):
            return _is_jit_call(func)   # jax.jit(f)(x) inline
        return False

    # -- entry ---------------------------------------------------------------

    def run(self, fnode, traced: bool):
        traced = traced or fnode in self.scan.traced_nodes or (
            isinstance(fnode, ast.FunctionDef)
            and fnode.name in self.scan.traced_def_names)
        is_kernel_body = self.in_kernels and self._looks_like_kernel(fnode)
        body = fnode.body if isinstance(fnode.body, list) else [fnode.body]

        traced_names: set[str] = set()
        clock_vars: dict[str, int] = {}
        jit_call_lines: list[int] = []
        fence_lines: list[int] = []

        nested: list[tuple[ast.AST, bool]] = []

        def walk(node, loop_depth):
            # don't descend into nested function scopes here; queue them
            if node is not fnode and isinstance(
                    node, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.Lambda)):
                nested.append((node, traced))
                return
            if isinstance(node, ast.Assign):
                v = node.value
                if isinstance(v, ast.Call):
                    d = _dotted(v.func)
                    if self._is_jitted_callable(v.func):
                        for t in node.targets:
                            traced_names.update(_target_names(t))
                        jit_call_lines.append(node.lineno)
                    if d in _CLOCK_NAMES:
                        for t in node.targets:
                            for name in _target_names(t):
                                clock_vars[name] = node.lineno
            if isinstance(node, ast.Call):
                self._check_call(node, loop_depth, traced, is_kernel_body,
                                 traced_names, clock_vars, jit_call_lines,
                                 fence_lines)
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
                self._check_clock_delta(node, clock_vars, jit_call_lines,
                                        fence_lines)
            if isinstance(node, ast.ExceptHandler):
                self._check_except(node)
            is_loop = isinstance(node, (ast.For, ast.While, ast.AsyncFor))
            for child in ast.iter_child_nodes(node):
                walk(child, loop_depth + (1 if is_loop else 0))

        for stmt in body:
            walk(stmt, 0)
        for sub, sub_traced in nested:
            _FunctionLinter.run(self, sub, sub_traced)

    def _looks_like_kernel(self, fnode) -> bool:
        if isinstance(fnode, ast.FunctionDef) and \
                fnode.name.endswith("_kernel"):
            return True
        for node in ast.walk(fnode):
            if isinstance(node, ast.Attribute):
                base = node.value
                if isinstance(base, ast.Name) and base.id in ("pl", "pltpu"):
                    return True
        return False

    # -- rules ---------------------------------------------------------------

    def _check_call(self, node: ast.Call, loop_depth: int, traced: bool,
                    is_kernel_body: bool, traced_names: set[str],
                    clock_vars: dict, jit_call_lines: list,
                    fence_lines: list):
        d = _dotted(node.func)

        # bookkeeping for REPRO002 fences
        if ("block_until_ready" in d or d in _NP_SYNC
                or (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("fence", "block_until_ready"))
                or d in ("obs.timer", "obs.span")):
            fence_lines.append(node.lineno)

        # REPRO001: host sync on a traced value
        sync_arg = None
        if d in _SYNC_CALLS and node.args and not isinstance(
                node.args[0], ast.Constant):
            sync_arg = node.args[0]
        elif d in _NP_SYNC and node.args:
            sync_arg = node.args[0]
        elif isinstance(node.func, ast.Attribute) and \
                node.func.attr == "item" and not node.args:
            sync_arg = node.func.value
        if sync_arg is not None:
            base = _base_name(sync_arg)
            if base in traced_names and (loop_depth > 0 or traced):
                self._emit(node, "REPRO001",
                           f"`{d or 'item'}` on `{base}` pulls a jitted "
                           "result to host "
                           + ("inside a traced body" if traced else
                              "every loop iteration")
                           + "; accumulate on device and sync once")
                fence_lines.append(node.lineno)  # it IS a sync, for REPRO002
            elif base in traced_names:
                fence_lines.append(node.lineno)
            elif traced and d in _NP_SYNC:
                self._emit(node, "REPRO001",
                           f"`{d}` inside a jit/scan body forces a host "
                           "round-trip (TracerError or silent constant)")

        # REPRO002: wall clock inside a traced body
        if d in _CLOCK_NAMES and traced:
            self._emit(node, "REPRO002",
                       f"`{d}()` inside a jit/scan body reads the clock at "
                       "trace time, not run time")

        # REPRO004: host numpy inside a kernels/ compute body
        if is_kernel_body and (d.startswith("np.") or
                               d.startswith("numpy.")):
            self._emit(node, "REPRO004",
                       f"`{d}` inside a kernel body runs on host at trace "
                       "time; use jnp/lax (or hoist to the wrapper)")

        # REPRO005: unhashable literal at a static position
        if self._is_jitted_callable(node.func):
            jit_call_lines.append(node.lineno)
            static = set()
            if isinstance(node.func, ast.Name):
                static = self.scan.jit_static.get(node.func.id, set())
            elif isinstance(node.func, ast.Call):
                static = _static_argnums(node.func)
            for i in static:
                if i < len(node.args) and _unhashable_literal(node.args[i]):
                    self._emit(node.args[i], "REPRO005",
                               f"static arg {i} is an unhashable literal; "
                               "jit static args must hash (use a tuple)")
        # static_argnames misuse: a declared-static keyword fed an
        # unhashable literal at the call site of the jitted name
        if isinstance(node.func, ast.Name):
            static_kw = self.scan.jit_static_names.get(node.func.id, set())
            for kw in node.keywords:
                if kw.arg in static_kw and _unhashable_literal(kw.value):
                    self._emit(kw.value, "REPRO005",
                               f"static keyword `{kw.arg}` of jitted "
                               f"`{node.func.id}` is an unhashable literal")

        # REPRO006: zipped tree leaves without strict=True
        if d == "zip":
            leaves = [a for a in node.args if isinstance(a, ast.Call)
                      and _dotted(a.func) in _TREE_LEAVES]
            strict = any(kw.arg == "strict" and
                         isinstance(kw.value, ast.Constant) and
                         kw.value.value is True for kw in node.keywords)
            if len(leaves) >= 2 and not strict:
                self._emit(node, "REPRO006",
                           "zip over tree leaves silently truncates on "
                           "structural divergence; use jax.tree.map or "
                           "zip(..., strict=True)")

    def _check_clock_delta(self, node: ast.BinOp, clock_vars: dict,
                           jit_call_lines: list, fence_lines: list):
        """t1 - t0 (or time.time() - t0) bracketing a jitted call."""
        right = node.right
        r_name = right.id if isinstance(right, ast.Name) else ""
        if r_name not in clock_vars:
            return
        start = clock_vars[r_name]
        left = node.left
        stop = node.lineno
        is_clock_delta = (isinstance(left, ast.Call)
                          and _dotted(left.func) in _CLOCK_NAMES) or \
            (isinstance(left, ast.Name) and left.id in clock_vars)
        if not is_clock_delta:
            return
        dispatched = [ln for ln in jit_call_lines if start <= ln <= stop]
        fenced = [ln for ln in fence_lines if start <= ln <= stop]
        if dispatched and not fenced:
            self._emit(node, "REPRO002",
                       "clock pair brackets an async jitted dispatch with "
                       "no fence; the delta under-reports device time (use "
                       "obs.timer / block_until_ready / a host sync)")

    def _check_except(self, node: ast.ExceptHandler):
        for stmt in ast.walk(node):
            if isinstance(stmt, ast.Raise):
                return
            if isinstance(stmt, ast.Call):
                d = _dotted(stmt.func)
                if d in _WARN_CALLS:
                    return
                if isinstance(stmt.func, ast.Attribute) and \
                        stmt.func.attr in _WARN_ATTRS:
                    return
        # a comment anywhere in the handler is an accepted justification
        end = getattr(node, "end_lineno", node.lineno) or node.lineno
        for ln in range(node.lineno, min(end, len(self.lines)) + 1):
            if "#" in self.lines[ln - 1]:
                return
        self._emit(node, "REPRO003",
                   "except handler swallows the failure silently; warn "
                   "(obs.log / warnings.warn), raise, or justify with an "
                   "inline comment")


def _reads_existing_env(value: ast.AST) -> bool:
    """Does the assigned value fold in the current environment (any
    ``os.environ`` read or ``os.getenv`` call)?"""
    for n in ast.walk(value):
        if isinstance(n, (ast.Attribute, ast.Name)) and \
                _dotted(n) == "os.environ":
            return True
        if isinstance(n, ast.Call) and _dotted(n.func) == "os.getenv":
            return True
    return False


def _check_env_clobber(tree: ast.AST, linter: _FunctionLinter) -> None:
    """REPRO007, module-wide: the offending assignments typically sit at
    module top level (pre-jax-import), outside every function scope."""
    for node in ast.walk(tree):
        if not isinstance(node, ast.Assign):
            continue
        for t in node.targets:
            if (isinstance(t, ast.Subscript)
                    and _dotted(t.value) == "os.environ"
                    and isinstance(t.slice, ast.Constant)
                    and t.slice.value == "XLA_FLAGS"
                    and not _reads_existing_env(node.value)):
                linter._emit(node, "REPRO007",
                             'assignment to os.environ["XLA_FLAGS"] drops '
                             "any flags already set; append to "
                             'os.environ.get("XLA_FLAGS", "") instead')


def lint_source(src: str, path: str = "<string>") -> list[Finding]:
    """Lint one python source string; returns findings sorted by line."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:  # unparseable file: surfaced as a finding
        return [Finding(path, e.lineno or 0, 0, "REPRO000",
                        f"syntax error: {e.msg}")]
    scan = _ModuleScan()
    scan.visit(tree)
    lines = src.splitlines()
    in_kernels = "kernels" in pathlib.PurePath(path).parts
    linter = _FunctionLinter(scan, path, lines, in_kernels)
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            linter.run(node, traced=False)
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    linter.run(sub, traced=False)
    _check_env_clobber(tree, linter)
    linter.findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return linter.findings


def lint_paths(paths, *, rules: set[str] | None = None) -> list[Finding]:
    """Lint files / directory trees (``*.py``, tests excluded by callers)."""
    out: list[Finding] = []
    for p in paths:
        p = pathlib.Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            found = lint_source(f.read_text(encoding="utf-8"), str(f))
            if rules:
                found = [x for x in found if x.rule in rules]
            out.extend(found)
    return out


def main(argv: list[str] | None = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="repo-native jax hot-path linter (REPRO001-007)")
    ap.add_argument("paths", nargs="*", help="files or directories")
    ap.add_argument("--rules", help="comma-separated rule ids to enable")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rid, desc in RULES.items():
            print(f"{rid}  {desc}")
        return 0
    if not args.paths:
        ap.error("paths required (or --list-rules)")
    rules = set(args.rules.split(",")) if args.rules else None
    findings = lint_paths(args.paths, rules=rules)
    for f in findings:
        print(f)
    print(f"{len(findings)} finding(s)", file=sys.stderr)
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
