"""Task losses and perplexity evaluation."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def lm_loss(cfg: ModelConfig, params: Any, batch: dict, *,
            remat: bool = False, aux_weight: float = 0.01,
            unroll: bool = False):
    """Next-token cross entropy. batch["tokens"]: (B, S); optional
    batch["mask"]: (B, S) loss weights. Returns (loss, metrics)."""
    logits, aux, _ = M.forward(cfg, params, batch, remat=remat, unroll=unroll)
    tokens = batch["tokens"]
    if cfg.vit_dim and "patches" in batch:  # image prefix produces no loss
        logits = logits[:, -tokens.shape[1]:]
    targets = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, targets[..., None], axis=-1)[..., 0]
    w = batch.get("mask")
    w = jnp.ones_like(nll) if w is None else w[:, 1:].astype(jnp.float32)
    token_nll = jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
    loss = token_nll + aux_weight * aux
    return loss, {"nll": token_nll, "aux": aux}


def eval_ppl(cfg: ModelConfig, params: Any, batches: list[dict]) -> float:
    """Perplexity over a list of batches (held-out synthetic corpus).

    The per-batch NLL stays on device (``float(nll)`` here used to force a
    host sync per batch, serializing the eval loop against async dispatch -
    REPRO001); the weighted sum accumulates as a device scalar and syncs
    exactly once at the end.
    """
    fn = jax.jit(lambda p, b: lm_loss(cfg, p, b)[1]["nll"])
    tot_nll = jnp.zeros((), jnp.float32)
    tot_tok = 0
    for b in batches:
        n = b["tokens"][:, 1:].size
        tot_nll = tot_nll + fn(params, b) * n
        tot_tok += n
    import math
    return math.exp(min(float(tot_nll) / max(tot_tok, 1), 30.0))
