"""Gradient compression: int8 error-feedback all-reduce.

DP gradient sync moves |params| fp32 bytes per step; int8 + per-tensor scale
cuts ICI traffic ~4x.  Error feedback (Seide et al. / EF-SGD) accumulates the
quantization residual locally so the compressed SGD direction is unbiased in
the long run - required for convergence at int8.

`compressed_allreduce` is written against an axis name for use inside
shard_map; `simulate_workers` provides a device-free harness used by the
tests and by benchmarks to measure the bytes saved.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_quantize(x: jax.Array, err: jax.Array) -> tuple[jax.Array, jax.Array,
                                                       jax.Array]:
    """Quantize (x + carried error); returns (q, scale, new_err)."""
    corrected = x + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def compressed_allreduce(x: jax.Array, err: jax.Array, axis_name: str
                         ) -> tuple[jax.Array, jax.Array]:
    """Mean-all-reduce of x over `axis_name` at int8 wire format.

    Inside shard_map: each worker quantizes its shard with error feedback,
    the int8 payload is all-gathered (the compressed collective), and the
    dequantized sum is formed locally.  Returns (mean, new_err).
    """
    q, scale, new_err = ef_quantize(x, err)
    qs = jax.lax.all_gather(q, axis_name)          # int8 wire traffic
    ss = jax.lax.all_gather(scale, axis_name)      # tiny
    n = qs.shape[0]
    total = jnp.sum(qs.astype(jnp.float32) *
                    ss.reshape((n,) + (1,) * x.ndim), axis=0)
    return total / n, new_err


def tree_ef_init(grads: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def simulate_workers(worker_grads: list[PyTree], errs: list[PyTree]
                     ) -> tuple[PyTree, list[PyTree]]:
    """Device-free reference of the compressed mean-all-reduce."""
    n = len(worker_grads)
    qs, new_errs = [], []
    for g, e in zip(worker_grads, errs):
        flat_q = jax.tree.map(
            lambda x, er: ef_quantize(x.astype(jnp.float32), er), g, e)
        qs.append(flat_q)
        new_errs.append(jax.tree.map(lambda t: t[2], flat_q,
                                     is_leaf=lambda x: isinstance(x, tuple)))
    def combine(*per_worker):
        acc = None
        for (q, s, _e) in per_worker:
            d = dequantize_int8(q, s)
            acc = d if acc is None else acc + d
        return acc / n
    mean = jax.tree.map(combine, *qs,
                        is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_errs


def wire_bytes(tree: PyTree, *, compressed: bool) -> int:
    tot = 0
    for x in jax.tree.leaves(tree):
        tot += x.size * (1 if compressed else 4)
    return tot
