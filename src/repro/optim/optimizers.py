"""Optimizers from scratch (no optax in the environment).

AdamW with optional ZeRO-style state sharding (states inherit the FSDP
sharding of their parameters - under pjit this IS ZeRO-3: states live
sharded, updates are local, no gather), gradient clipping, warmup-cosine
schedule, and SGD for the mirror-descent-style plain steps.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: PyTree
    nu: PyTree
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def warmup_cosine(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.minimum(warm, cos)


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: PyTree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: x * scale, tree), g


def adamw_init(params: PyTree) -> AdamWState:
    z = lambda: jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(mu=z(), nu=z(), count=jnp.zeros((), jnp.int32))


def adamw_update(cfg: AdamWConfig, grads: PyTree, state: AdamWState,
                 params: PyTree):
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    lr = warmup_cosine(cfg, count)
    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                      state.nu, grads)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c

    def upd(p, m, v):
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        return (p - lr * (step + cfg.weight_decay * p)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count), \
        {"grad_norm": gnorm, "lr": lr}


def sgd_update(lr: float, grads: PyTree, params: PyTree) -> PyTree:
    return jax.tree.map(lambda p, g: (p - lr * g).astype(p.dtype),
                        params, grads)
