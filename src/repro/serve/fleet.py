"""Multi-budget sparsity fleet: ONE mask bank, N budgets, one router.

UniPruning's headline property (paper §4.3) is that a single calibration
yields masks for *arbitrary* sparsity levels in one shot - global-update
baselines (SparseLLM, surrogate-free ADMM) re-solve per target
configuration.  The fleet is where that property reaches serving: one
``MaskBank`` artifact materializes N budget variants (dense passthrough,
unstructured masked-dense, N:M compressed) behind a single router, so
quality/latency tradeoffs A/B live against real traffic instead of per
re-deployed process.

Construction cost is amortized three ways:

* the bank's calibration state is loaded once and **thresholded once per
  budget** (``MaskBank.masks_at`` memoizes per (sparsity, nm) key); two
  members at the same budget share one params tree (the fleet memoizes
  ``sparse_params`` per budget too);
* dense leaves that pruning leaves untouched (embeddings, norms, biases)
  pass through ``sparse_params`` by object identity, so N members share ONE
  copy (``sparse.apply.shared_leaves`` counts the invariant);
* all members share one :class:`~repro.serve.engine.EngineFns` - the jitted
  decode/prefill/slot-write entry points - so step functions compile once
  per distinct params *structure*, not once per engine.

Routing: ``submit(prompt, budget=...)`` pins a request to one member;
``submit(prompt, ab=...)`` splits traffic across members by weight
(deterministic weighted fair scheduling - no RNG, reproducible splits) and
mirrors each off-reference request onto the *densest* member so the router
accumulates per-budget token-agreement alongside tokens/s;
``submit(prompt, spec=True)`` routes through the self-speculative decoder
(``serve.spec``): the sparse draft member proposes k tokens per round and
the dense member verifies them in one teacher-forced jitted pass, with the
two members interleaved inside one fleet step instead of ``run()``'s
sequential per-member drain - output bit-identical to the verifier alone.
``report()`` returns the live quality/latency table; ``agreement_matrix``
serves a prompt set through every member for the full NxN comparison.

The slot pool is partitioned across members at construction: ``slots``
total decode slots spread round-robin (every member gets at least one).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Iterable, Mapping

import jax
import numpy as np

from repro import obs
from repro.serve.engine import EngineFns, ServeEngine
from repro.serve.spec import SpecConfig, SpecDecoder, parse_spec

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Budget:
    """One fleet member's sparsity target.

    kind: ``dense`` (serve params0 untouched), ``unstructured`` (global
    budget, masked-dense serving) or ``nm`` ((n, m) semi-structured,
    2:4-compressed kernels when the pattern is 2:4).
    """
    kind: str
    sparsity: float = 0.0
    nm: tuple[int, int] | None = None

    @property
    def name(self) -> str:
        if self.kind == "nm":
            return f"{self.nm[0]}:{self.nm[1]}"
        return "0.0" if self.kind == "dense" else f"{self.sparsity:g}"

    @property
    def pruned_frac(self) -> float:
        """Fraction of prunable weights removed (density ordering key)."""
        if self.kind == "dense":
            return 0.0
        if self.kind == "nm":
            return 1.0 - self.nm[0] / self.nm[1]
        return self.sparsity


def parse_budget(spec) -> Budget:
    """``"2:4"`` / ``(2, 4)`` -> N:M; ``"0.5"`` / ``0.5`` -> unstructured;
    ``"0.0"`` / ``0`` / ``"dense"`` -> dense passthrough."""
    if isinstance(spec, Budget):
        return spec
    if isinstance(spec, tuple):
        n, m = spec
        return Budget("nm", nm=(int(n), int(m)))
    if isinstance(spec, (int, float)) and not isinstance(spec, bool):
        s = float(spec)
    else:
        text = str(spec).strip().lower()
        if text == "dense":
            return Budget("dense")
        if ":" in text:
            n, m = text.split(":")
            return Budget("nm", nm=(int(n), int(m)))
        s = float(text)
    if not 0.0 <= s < 1.0:
        raise ValueError(f"sparsity budget must be in [0, 1), got {s}")
    return Budget("dense") if s == 0.0 else Budget("unstructured", sparsity=s)


def token_agreement(a: list, b: list) -> float:
    """Positionwise match fraction over the longer stream (a length
    mismatch - e.g. one side hit eos earlier - counts as disagreement)."""
    n = max(len(a), len(b))
    if n == 0:
        return 1.0
    return sum(x == y for x, y in zip(a, b)) / n


def _partition_slots(slots: int, n: int) -> list[int]:
    """Spread ``slots`` across ``n`` members, earlier members first."""
    base, rem = divmod(slots, n)
    return [base + (i < rem) for i in range(n)]


class SparsityFleet:
    """N sparsity budgets from one mask bank behind a single router."""

    def __init__(self, bank, params0: PyTree, budgets: Iterable, *,
                 slots: int | None = None, capacity: int = 512,
                 decode_mode: str = "fused", rules: Any = None,
                 eos_id: int | None = None, idx_bits: int = 2,
                 spec: Any = None):
        from repro.sparse import apply as apply_mod
        self.bank = bank
        self.cfg = bank.cfg
        budgets = [parse_budget(b) for b in budgets]
        self._order = [b.name for b in budgets]
        if len(set(self._order)) != len(self._order):
            raise ValueError(f"duplicate budgets in fleet: {self._order}")
        self.budgets = {b.name: b for b in budgets}
        slots = 2 * len(budgets) if slots is None else slots
        if slots < len(budgets):
            raise ValueError(
                f"{slots} slots cannot cover {len(budgets)} budgets "
                "(every member needs at least one)")
        # agreement is a fraction: default ms-scale histogram edges would
        # lump everything under the first bucket
        obs.declare_hist("fleet.mirror_agreement",
                         tuple(i / 10 for i in range(1, 11)))
        # the shared helper: one set of jitted step functions for every
        # member (see EngineFns - compile per params structure, not per
        # engine)
        self.fns = EngineFns(self.cfg, capacity, decode_mode, rules=rules)
        self.engines: dict[str, ServeEngine] = {}
        self.reports: dict[str, dict] = {}
        for b, s in zip(budgets, _partition_slots(slots, len(budgets))):
            params, report = self._materialize(b, params0, idx_bits,
                                               apply_mod)
            self.engines[b.name] = ServeEngine(
                self.cfg, params, slots=s, capacity=capacity,
                decode_mode=decode_mode, rules=rules, eos_id=eos_id,
                fns=self.fns, labels={"budget": b.name})
            self.reports[b.name] = report
        # densest member = the quality reference A/B agreement is scored
        # against (ties break toward earlier budget order)
        self.reference = min(
            budgets, key=lambda b: (b.pruned_frac,
                                    self._order.index(b.name))).name
        self._routes: dict[int, tuple[str, int]] = {}   # frid -> (name, rid)
        self._shadows: dict[int, int] = {}  # frid -> reference engine rid
        self._next_rid = 0
        self._ab_served: dict[str, int] = {n: 0 for n in self._order}
        # per-member counters; "shadow" keeps A/B mirror traffic out of the
        # headline tokens/seconds (the skew fix: shadow tokens used to fold
        # into the reference's tok_s while its request count ignored them),
        # "spec_phase_tokens" counts foreign tokens spec rounds advanced
        self._stats = {n: {"requests": 0, "tokens": 0, "seconds": 0.0,
                           "mirrored_picks": 0, "spec_phase_tokens": 0,
                           "agree_sum": 0.0, "agree_n": 0,
                           "shadow": {"requests": 0, "tokens": 0,
                                      "seconds": 0.0}}
                       for n in self._order}
        # speculative decoding (serve.spec): built lazily on the first
        # spec-routed submit so fleets that never use it pay nothing
        self.spec_config = parse_spec(spec) if spec is not None else None
        self._spec: SpecDecoder | None = None
        self._spec_names: tuple[str, str] | None = None
        self._spec_routes: dict[int, int] = {}  # frid -> spec decoder rid

    @classmethod
    def from_artifact(cls, bank_dir, params0: PyTree, budgets: Iterable,
                      **kw) -> "SparsityFleet":
        """One artifact -> N budget engines (no re-calibration)."""
        from repro.sparse.bank import MaskBank
        return cls(MaskBank.load(bank_dir), params0, budgets, **kw)

    # -- per-budget weights --------------------------------------------------

    def _materialize(self, b: Budget, params0: PyTree, idx_bits: int,
                     apply_mod) -> tuple[PyTree, dict]:
        """Budget -> (params tree, byte report).  Budget names are unique
        per fleet, so this runs once per member; the expensive part - the
        threshold pass over the calibration state - is memoized in the bank
        itself (``MaskBank.masks_at``), shared across fleets over one bank.
        """
        n_leaves = len(jax.tree.leaves(params0))
        if b.kind == "dense":
            # passthrough: every leaf shared, trivially token-identical to a
            # plain dense engine over the same params0
            report = {"weight_bytes_ratio": 1.0, "compressed_kernels": 0,
                      "fallback_leaves": 0, "shared_dense_leaves": n_leaves}
            out = (params0, report)
        else:
            compressed = b.kind == "nm"
            params, masks = self.bank.sparse_params(
                params0,
                sparsity=b.sparsity if b.kind == "unstructured" else None,
                nm=b.nm, compressed=compressed, idx_bits=idx_bits,
                with_masks=True)
            rep = apply_mod.compressed_report(params, masks)
            report = {"weight_bytes_ratio": rep["ratio"],
                      "compressed_kernels": len(rep["layers"])
                      - rep["fallback_leaves"],
                      "fallback_leaves": rep["fallback_leaves"],
                      "shared_dense_leaves":
                          apply_mod.shared_leaves(params0, params)}
            out = (params, report)
        return out

    # -- routing -------------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16, *,
               budget=None, ab=None, spec=None) -> int:
        """Route one request; exactly one of ``budget=``/``ab=``/``spec=``.

        budget: a member (any ``parse_budget`` spelling) - pinned routing.
        ab: True (uniform split) or a {budget: weight} mapping - the fleet
        picks the member deterministically (weighted fair: the member with
        the smallest served/weight ratio) and, when the pick is not the
        densest member, mirrors the request onto the reference engine so
        ``report()`` accumulates token-agreement for the pick.
        spec: True routes through the fleet's speculative decoder (the
        sparse draft member proposes, the dense member verifies - output
        bit-identical to the verifier decoding alone, see ``serve.spec``);
        pass a :class:`SpecConfig` or a ``draft:2:4,verify:0.0,k:4`` string
        to configure the decoder on first use instead of the fleet's
        ``spec=`` construction argument.
        """
        if (budget is not None) + (ab is not None) + (spec is not None) != 1:
            raise ValueError("pass exactly one of budget=, ab= or spec=")
        if spec is not None:
            sd = self._spec_decoder(None if spec is True else spec)
            frid = self._next_rid
            self._next_rid += 1
            self._spec_routes[frid] = sd.submit(prompt, max_tokens)
            if obs.enabled():
                d, v = self._spec_names
                obs.inc("fleet.requests", budget=f"spec:{d}>{v}")
            return frid
        if budget is not None:
            name = parse_budget(budget).name
            if name not in self.engines:
                raise KeyError(
                    f"budget {name!r} not in fleet {self._order}")
        else:
            name = self._pick_ab(ab)
        frid = self._next_rid
        self._next_rid += 1
        erid = self.engines[name].submit(prompt, max_tokens)
        self._routes[frid] = (name, erid)
        self._stats[name]["requests"] += 1
        if obs.enabled():
            obs.inc("fleet.requests", budget=name)
            obs.set_gauge("fleet.queue_depth",
                          len(self.engines[name].queue), budget=name)
        if ab is not None and name != self.reference:
            # shadow for live agreement: same prompt through the densest
            # member, consumed by the stats only (never returned to the
            # caller under this frid)
            self._shadows[frid] = self.engines[self.reference].submit(
                prompt, max_tokens)
            self._stats[name]["mirrored_picks"] += 1
            obs.inc("fleet.mirrored_picks", budget=name)
        return frid

    def _pick_ab(self, ab) -> str:
        if ab is True:
            weights = {n: 1.0 for n in self._order}
        elif isinstance(ab, Mapping):
            weights = {parse_budget(k).name: float(v) for k, v in ab.items()}
        else:
            raise TypeError(f"ab= takes True or a mapping, got {type(ab)}")
        unknown = set(weights) - set(self.engines)
        if unknown:
            raise KeyError(f"ab budgets {sorted(unknown)} not in fleet "
                           f"{self._order}")
        if not weights or min(weights.values()) <= 0:
            raise ValueError(f"ab weights must be positive: {weights}")
        # deterministic weighted fair pick: lowest (served+1)/weight next
        name = min(weights, key=lambda n: ((self._ab_served[n] + 1)
                                           / weights[n],
                                           self._order.index(n)))
        self._ab_served[name] += 1
        return name

    def _spec_decoder(self, override=None) -> SpecDecoder:
        """The fleet's (lazily-built) speculative decoder; one per fleet -
        the (draft, verifier) pair is fixed at first use."""
        if override is not None:
            sc = parse_spec(override)
            if self._spec is not None and sc != self.spec_config:
                raise ValueError(
                    f"fleet speculative decoder already configured as "
                    f"{self.spec_config}; cannot reconfigure to {sc}")
            self.spec_config = sc
        if self._spec is None:
            sc = self.spec_config or SpecConfig()
            dname = parse_budget(sc.draft).name
            vname = (parse_budget(sc.verify).name if sc.verify is not None
                     else self.reference)
            for nm in (dname, vname):
                if nm not in self.engines:
                    raise KeyError(
                        f"spec member {nm!r} not in fleet {self._order}")
            if dname == vname:
                raise ValueError(
                    f"spec draft and verifier are both {dname!r}; pick a "
                    "sparser draft than the verifier")
            # seed adaptive k from the live A/B agreement of the drafting
            # member vs the reference, when any has accumulated
            st = self._stats[dname]
            init = (st["agree_sum"] / st["agree_n"] if st["agree_n"]
                    and vname == self.reference else None)
            self._spec = SpecDecoder(
                self.engines[dname], self.engines[vname], k=sc.k,
                k_min=sc.k_min, k_max=sc.k_max, adaptive=sc.adaptive,
                ema=sc.ema, ema_hi=sc.ema_hi, ema_lo=sc.ema_lo,
                init_accept=init, labels={"draft": dname, "verify": vname})
            self._spec_names = (dname, vname)
        return self._spec

    def run(self) -> dict[int, list[int]]:
        """Drive every member to completion; returns fleet rid -> tokens.

        Spec-routed traffic runs FIRST: the speculative decoder interleaves
        the draft and verifier members round by round inside one fleet
        step (instead of this loop's sequential per-member drain), and any
        foreign requests it finished along the way merge into the member
        results below.  Per-member wall time and token counts accumulate
        into ``report()``; A/B shadow outputs are folded into the router's
        agreement stats and dropped (the caller sees only the member its
        request routed to), and their tokens/seconds accumulate under the
        member's ``shadow`` key so headline tok_s stays shadow-free.
        """
        per_engine: dict[str, dict[int, list[int]]] = {}
        merged: dict[int, list[int]] = {}
        if self._spec is not None and self._spec.pending:
            dname, vname = self._spec_names
            sp = obs.span("fleet.run_spec", draft=dname, verify=vname)
            with sp:
                t0 = time.perf_counter()
                spec_res, spec_foreign = self._spec.run()
                dt = time.perf_counter() - t0
            self._spec.stats["seconds"] += dt
            for kind, nm in (("draft", dname), ("verify", vname)):
                fin = spec_foreign[kind]
                if fin:
                    per_engine.setdefault(nm, {}).update(fin)
                    self._stats[nm]["spec_phase_tokens"] += sum(
                        len(v) for v in fin.values())
            for frid, srid in list(self._spec_routes.items()):
                if srid in spec_res:
                    merged[frid] = spec_res[srid]
                    del self._spec_routes[frid]
        shadow_rids = set(self._shadows.values())
        for name, eng in self.engines.items():
            if not eng.pending:
                continue
            sp = obs.span("fleet.run_member", budget=name)
            with sp:
                t0 = time.perf_counter()
                res = eng.run()
                dt = time.perf_counter() - t0
            per_engine.setdefault(name, {}).update(res)
            st = self._stats[name]
            total = sum(len(v) for v in res.values())
            sh_toks = (sum(len(v) for rid, v in res.items()
                           if rid in shadow_rids)
                       if name == self.reference else 0)
            # shadow work rode the same batched steps as real traffic, so
            # its share of the member's wall time is prorated by tokens
            sh_dt = dt * sh_toks / total if total else 0.0
            st["seconds"] += dt - sh_dt
            st["tokens"] += total - sh_toks
            if sh_toks:
                st["shadow"]["tokens"] += sh_toks
                st["shadow"]["seconds"] += sh_dt
                st["shadow"]["requests"] += sum(
                    1 for rid in res if rid in shadow_rids)
            if obs.enabled():
                obs.set_gauge("fleet.queue_depth", len(eng.queue),
                              budget=name)
        for frid, (name, erid) in list(self._routes.items()):
            res = per_engine.get(name, {})
            if erid not in res:
                continue
            merged[frid] = res[erid]
            del self._routes[frid]
            shadow = self._shadows.pop(frid, None)
            if shadow is not None:
                ref_out = per_engine[self.reference][shadow]
                st = self._stats[name]
                agree = token_agreement(merged[frid], ref_out)
                st["agree_sum"] += agree
                st["agree_n"] += 1
                obs.observe("fleet.mirror_agreement", agree, budget=name)
        return merged

    # -- live quality/latency ------------------------------------------------

    def report(self) -> dict:
        """Per-budget serving table: slots, traffic, tok/s, compressed
        ratio, A/B token-agreement vs the densest member, and (with the
        flight recorder on) per-budget decode-latency percentiles.

        Every number is LIFETIME-scoped and safe to poll: ``cumulative``
        holds the monotonic counters (tokens, requests, mirrored picks,
        busy seconds) and the top-level ``tok_s`` / agreement fields are
        lifetime averages over exactly those counters - repeated
        ``report()`` calls never alias an interval rate with a lifetime
        one.  Interval rates are the caller's delta of two ``cumulative``
        snapshots.
        """
        budgets = {}
        for name in self._order:
            st = self._stats[name]
            budgets[name] = {
                "slots": self.engines[name].slots,
                "requests": st["requests"],
                "tokens": st["tokens"],
                "tok_s": (st["tokens"] / st["seconds"]
                          if st["seconds"] else None),
                "token_agreement_vs_reference": (
                    st["agree_sum"] / st["agree_n"] if st["agree_n"]
                    else None),
                "cumulative": {
                    "tokens": st["tokens"],
                    "requests": st["requests"],
                    "mirrored_picks": st["mirrored_picks"],
                    "seconds": st["seconds"],
                    "spec_phase_tokens": st["spec_phase_tokens"],
                },
                # A/B mirror traffic, tracked apart so the headline tok_s
                # and per-request numbers above stay shadow-free
                "shadow": dict(st["shadow"]),
                # populated when the flight recorder is enabled (None
                # otherwise): bucket-estimated percentiles over every
                # decode step this member served
                "decode_ms_p50": obs.percentile("serve.decode_step_ms", 50,
                                                budget=name),
                "decode_ms_p95": obs.percentile("serve.decode_step_ms", 95,
                                                budget=name),
                **self.reports[name],
            }
        return {"reference": self.reference, "budgets": budgets,
                "spec": (self._spec.summary() if self._spec is not None
                         else None)}

    def agreement_matrix(self, prompts: list, max_tokens: int = 8
                         ) -> tuple[dict, dict]:
        """Serve every prompt through every member (live traffic, counted
        in ``report()``); returns (NxN mean token-agreement, per-member
        outputs)."""
        rids = {name: [self.submit(p, max_tokens, budget=name)
                       for p in prompts] for name in self._order}
        res = self.run()
        outs = {name: [res[r] for r in rids[name]] for name in self._order}
        matrix = {
            a: {b: float(np.mean([token_agreement(x, y) for x, y
                                  in zip(outs[a], outs[b])]))
                for b in self._order}
            for a in self._order}
        return matrix, outs
