"""Serving layer.

* ``engine`` - :class:`ServeEngine`, slot-based continuous batching over a
  fixed-slot KV cache (dense or 2:4-compressed weights), with the jitted
  step functions factored into :class:`EngineFns` so multiple engines can
  share compilations.
* ``fleet`` - :class:`SparsityFleet`, N sparsity budgets materialized from
  ONE mask bank and served behind a single router with tagged and A/B
  traffic splitting (per-budget tok/s + token-agreement vs the densest
  member).
* ``spec`` - :class:`SpecDecoder`, self-speculative decoding across two
  fleet members: the sparse member drafts k tokens per round, the dense
  member verifies them in one teacher-forced jitted pass; output streams
  are bit-identical to the verifier decoding alone.
"""
from repro.serve.engine import EngineFns, ServeEngine  # noqa: F401
from repro.serve.fleet import (  # noqa: F401
    Budget, SparsityFleet, parse_budget, token_agreement)
from repro.serve.spec import (  # noqa: F401
    SpecConfig, SpecDecoder, accept_commit, parse_spec)
