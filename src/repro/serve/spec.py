"""Self-speculative fleet decoding: a sparse member drafts, dense verifies.

UniPruning's one-calibration-many-budgets property gives the fleet a free
family of cheap draft models that share every untouched leaf (embeddings,
norms) and the whole KV-cache layout with the dense reference - masks never
touch attention state.  Speculative decoding monetizes their token
agreement: per round, the high-sparsity draft member autoregressively
proposes k tokens from its own jitted decode loop (``EngineFns.draft`` -
ONE dispatch for all k), and the verifier re-derives the greedy
continuation over the same k fed tokens in ONE teacher-forced jitted pass
(``EngineFns.verify``).  The longest agreeing prefix commits, plus the
verifier's own token at the first disagreement, so every round commits
between 1 and k tokens in 2 dispatches - against k dispatches for the
plain per-token loop - and the output stream is BIT-IDENTICAL to the
verifier decoding alone (greedy speculative decoding is lossless; both
scan bodies are exactly ``model.decode_step``).

Accept/rollback is pure position bookkeeping, never cache surgery.  Both
members write ring rows for all k fed positions; a rejected suffix simply
stays AHEAD of the slot's committed position vector, where
``attention.ring_positions`` masks it (kpos > t is invisible), until the
committed stream reaches each row and overwrites it - the next round's
first fed token lands exactly on the first stale row.  Two invariants make
this safe, both enforced here:

* every layer cache must be a full-capacity position-masked attention ring
  (kinds in :data:`SPEC_SAFE_KINDS`; sliding windows cap the ring below
  capacity and recurrent state folds irreversibly, so neither can roll
  back - rejected at construction);
* a round never writes a ring row past capacity unless it is the committed
  next position itself: ``k_eff`` shrinks to the capacity headroom,
  bottoming out at 1 = plain decode (which may wrap, like plain decode).

Adaptive k: an EMA of the per-round draft acceptance rate (seedable from
the fleet's live agreement stats) grows k toward ``k_max`` while drafts
keep being accepted and shrinks it toward ``k_min`` when they stop; each
distinct k is its own jit bucket, counted in ``serve.jit_entries``.

Mixed traffic: engine slots NOT owned by a spec route ("foreign" - pinned
or A/B fleet requests on the draft/verify members) still advance exactly
one token per round, read from column 0 of the same batched dispatch -
which is precisely the plain fused decode of that slot, so foreign streams
stay bit-identical too and the members never stall behind spec rounds.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis import recompile
from repro.serve.engine import ServeEngine

__all__ = ["SPEC_SAFE_KINDS", "SpecConfig", "SpecDecoder", "accept_commit",
           "parse_spec"]

# layer kinds whose decode caches are full-capacity position-masked
# attention rings (plain and MLA): junk rows ahead of the committed
# position are invisible until overwritten, so rollback is free.  Windowed
# rings ("local"/"moe_local") evict real rows on speculative writes;
# recurrent kinds (ssm/xlstm) fold every fed token into their state.
SPEC_SAFE_KINDS = {"attn", "moe", "mla_dense", "mla_moe"}


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decode knobs (``parse_spec`` builds one from the CLI
    string ``draft:2:4,verify:0.0,k:4``)."""
    draft: str = "2:4"            # drafting member (any parse_budget form)
    verify: str | None = None     # verifying member; None = fleet reference
    k: int = 4                    # draft width (tokens proposed per round)
    k_min: int = 1
    k_max: int = 8
    adaptive: bool = True         # move k with the acceptance-rate EMA
    ema: float = 0.8              # EMA decay toward history
    ema_hi: float = 0.8           # grow k while EMA >= hi
    ema_lo: float = 0.4           # shrink k while EMA < lo


def parse_spec(text) -> SpecConfig:
    """``"draft:2:4,verify:0.0,k:4"`` -> :class:`SpecConfig`.

    Comma-separated ``key:value`` pairs, split on the FIRST colon so budget
    values keep their own (``draft:2:4`` = draft member "2:4").
    """
    if isinstance(text, SpecConfig):
        return text
    kw: dict[str, Any] = {}
    for part in str(text).split(","):
        part = part.strip()
        if not part:
            continue
        if ":" not in part:
            raise ValueError(
                f"spec part {part!r} is not key:value "
                "(expected e.g. draft:2:4,verify:0.0,k:4)")
        key, val = part.split(":", 1)
        key, val = key.strip(), val.strip()
        if key in ("draft", "verify"):
            kw[key] = val
        elif key in ("k", "k_min", "k_max"):
            kw[key] = int(val)
        elif key == "adaptive":
            kw[key] = val.lower() in ("1", "true", "yes", "on")
        elif key in ("ema", "ema_hi", "ema_lo"):
            kw[key] = float(val)
        else:
            raise ValueError(f"unknown spec key {key!r} in {text!r}")
    return SpecConfig(**kw)


def accept_commit(drafts, verified) -> tuple[int, list[int]]:
    """One slot's round outcome: ``(accepted, committed_tokens)``.

    ``drafts[i]`` is the draft's token i+1 ahead of the pending token;
    ``verified[i]`` is the verifier's greedy token after the SAME fed
    prefix, i.e. the true stream token at that offset.  The commit is the
    longest agreeing draft prefix plus the verifier's correction at the
    first disagreement (no correction on full accept: the last draft token
    was itself verified).  Every committed token therefore equals what the
    verifier decoding alone would emit - losslessness lives here.
    """
    k = len(verified)
    a = 0
    while a < k and int(drafts[a]) == int(verified[a]):
        a += 1
    toks = [int(t) for t in drafts[:a]]
    if a < k:
        toks.append(int(verified[a]))
    return a, toks


class SpecDecoder:
    """Drive one (draft, verifier) engine pair through speculative rounds.

    Both engines usually come from one ``SparsityFleet`` (shared
    ``EngineFns``, shared cache layout), but any two engines over the same
    config/capacity work - including two engines over identical params,
    which makes every draft accept (handy as a test oracle).
    """

    def __init__(self, draft: ServeEngine, verify: ServeEngine, *,
                 k: int = 4, k_min: int = 1, k_max: int = 8,
                 adaptive: bool = True, ema: float = 0.8,
                 ema_hi: float = 0.8, ema_lo: float = 0.4,
                 init_accept: float | None = None,
                 labels: dict | None = None):
        if draft is verify:
            raise ValueError(
                "draft and verifier must be distinct engines (one engine "
                "cannot both propose and check its own proposals)")
        if draft.cfg is not verify.cfg and draft.cfg != verify.cfg:
            raise ValueError("draft and verifier must serve one model cfg")
        if draft.capacity != verify.capacity:
            raise ValueError(
                f"draft capacity {draft.capacity} != verifier capacity "
                f"{verify.capacity}: the pair must share one cache layout")
        if draft.eos_id != verify.eos_id:
            raise ValueError(
                f"draft eos_id {draft.eos_id} != verifier eos_id "
                f"{verify.eos_id}: termination must be decided identically")
        cfg = verify.cfg
        bad = sorted(set(cfg.layer_kinds) - SPEC_SAFE_KINDS)
        if bad or cfg.sliding_window:
            why = (f"layer kinds {bad}" if bad
                   else f"sliding_window={cfg.sliding_window}")
            raise ValueError(
                f"speculative decode needs full-capacity position-masked "
                f"attention rings to roll back rejected tokens; {cfg.name} "
                f"has {why} (windowed rings evict live rows on speculative "
                f"writes, recurrent state cannot be rolled back)")
        if not 1 <= k_min <= k <= k_max:
            raise ValueError(
                f"need 1 <= k_min <= k <= k_max, got "
                f"({k_min}, {k}, {k_max})")
        self.draft_eng = draft
        self.verify_eng = verify
        self.k = int(k)
        self.k_min, self.k_max = int(k_min), int(k_max)
        self.adaptive = bool(adaptive)
        self.ema_decay = float(ema)
        self.ema_hi, self.ema_lo = float(ema_hi), float(ema_lo)
        # seed from the fleet's live agreement matrix when available;
        # otherwise start neutral (between the two thresholds: no k move
        # until real rounds vote)
        self.accept_ema = (float(init_accept) if init_accept is not None
                           else (ema_hi + ema_lo) / 2)
        self.obs_labels = dict(labels or {})
        self._routes: dict[int, tuple[int, int]] = {}  # srid -> (drid, vrid)
        self._done: dict[int, list[int]] = {}          # unslotted completions
        self._next_srid = 0
        self.stats = {"requests": 0, "requests_retired": 0, "rounds": 0,
                      "pair_rounds": 0, "tokens": 0, "draft_positions": 0,
                      "accepted_draft_tokens": 0, "rollbacks": 0,
                      "seconds": 0.0}
        # fraction- and count-scale histograms: the default ms-scale edges
        # would lump every sample under the first bucket
        obs.declare_hist("spec.accept_rate",
                         tuple(i / 10 for i in range(1, 11)))
        obs.declare_hist("spec.accepted_tokens_per_step",
                         tuple(float(i) for i in range(1, self.k_max + 1)))

    # -- client API ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16) -> int:
        """Queue one request on BOTH members; engine-side validation (empty
        prompt, capacity, max_tokens) applies unchanged and, because the
        pair shares capacity, accepts or rejects atomically."""
        srid = self._next_srid
        self._next_srid += 1
        drid = self.draft_eng.submit(prompt, max_tokens)
        vrid = self.verify_eng.submit(prompt, max_tokens)
        self.stats["requests"] += 1
        if max_tokens <= 0:
            # both engines short-circuited the request into their unslotted
            # done lists; claim both records now (the verifier's is
            # canonical) so run() never confuses them with foreign traffic
            self._done[srid] = self._pop_unslotted(self.verify_eng, vrid)
            self._pop_unslotted(self.draft_eng, drid)
        else:
            self._routes[srid] = (drid, vrid)
        if obs.enabled():
            obs.inc("spec.requests_submitted", **self.obs_labels)
        return srid

    @property
    def pending(self) -> bool:
        return bool(self._routes or self._done)

    def run(self) -> tuple[dict[int, list[int]], dict[str, dict]]:
        """Drive every spec request to completion.

        Returns ``(results, foreign)``: ``results`` maps spec rid -> tokens
        (bit-identical to the verifier decoding alone); ``foreign`` maps
        ``{"draft": {...}, "verify": {...}}`` engine rid -> tokens for
        non-spec requests that FINISHED while interleaved into spec rounds
        (the fleet merges them into its member results - they are ordinary
        member traffic that happened to ride the batched dispatches).
        """
        results = dict(self._done)
        self._done.clear()
        foreign: dict[str, dict[int, list[int]]] = {"draft": {}, "verify": {}}
        stall = 0
        while self._routes:
            self.draft_eng._admit()
            self.verify_eng._admit()
            if self._round(results, foreign) == 0:
                stall += 1
                # FIFO admission on both members plus 1-token foreign
                # progress guarantees the earliest pending route unblocks;
                # a persistent zero-commit loop means that invariant broke
                if stall > 4 * (len(self._routes) + self.draft_eng.slots
                                + self.verify_eng.slots) + 16:
                    raise RuntimeError(
                        "speculative decode made no progress; "
                        f"routes={sorted(self._routes)}")
            else:
                stall = 0
        return results, foreign

    def summary(self) -> dict:
        """Lifetime spec counters for ``SparsityFleet.report()``."""
        st = self.stats
        return {
            **self.obs_labels,
            "k": self.k,
            "accept_ema": self.accept_ema,
            "requests": st["requests"],
            "requests_retired": st["requests_retired"],
            "rounds": st["rounds"],
            "tokens": st["tokens"],
            "rollbacks": st["rollbacks"],
            "accept_rate": (st["accepted_draft_tokens"]
                            / st["draft_positions"]
                            if st["draft_positions"] else None),
            "accepted_tokens_per_round": (st["tokens"] / st["pair_rounds"]
                                          if st["pair_rounds"] else None),
            "tok_s": (st["tokens"] / st["seconds"]
                      if st["seconds"] else None),
            "seconds": st["seconds"],
        }

    # -- internals -----------------------------------------------------------

    @staticmethod
    def _pop_unslotted(eng: ServeEngine, rid: int) -> list[int]:
        for i, r in enumerate(eng._done_unslotted):
            if r.rid == rid:
                del eng._done_unslotted[i]
                return r.out
        raise KeyError(f"rid {rid} not in unslotted done list")

    def _k_eff(self) -> int:
        """Fed width for this round: the configured k capped to the ring
        headroom of the furthest-along live slot.  Rows past capacity would
        WRAP the ring and evict live rows while still speculative; at
        k_eff=1 only the committed next position is written - exactly what
        plain decode writes, so wrapping there is as safe as plain decode.
        """
        maxpos = 0
        for eng in (self.draft_eng, self.verify_eng):
            for s, r in enumerate(eng.active):
                if r is not None:
                    maxpos = max(maxpos, int(eng.pos[s]))
        return max(1, min(self.k, self.verify_eng.capacity - maxpos))

    def _dispatch(self, phase: str, eng: ServeEngine, fn, host_args: tuple,
                  k_eff: int) -> np.ndarray:
        """One jitted spec dispatch (draft or verify) with the sentinel
        note and span timing; returns the host-side (slots, k) token
        matrix.  The np.asarray is the dispatch's natural sync point, so
        the span needs no extra fence."""
        if recompile.enabled():
            recompile.note(f"{phase}_{k_eff}",
                           (eng.params,) + host_args + (eng.caches, eng.pos))
        sp = obs.span(f"spec.{phase}", k=k_eff, **self.obs_labels)
        with sp:
            out, eng.caches = fn(eng.params,
                                 *(jnp.asarray(a) for a in host_args),
                                 eng.caches, jnp.asarray(eng.pos, jnp.int32))
            out = np.asarray(out)
        if sp.seconds is not None:
            obs.observe(f"spec.{phase}_ms", sp.seconds * 1e3,
                        **self.obs_labels)
        return out

    def _round(self, results: dict, foreign: dict) -> int:
        """One speculative round over both engines; returns tokens
        committed (0 only when nothing could progress)."""
        d_eng, v_eng = self.draft_eng, self.verify_eng
        d_act = {r.rid: s for s, r in enumerate(d_eng.active)
                 if r is not None}
        v_act = {r.rid: s for s, r in enumerate(v_eng.active)
                 if r is not None}
        if not d_act and not v_act:
            return 0
        # routes live on both members; a route is driven only once BOTH
        # sides hold a slot (an unpaired side idles: its writes stay ahead
        # of its unadvanced position, invisible by the ring mask)
        pairs = [(srid, d_act[dr], v_act[vr])
                 for srid, (dr, vr) in self._routes.items()
                 if dr in d_act and vr in v_act]
        d_spec_rids = {dr for dr, _ in self._routes.values()}
        v_spec_rids = {vr for _, vr in self._routes.values()}
        k_eff = self._k_eff()

        # draft phase: every active draft-member slot feeds its pending
        # token and proposes k_eff continuations in one dispatch
        seed = np.zeros((d_eng.slots,), np.int32)
        for s, r in enumerate(d_eng.active):
            if r is not None:
                seed[s] = r.pending_token
        drafts = self._dispatch("draft", d_eng, d_eng.fns.draft(k_eff),
                                (seed,), k_eff)

        # verify phase: the verifier teacher-forces the SAME fed prefix -
        # pending token then the first k_eff-1 draft proposals
        vt = np.zeros((v_eng.slots, k_eff), np.int32)
        for s, r in enumerate(v_eng.active):
            if r is not None:
                vt[s, 0] = r.pending_token
        for _, sd, sv in pairs:
            if k_eff > 1:
                vt[sv, 1:] = drafts[sd, :k_eff - 1]
        verified = self._dispatch("verify", v_eng, v_eng.fns.verify(k_eff),
                                  (vt,), k_eff)

        committed = 0
        accept_sum = 0.0
        for srid, sd, sv in pairs:
            a, toks = accept_commit(drafts[sd], verified[sv])
            req_d, req_v = d_eng.active[sd], v_eng.active[sv]
            # request-budget and eos truncation BEFORE committing: tokens
            # past either boundary never reach the output or the position
            # vectors (their rows stay masked junk, overwritten on reuse)
            m_cap = req_v.max_tokens - len(req_v.out)
            toks = toks[:m_cap]
            hit_eos = (v_eng.eos_id is not None and v_eng.eos_id in toks)
            if hit_eos:
                toks = toks[:toks.index(v_eng.eos_id) + 1]
            m = len(toks)
            req_v.out.extend(toks)
            req_d.out.extend(toks)
            d_eng.pos[sd] += m
            v_eng.pos[sv] += m
            if m:
                req_d.pending_token = req_v.pending_token = toks[-1]
            committed += m
            accept_sum += a / k_eff
            st = self.stats
            st["tokens"] += m
            # acceptance is scored over positions that COULD commit: drafts
            # past the request budget are discarded work, not rejections
            st["draft_positions"] += min(k_eff, m_cap)
            st["accepted_draft_tokens"] += min(a, m)
            if a < k_eff:
                st["rollbacks"] += 1
            if obs.enabled():
                obs.observe("spec.accept_rate", a / k_eff,
                            **self.obs_labels)
                obs.observe("spec.accepted_tokens_per_step", m,
                            **self.obs_labels)
                if a < k_eff:
                    obs.inc("spec.rollbacks", **self.obs_labels)
                obs.inc("spec.tokens_committed", m, **self.obs_labels)
            if hit_eos or len(req_v.out) >= req_v.max_tokens:
                req_d.done = req_v.done = True
                results[srid] = req_v.out
                d_eng.free_slot(sd)
                v_eng.free_slot(sv)
                del self._routes[srid]
                st["requests_retired"] += 1
                if obs.enabled():
                    obs.inc("spec.requests_retired", **self.obs_labels)

        # foreign slots (pinned / A/B member traffic): column 0 of the same
        # dispatch IS that slot's plain fused decode - advance one token
        for kind, eng, mat, rids in (("draft", d_eng, drafts, d_spec_rids),
                                     ("verify", v_eng, verified,
                                      v_spec_rids)):
            n_foreign = 0
            for s, req in enumerate(eng.active):
                if req is None or req.rid in rids:
                    continue
                tok = int(mat[s, 0])
                req.out.append(tok)
                req.pending_token = tok
                eng.pos[s] += 1
                committed += 1
                n_foreign += 1
                if ((eng.eos_id is not None and tok == eng.eos_id)
                        or len(req.out) >= req.max_tokens):
                    req.done = True
                    foreign[kind][req.rid] = req.out
                    eng.free_slot(s)
            if n_foreign and obs.enabled():
                obs.inc("serve.tokens_decoded", n_foreign, **eng.obs_labels)

        self.stats["rounds"] += 1
        if pairs:
            self.stats["pair_rounds"] += 1
            rate = accept_sum / len(pairs)
            self.accept_ema = (self.ema_decay * self.accept_ema
                               + (1 - self.ema_decay) * rate)
            if self.adaptive:
                if self.accept_ema >= self.ema_hi and self.k < self.k_max:
                    self.k += 1
                elif self.accept_ema < self.ema_lo and self.k > self.k_min:
                    self.k -= 1
            if obs.enabled():
                obs.set_gauge("spec.accept_ema", self.accept_ema,
                              **self.obs_labels)
                obs.set_gauge("spec.k", self.k, **self.obs_labels)
        return committed
