"""Batched serving engine: request queue -> continuous batched decode.

Continuous batching over a fixed-slot KV cache: requests join free slots,
prefill runs once per admitted request (one jitted chunked forward that
fills the slot's cache rows), decode advances every slot one token per step
in a single jitted call.  Decode is ONE fused ``model.decode_step``
invocation per step with the per-slot positions carried as an index vector
- each slot writes its own cache ring slot and masks attention at its own
position (row-local by construction, see ``attention.attn_apply_decode``),
so a slot mid-generation never sees another slot's ring writes and new
slots admit mid-batch without changing the traced computation.  The older
per-slot vmapped step is kept behind ``decode_mode="vmap"`` as a parity
oracle.  Slot admission writes cache rows through one jitted
dynamic-index update (no per-slot recompiles, no host round-trip of the
cache buffers).  Finished slots free up on max_tokens or on emitting the
eos token (``cfg.eos_id`` / the engine's ``eos_id`` override; the eos is
included in the request's output) and are reused by queued requests
mid-batch.

Weights may be dense or 2:4-compressed (``sparse.apply.sparsify_params``):
``models.common.dense`` dispatches per leaf, so the same engine serves both;
``ServeEngine.from_artifact`` builds the sparse engine straight from a saved
mask bank.  The engine is device-count-agnostic (1 CPU device in tests, the
production mesh via the same jitted step functions); passing ``rules``
(a ``dist.axes.ShardingRules``) places params - compressed SparseTensor
leaves included, via ``dist.sharding.params_sharding`` - and KV caches onto
the mesh before serving.

The jitted step functions (decode, per-bucket prefill, the slot-admission
write) and the blank-slot template live in :class:`EngineFns`; engines that
share one instance (``serve.fleet.SparsityFleet`` members) share jit entry
points and therefore compilations.  Request validation happens at
``submit()`` - an empty prompt, a prompt at/over cache capacity, or a
``max_tokens <= 0`` request never claims a slot.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.analysis import recompile
from repro.configs.base import ModelConfig
from repro.models import model as M

# layer kinds whose caches are position-masked attention rings: prompt
# padding past the real length is invisible until overwritten.  Recurrent
# kinds (ssm/xlstm) fold every token into their state, so their prefill
# must run unpadded (exact length, one compile per distinct prompt length).
_PAD_SAFE_KINDS = {"attn", "local"}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    pending_token: int = 0        # next token to feed (last prompt tok, then
                                  # each generated one)


class EngineFns:
    """Jitted step functions + slot templates for one (cfg, capacity,
    decode_mode) triple.

    ``ServeEngine`` builds one per instance by default; a multi-engine owner
    (``serve.fleet.SparsityFleet``) builds ONE and hands it to every member,
    so the decode / prefill / slot-write callables are shared jit entry
    points: N budget engines compile each step function once per distinct
    params *structure* (jit retraces per treedef) instead of once per
    engine.
    """

    def __init__(self, cfg: ModelConfig, capacity: int,
                 decode_mode: str = "fused", rules: Any = None):
        assert decode_mode in ("fused", "vmap"), decode_mode
        self.cfg = cfg
        self.capacity = capacity
        self.decode_mode = decode_mode
        # rules make the mesh visible at TRACE time (dist.axes.use_rules
        # around every jitted body): sparse.apply dispatch sees the K-shard
        # tags, decode_attend sees the capacity sharding, and the shard_map
        # wrappers bake the mesh into the jaxpr - tensor-parallel serving
        # is compiled in, not GSPMD-guessed.  None = single-device/GSPMD.
        self.rules = rules
        self.prefill_fns: dict[int, Any] = {}   # bucket -> jitted prefill
        self.verify_fns: dict[int, Any] = {}    # k -> jitted verify pass
        self.draft_fns: dict[int, Any] = {}     # k -> jitted draft loop
        self._blank_row = None  # lazily-built slot-reset template
        # slot admission: one jitted dynamic-index row write (slot index is
        # an operand, not a constant -> one compile covers every slot)
        self.write_slot = jax.jit(lambda full, row, s: jax.tree.map(
            lambda f, n: jax.lax.dynamic_update_index_in_dim(
                f, n[:, 0], s, axis=1), full, row))

        if decode_mode == "vmap":
            def _row_step(p, tok, cache_row, t):
                """One slot's decode at its own position t (vmapped)."""
                caches = jax.tree.map(lambda a: a[:, None], cache_row)
                logits, nc = M.decode_step(cfg, p, tok[None], caches, t)
                return logits[0], jax.tree.map(lambda a: a[:, 0], nc)

            self.decode = jax.jit(self._under_rules(jax.vmap(
                _row_step, in_axes=(None, 0, 1, 0), out_axes=(0, 1))))
        else:
            # fused: one decode_step over all slots, per-slot positions as
            # an index vector (no vmapped scan, no per-slot kernel launches)
            self.decode = jax.jit(self._under_rules(
                lambda p, toks, caches, t: M.decode_step(cfg, p, toks,
                                                         caches, t)))

    def _under_rules(self, fn):
        """Install the sharding rules for the duration of a trace."""
        if self.rules is None:
            return fn
        from repro.dist.axes import use_rules
        rules = self.rules

        def traced(*args):
            with use_rules(rules):
                return fn(*args)
        return traced

    def prefill(self, bucket: int) -> Any:
        """Jitted chunked prefill for one padded prompt-length bucket."""
        fn = self.prefill_fns.get(bucket)
        if fn is None:
            obs.inc("serve.jit_entries", surface="prefill", bucket=bucket)
            fn = jax.jit(self._under_rules(lambda p, toks: M.prefill(
                self.cfg, p, {"tokens": toks},
                cache_capacity=self.capacity)[1]))
            self.prefill_fns[bucket] = fn
        return fn

    def verify(self, k: int) -> Any:
        """Jitted teacher-forced verify over k fed tokens in ONE batched
        prefill-style pass (bucketed on k like prefill is on length).

        ``(params, toks (B, k), caches, pos (B,)) -> (argmax (B, k) int32,
        caches)``.  Column i's argmax is the model's greedy continuation of
        the fed prefix ``toks[:, :i + 1]`` - ``model.verify_step`` runs the
        same arithmetic as k sequential fused decode steps (write-then-
        attend ring updates, per-query position masks) but executes the
        layer op graph ONCE for all k positions, so verifying k draft
        tokens costs about one decode step, not k.  Cache rows for all k
        fed positions are written; rows past a rejection point sit AHEAD of
        the slot's committed position vector and stay invisible to
        attention (``ring_positions`` masks kpos > t) until the committed
        stream reaches and overwrites them - rollback is a host-side
        position bookkeeping change, never cache surgery.
        """
        fn = self.verify_fns.get(k)
        if fn is None:
            obs.inc("serve.jit_entries", surface="verify", bucket=k)
            cfg = self.cfg

            def _verify(p, toks, caches, t):
                logits, caches = M.verify_step(cfg, p, toks, caches, t)
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), caches

            fn = jax.jit(self._under_rules(_verify))
            self.verify_fns[k] = fn
        return fn

    def draft(self, k: int) -> Any:
        """Jitted k-token autoregressive draft loop (bucketed on k).

        ``(params, seed (B,), caches, pos (B,)) -> (drafts (B, k) int32,
        caches)``.  Feeds ``seed`` (the slot's pending token), then its own
        greedy argmax k - 1 more times - ONE dispatch proposes k tokens,
        against k dispatches for the plain per-token decode loop.  The scan
        body is the same ``model.decode_step`` as the fused decode, so a
        draft engine running this loop produces the identical stream its
        own sequential decode would.
        """
        fn = self.draft_fns.get(k)
        if fn is None:
            obs.inc("serve.jit_entries", surface="draft", bucket=k)
            cfg = self.cfg

            def _draft(p, seed, caches, t):
                def col(carry, _):
                    tok, caches, pos = carry
                    logits, caches = M.decode_step(cfg, p, tok, caches, pos)
                    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                    return (nxt, caches, pos + 1), nxt
                (_, caches, _), out = jax.lax.scan(
                    col, (seed, caches, t), None, length=k)
                return jnp.transpose(out), caches

            fn = jax.jit(self._under_rules(_draft))
            self.draft_fns[k] = fn
        return fn

    def jit_cache_sizes(self) -> dict[str, int]:
        """Compiled-trace count per jit surface (shared across every engine
        on this EngineFns): the live recompile signal - one entry per
        distinct params *structure* that hit the surface, so a fleet whose
        members alias one structure shows 1, not N."""
        fns = {"decode": self.decode, "write_slot": self.write_slot,
               **{f"prefill_{b}": f for b, f in self.prefill_fns.items()},
               **{f"verify_{k}": f for k, f in self.verify_fns.items()},
               **{f"draft_{k}": f for k, f in self.draft_fns.items()}}
        out = {}
        for surface, fn in fns.items():
            size = getattr(fn, "_cache_size", None)
            if callable(size):
                try:
                    out[surface] = int(size())
                except Exception:  # private jax API: absence is not an error
                    pass
        return out

    def blank_row(self) -> Any:
        """1-slot cache template that resets a reused slot's state."""
        if self._blank_row is None:
            self._blank_row = M.init_caches(self.cfg, 1, self.capacity)
        return self._blank_row


class ServeEngine:
    """Slot-based continuous batching (greedy decode)."""

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 capacity: int = 512, decode_mode: str = "fused",
                 rules: Any = None, eos_id: int | None = None,
                 fns: EngineFns | None = None,
                 labels: dict | None = None):
        assert not cfg.is_encoder_decoder, "decoder-only engine"
        if fns is None:
            fns = EngineFns(cfg, capacity, decode_mode, rules=rules)
        elif (fns.cfg, fns.capacity, fns.decode_mode) != \
                (cfg, capacity, decode_mode) or \
                (fns.rules is not None and rules is not None
                 and fns.rules is not rules):
            # a mismatched EngineFns would prefill at the wrong cache
            # capacity (opaque shape error mid-run), silently decode
            # through the other mode, or bake a different mesh into the
            # shared traces - and asserts vanish under python -O
            raise ValueError(
                "shared EngineFns was built for "
                f"(capacity={fns.capacity}, decode_mode={fns.decode_mode}) "
                f"and cannot serve (capacity={capacity}, "
                f"decode_mode={decode_mode}) or a different cfg/mesh")
        self.cfg = cfg
        self.slots = slots
        self.capacity = capacity
        self.decode_mode = decode_mode
        self.rules = rules
        # eos terminates a slot mid-generation (the emitted eos is included
        # in the request's output); None falls back to the model config's id
        self.eos_id = cfg.eos_id if eos_id is None else eos_id
        caches = M.init_caches(cfg, slots, capacity)
        if rules is not None:
            from repro.dist import sharding as shd
            axes = M.param_axes(cfg)
            # stamp K-shard tags on compressed leaves FIRST: the tags are
            # pytree aux data, so tagging after device_put would change the
            # treedef out from under the placed arrays; params_sharding then
            # mirrors the same tags into its sharding tree (treedefs match)
            params = shd.tag_compressed(axes, params, rules)
            params = jax.device_put(
                params, shd.params_sharding(axes, params, rules))
            caches = jax.device_put(
                caches, shd.cache_sharding(caches, rules.mesh))
        self.params = params
        self.caches = caches
        self.pos = np.zeros((slots,), np.int32)       # next position per slot
        self.active: list[Request | None] = [None] * slots
        # admission is FIFO off the left end; deque keeps it O(1) now that
        # spec mode interleaves members (and admits) every round
        self.queue: collections.deque[Request] = collections.deque()
        self._done_unslotted: list[Request] = []  # finished without a slot
        self._next_rid = 0
        self._pad_prefill = set(cfg.layer_kinds) <= _PAD_SAFE_KINDS
        # padding past the prompt is only invisible while every junk ring
        # slot stays ahead of the decode position; sliding-window layers cap
        # their ring at min(capacity, window), so buckets must fit that ring
        self._min_ring = (min(capacity, cfg.sliding_window)
                          if cfg.sliding_window else capacity)
        self.fns = fns
        self._write_slot = fns.write_slot
        self._decode = fns.decode
        # metric labels stamped on every span/counter/histogram this engine
        # emits (the fleet labels members by budget so per-budget latency
        # series stay separable); metadata only, never touches dispatch
        self.obs_labels = dict(labels or {})

    @classmethod
    def from_artifact(cls, bank_dir, params0: Any, *,
                      sparsity: float | None = None, compressed: bool = True,
                      slots: int = 4, capacity: int = 512,
                      decode_mode: str = "fused",
                      rules: Any = None,
                      eos_id: int | None = None) -> "ServeEngine":
        """Engine over bank-derived sparse weights (no re-calibration)."""
        from repro.sparse.bank import MaskBank
        bank = MaskBank.load(bank_dir)
        params = bank.sparse_params(params0, sparsity=sparsity,
                                    compressed=compressed)
        return cls(bank.cfg, params, slots=slots, capacity=capacity,
                   decode_mode=decode_mode, rules=rules, eos_id=eos_id)

    # -- client API ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16) -> int:
        """Queue a request; every admission invariant is checked HERE.

        Rejections raise before a slot is claimed, so an invalid request can
        never wedge a slot mid-prefill or abort the ``run()`` loop for the
        other requests in the batch (the old code asserted inside
        ``_prefill_slot``, after the slot was taken - and asserts vanish
        under ``python -O``).
        """
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError(
                "empty prompt: a request needs at least one token to feed "
                "the first decode step (rejected at submit, no slot claimed)")
        if len(prompt) - 1 >= self.capacity:
            raise ValueError(
                f"prompt of {len(prompt)} tokens needs {len(prompt) - 1} "
                f"prefill cache rows but engine capacity is {self.capacity} "
                "(rejected at submit, no slot claimed)")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid, prompt, max_tokens)
        if max_tokens <= 0:
            # short-circuit: a zero-token request is already complete; it
            # must not claim a slot or spend a decode step (which would
            # also wrongly emit one token before the length check)
            req.done = True
            self._done_unslotted.append(req)
        else:
            self.queue.append(req)
        if obs.enabled():
            obs.inc("serve.requests_submitted", **self.obs_labels)
            obs.set_gauge("serve.queue_depth", len(self.queue),
                          **self.obs_labels)
        return rid

    @property
    def pending(self) -> bool:
        """Any submitted-but-undelivered work (queued, active, or finished
        without a slot and awaiting the next ``run()``)."""
        return bool(self.queue or self._done_unslotted
                    or any(r is not None for r in self.active))

    def run(self) -> dict[int, list[int]]:
        """Drive until all submitted requests complete; returns rid->tokens."""
        results: dict[int, list[int]] = {
            r.rid: r.out for r in self._done_unslotted}
        self._done_unslotted.clear()
        while self.queue or any(r is not None for r in self.active):
            self._admit()
            finished = self._step()
            for r in finished:
                results[r.rid] = r.out
        if obs.enabled():
            # compiled-trace counts per shared jit surface: a growing gauge
            # across runs means a new params structure retraced the fns
            for surface, size in self.fns.jit_cache_sizes().items():
                obs.set_gauge("serve.jit_cache_size", size, surface=surface)
        return results

    # -- internals -----------------------------------------------------------

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                self.active[s] = req
                self._prefill_slot(s, req)

    def free_slot(self, s: int) -> None:
        """Release slot s for reuse (requests retired outside ``_step``,
        e.g. by the speculative decoder, go through here)."""
        self.active[s] = None
        self.pos[s] = 0

    def _prefill_bucket(self, n: int) -> int:
        if not self._pad_prefill:
            return n  # recurrent state: exact length, no padding
        bucket = min(max(8, 1 << (n - 1).bit_length()), self.capacity)
        # a bucket larger than the smallest attention ring would evict real
        # in-window tokens and leave junk at positions ring_positions treats
        # as valid -> fall back to the exact length (no padding, still one
        # jitted chunked prefill)
        return bucket if bucket <= self._min_ring else n

    def _prefill_slot(self, s: int, req: Request) -> None:
        """One jitted chunked prefill writing slot s's cache rows.

        All prompt tokens but the last run through the prefill forward
        (bucketed to limit recompiles); the produced cache rows replace
        slot s's rows wholesale through the jitted dynamic-index write.
        Padding past the prompt is masked during decode (kpos > t) and each
        junk ring slot is overwritten by the real token before it could
        become visible.
        """
        n = len(req.prompt) - 1  # submit() guarantees 0 <= n < capacity
        sp = obs.span("serve.prefill", slot=s, prompt_len=len(req.prompt),
                      **self.obs_labels)
        with sp:
            if n == 0:
                # no prefill forward runs, so nothing replaces the slot's
                # cache row; reset it explicitly or a reused slot leaks the
                # previous request's recurrent state (attention rings are
                # position-masked, ssm/xlstm state is not)
                row = self.fns.blank_row()
                sp.set(bucket="blank")
            else:
                bucket = self._prefill_bucket(n)
                fn = self.fns.prefill(bucket)
                toks = np.zeros((1, bucket), np.int32)
                toks[0, :n] = req.prompt[:-1]
                if recompile.enabled():
                    recompile.note(f"prefill_{bucket}", (self.params, toks))
                row = fn(self.params, jnp.asarray(toks))
                sp.set(bucket=bucket)
                obs.inc("serve.prefill_bucket_hits", bucket=bucket,
                        **self.obs_labels)
            if recompile.enabled():
                # np scalar, not python int: the slot index is a traced
                # operand, so every slot shares one compile signature
                recompile.note("write_slot", (self.caches, row, np.int32(s)))
            self.caches = self._write_slot(self.caches, row, jnp.int32(s))
            sp.fence(row)
        if sp.seconds is not None:
            obs.observe("serve.prefill_ms", sp.seconds * 1e3,
                        **self.obs_labels)
        self.pos[s] = max(n, 0)
        req.pending_token = int(req.prompt[-1])

    def _step(self) -> list[Request]:
        toks = np.zeros((self.slots,), np.int32)
        n_active = 0
        for s, req in enumerate(self.active):
            if req is not None:
                toks[s] = req.pending_token
                n_active += 1
        # the decode step is THE hot path: histogram-observe only, no span
        # event per step (spans are for per-request units like prefill).
        # The np.asarray(argmax) below is the step's natural sync point, so
        # the clock pair needs no extra fence: the stop read already
        # includes the device work this step dispatched.
        if recompile.enabled():
            recompile.note("decode", (self.params, toks, self.caches,
                                      self.pos))
        t0 = time.perf_counter() if obs.enabled() else None
        logits, self.caches = self._decode(
            self.params, jnp.asarray(toks), self.caches,
            jnp.asarray(self.pos, jnp.int32))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        if t0 is not None:
            obs.observe("serve.decode_step_ms",
                        (time.perf_counter() - t0) * 1e3, **self.obs_labels)
            obs.set_gauge("serve.slot_util", n_active / max(self.slots, 1),
                          **self.obs_labels)
            obs.inc("serve.tokens_decoded", n_active, **self.obs_labels)
        finished = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            tok = int(nxt[s])
            req.out.append(tok)
            req.pending_token = tok
            hit_eos = self.eos_id is not None and tok == self.eos_id
            if hit_eos or len(req.out) >= req.max_tokens:
                req.done = True
                finished.append(req)
                self.free_slot(s)       # freed: _admit reuses it next step
        if finished and obs.enabled():
            obs.inc("serve.requests_retired", len(finished),
                    **self.obs_labels)
        return finished
