"""Batched serving engine: request queue -> continuous batched decode.

Continuous batching over a fixed-slot KV cache: requests join free slots,
prefill runs per-request (cache written at its slot), decode advances every
active slot one token per step, finished slots (eos/max_tokens) free up.
This is the orchestration layer the dry-run's serve_step lowers; the engine
itself is device-count-agnostic (works on 1 CPU device in tests and under
the production mesh via the same jitted step functions).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_tokens: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Slot-based continuous batching (greedy decode)."""

    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 capacity: int = 512):
        assert not cfg.is_encoder_decoder, "decoder-only engine"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.capacity = capacity
        self.caches = M.init_caches(cfg, slots, capacity)
        self.pos = np.zeros((slots,), np.int32)       # next position per slot
        self.active: list[Request | None] = [None] * slots
        self.queue: list[Request] = []
        self._next_rid = 0

        self._decode = jax.jit(
            lambda p, tok, c, t: M.decode_step(cfg, p, tok, c, t))

    # -- client API ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32),
                                  max_tokens))
        return rid

    def run(self) -> dict[int, list[int]]:
        """Drive until all submitted requests complete; returns rid->tokens."""
        results: dict[int, list[int]] = {}
        while self.queue or any(r is not None for r in self.active):
            self._admit()
            finished = self._step()
            for r in finished:
                results[r.rid] = r.out
        return results

    # -- internals -----------------------------------------------------------

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.pop(0)
                self.active[s] = req
                self._prefill_slot(s, req)

    def _prefill_slot(self, s: int, req: Request) -> None:
        """Token-by-token prefill into slot s (slot-local cache writes).

        Production would run a batched prefill kernel; slot-serial decode
        keeps the engine simple and uses the identical cache layout.
        """
        for i, tok in enumerate(req.prompt[:-1]):
            self._advance(s, int(tok), record=False)
        self.pos[s] = max(len(req.prompt) - 1, 0)
        self._last_token = int(req.prompt[-1])
        req._pending_token = int(req.prompt[-1])

    def _advance(self, s: int, token: int, record: bool = True) -> int:
        toks = np.zeros((self.slots,), np.int32)
        toks[s] = token
        t = jnp.asarray(int(self.pos[s]), jnp.int32)
        logits, caches = self._decode(self.params, jnp.asarray(toks),
                                      self.caches, t)
        # only slot s's cache row advanced meaningfully; caches are batched
        self.caches = caches
        self.pos[s] += 1
        return int(np.asarray(jnp.argmax(logits[s])))

    def _step(self) -> list[Request]:
        finished = []
        for s, req in enumerate(self.active):
            if req is None:
                continue
            nxt = self._advance(s, getattr(req, "_pending_token", 0))
            req.out.append(nxt)
            req._pending_token = nxt
            if len(req.out) >= req.max_tokens:
                req.done = True
                finished.append(req)
                self.active[s] = None
                self.pos[s] = 0
        return finished
