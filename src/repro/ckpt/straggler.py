"""Straggler / failure detection + elastic recovery planning.

At real scale every host reports a heartbeat per step; this module holds the
launcher-side policy, fully unit-testable without hardware:

* HeartbeatMonitor: per-host last-seen step/time, EWMA of step durations.
  A host is a STRAGGLER when its step time exceeds `straggler_factor` x the
  fleet median, and FAILED when silent for `timeout_s`.
* plan_recovery(): given the surviving hosts, pick the largest valid
  (data, model) mesh (model axis preserved - TP groups must stay intact;
  data axis shrinks to the largest divisor), map hosts to it, and rescale
  gradient accumulation so the GLOBAL batch is unchanged.
* The training loop reacts by restoring the latest checkpoint onto the new
  mesh (checkpoint.py restores with target shardings) and skipping the data
  cursor forward - no replayed or dropped batches.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterable


@dataclasses.dataclass
class HostState:
    host_id: int
    last_step: int = -1
    last_beat: float = 0.0
    ewma_step_s: float = 0.0


class HeartbeatMonitor:
    def __init__(self, num_hosts: int, *, timeout_s: float = 300.0,
                 straggler_factor: float = 2.0, ewma: float = 0.7):
        self.hosts = {h: HostState(h) for h in range(num_hosts)}
        self.timeout_s = timeout_s
        self.straggler_factor = straggler_factor
        self.ewma = ewma

    def beat(self, host_id: int, step: int, *, now: float | None = None,
             step_s: float | None = None) -> None:
        now = time.monotonic() if now is None else now
        h = self.hosts[host_id]
        if step_s is not None:
            h.ewma_step_s = (self.ewma * h.ewma_step_s +
                             (1 - self.ewma) * step_s
                             if h.ewma_step_s else step_s)
        h.last_step = step
        h.last_beat = now

    def failed(self, *, now: float | None = None) -> list[int]:
        now = time.monotonic() if now is None else now
        return [h.host_id for h in self.hosts.values()
                if h.last_beat and now - h.last_beat > self.timeout_s]

    def stragglers(self) -> list[int]:
        times = sorted(h.ewma_step_s for h in self.hosts.values()
                       if h.ewma_step_s)
        if not times:
            return []
        median = times[len(times) // 2]
        return [h.host_id for h in self.hosts.values()
                if h.ewma_step_s > self.straggler_factor * median]

    def healthy(self, *, now: float | None = None) -> list[int]:
        bad = set(self.failed(now=now)) | set(self.stragglers())
        return [h for h in self.hosts if h not in bad]


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    mesh_shape: tuple[int, ...]          # (data, model) or (pod, data, model)
    hosts: tuple[int, ...]               # surviving hosts, mesh order
    accum_scale: int                     # multiply grad-accum by this
    dropped_hosts: tuple[int, ...]


def plan_recovery(surviving: Iterable[int], *, hosts_total: int,
                  old_mesh: tuple[int, ...], model_axis: int,
                  chips_per_host: int = 4) -> RecoveryPlan:
    """Largest valid mesh from survivors; TP (model) groups preserved."""
    surviving = sorted(surviving)
    old_chips = 1
    for d in old_mesh:
        old_chips *= d
    chips = len(surviving) * chips_per_host
    assert chips >= model_axis, "not enough chips for one TP group"
    data_axis = chips // model_axis
    # data axis must divide the old data axis product so the global batch
    # factorizes into an integer accumulation rescale
    old_data = old_chips // model_axis
    while data_axis > 0 and old_data % data_axis != 0:
        data_axis -= 1
    assert data_axis > 0
    used_hosts = (data_axis * model_axis) // chips_per_host
    dropped = tuple(h for h in range(hosts_total) if h not in surviving)
    return RecoveryPlan(
        mesh_shape=(data_axis, model_axis),
        hosts=tuple(surviving[:used_hosts]),
        accum_scale=old_data // data_axis,
        dropped_hosts=dropped)
