"""Sharded, atomic, resumable checkpointing (no orbax in the environment).

Layout:
  <dir>/step_<N>.tmp/      - written first
      manifest.json        - {path: {file, shape, dtype}}, metadata
      <leaf files>.npy
  <dir>/step_<N>/          - atomic rename after fsync
  <dir>/LATEST             - text file with the committed step number

* Atomicity: a crash mid-save leaves only a .tmp dir, never a torn commit.
* Async: save_async() runs the serialization on a worker thread; wait() (or
  the next save) joins it - training overlaps J steps with the previous save.
* Elastic restore: leaves are saved unsharded (host-side np arrays, gathered
  per-leaf); restore_sharded() device_puts each leaf with the *target* mesh's
  NamedSharding, so a checkpoint written on one mesh restores onto any other
  (tested 8 -> 4 -> 16 logical devices in tests/test_ckpt.py).  At real
  multi-host scale each host writes its addressable shards and the manifest
  carries the index - the commit protocol is unchanged.
"""
from __future__ import annotations

import concurrent.futures as futures
import json
import os
import pathlib
import shutil
from typing import Any

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def _write_tree(tmp: pathlib.Path, final: pathlib.Path, host_tree: PyTree,
                manifest_extra: dict) -> None:
    """Serialize a host pytree under tmp, then atomically commit to final."""
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    manifest = {**manifest_extra, "leaves": {}}
    for i, (path, leaf) in enumerate(_flatten(host_tree)):
        if leaf is None:
            manifest["leaves"][path] = None
            continue
        fname = f"leaf_{i:06d}.npy"
        np.save(tmp / fname, leaf)
        manifest["leaves"][path] = {
            "file": fname, "shape": list(np.shape(leaf)),
            "dtype": str(np.asarray(leaf).dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():  # re-save (e.g. final + periodic, or artifact update)
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic commit


def _read_tree(d: pathlib.Path, template: PyTree,
               shardings: PyTree | None = None) -> tuple[PyTree, dict]:
    """Load a pytree serialized by _write_tree into template's structure."""
    manifest = json.loads((d / "manifest.json").read_text())
    by_path = manifest["leaves"]
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        template, is_leaf=lambda x: x is None)
    sh_flat = (None if shardings is None else
               jax.tree_util.tree_flatten(
                   shardings, is_leaf=lambda x: x is None)[0])
    out = []
    for i, (kp, leaf) in enumerate(flat):
        ent = by_path.get(jax.tree_util.keystr(kp))
        if ent is None:
            out.append(None)
            continue
        arr = np.load(d / ent["file"])
        if sh_flat is not None and sh_flat[i] is not None:
            arr = jax.device_put(arr, sh_flat[i])
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


# -- named artifacts (non-step state: mask banks, calibration results) -------

def save_artifact(directory: str | os.PathLike, tree: PyTree, *,
                  metadata: dict | None = None) -> None:
    """Atomically write a pytree + metadata as a standalone artifact dir."""
    final = pathlib.Path(directory)
    final.parent.mkdir(parents=True, exist_ok=True)
    host = jax.tree.map(lambda x: None if x is None else np.asarray(x),
                        tree, is_leaf=lambda x: x is None)
    _write_tree(final.parent / (final.name + ".tmp"), final, host,
                {"metadata": metadata or {}})


def load_artifact(directory: str | os.PathLike, template: PyTree
                  ) -> tuple[PyTree, dict]:
    """Restore an artifact into template's structure; returns (tree, meta)."""
    tree, manifest = _read_tree(pathlib.Path(directory), template)
    return tree, manifest["metadata"]


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = futures.ThreadPoolExecutor(max_workers=1)
        self._pending: futures.Future | None = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state: PyTree, *, metadata: dict | None = None
             ) -> None:
        self.wait()
        host_state = jax.tree.map(
            lambda x: None if x is None else np.asarray(x), state,
            is_leaf=lambda x: x is None)
        self._write(step, host_state, metadata or {})

    def save_async(self, step: int, state: PyTree, *,
                   metadata: dict | None = None) -> None:
        self.wait()
        # materialize on host before returning so the training step can
        # donate/overwrite device buffers safely
        host_state = jax.tree.map(
            lambda x: None if x is None else np.asarray(x), state,
            is_leaf=lambda x: x is None)
        self._pending = self._pool.submit(self._write, step, host_state,
                                          metadata or {})

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_state: PyTree, metadata: dict) -> None:
        _write_tree(self.dir / f"step_{step:08d}.tmp",
                    self.dir / f"step_{step:08d}", host_state,
                    {"step": step, "metadata": metadata})
        latest_tmp = self.dir / "LATEST.tmp"
        latest_tmp.write_text(str(step))
        os.replace(latest_tmp, self.dir / "LATEST")
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.iterdir()
                      if p.is_dir() and p.name.startswith("step_")
                      and not p.name.endswith(".tmp"))

    def latest_step(self) -> int | None:
        f = self.dir / "LATEST"
        if not f.exists():
            steps = self.all_steps()
            return steps[-1] if steps else None
        return int(f.read_text().strip())

    def restore(self, template: PyTree, *, step: int | None = None,
                shardings: PyTree | None = None) -> tuple[PyTree, dict]:
        """Restore into the structure of ``template``.

        shardings: optional matching pytree of NamedSharding - each leaf is
        device_put with the TARGET sharding (elastic re-shard on restore).
        """
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        tree, manifest = _read_tree(self.dir / f"step_{step:08d}", template,
                                    shardings)
        return tree, manifest["metadata"]
