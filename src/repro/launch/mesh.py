"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the pod axis is pure
data/FSDP parallelism over DCI.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / smoke runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
