"""Shape-cell semantics: step functions + input specs per (arch x cell).

  train_4k    -> train_step   (fwd+bwd+AdamW, grad-accum microbatching, remat)
  prefill_32k -> serve_prefill (fwd, fills KV caches, last-token logits)
  decode_32k  -> serve_step   (1 token against a full cache)
  long_500k   -> serve_step   (batch=1, sequence-sharded KV)

[audio]/[vlm] frontends are stubs: input_specs() provides precomputed frame/
patch embeddings.  Whisper splits a cell's seq_len as enc S/2 + dec S/2.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.optim.losses import lm_loss

PyTree = Any


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def token_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    B, S = cell.global_batch, cell.seq_len
    if cfg.is_encoder_decoder:
        return {"tokens": _sds((B, S // 2), jnp.int32),
                "frames": _sds((B, S // 2, cfg.d_model), jnp.bfloat16)}
    if cfg.vit_dim:
        return {"tokens": _sds((B, S - cfg.num_image_tokens), jnp.int32),
                "patches": _sds((B, cfg.num_image_tokens, cfg.vit_dim),
                                jnp.bfloat16)}
    return {"tokens": _sds((B, S), jnp.int32)}


def cache_capacity(cfg: ModelConfig, cell: ShapeCell) -> int:
    return cell.seq_len // 2 if cfg.is_encoder_decoder else cell.seq_len


def cache_specs(cfg: ModelConfig, cell: ShapeCell) -> PyTree:
    cap = cache_capacity(cfg, cell)
    enc_len = cell.seq_len // 2 if cfg.is_encoder_decoder else 0
    return jax.eval_shape(
        lambda: M.init_caches(cfg, cell.global_batch, cap, enc_len))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """All inputs for the cell's step function (excluding weights/opt)."""
    if cell.kind == "train":
        return {"batch": token_specs(cfg, cell)}
    if cell.kind == "prefill":
        return {"batch": token_specs(cfg, cell)}
    # decode
    return {"token": _sds((cell.global_batch,), jnp.int32),
            "caches": cache_specs(cfg, cell),
            "t": _sds((), jnp.int32)}


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def choose_accum(cfg: ModelConfig, cell: ShapeCell, dp: int,
                 target_per_device: int = 1) -> int:
    """Grad-accum factor so each device sees ~target_per_device rows/micro."""
    per_dev = max(cell.global_batch // dp, 1)
    accum = max(per_dev // target_per_device, 1)
    while cell.global_batch % (accum * dp) != 0 and accum > 1:
        accum -= 1
    return accum


def make_train_step(cfg: ModelConfig, ocfg: opt.AdamWConfig, *,
                    accum: int = 1, remat: bool = True,
                    cast_bf16: bool = False):
    def loss_fn(params, mb):
        return lm_loss(cfg, params, mb, remat=remat)

    def train_step(params, ostate, batch):
        def reshape(x):
            return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])

        micro_batches = jax.tree.map(reshape, batch)
        # one bf16 cast of the sharded fp32 masters BEFORE the microbatch
        # loop: every FSDP all-gather inside the layer scan then moves bf16
        # (2x less ICI) and the cast runs once, not once per microbatch.
        compute_params = jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if (cast_bf16 and p.dtype == jnp.float32 and p.ndim >= 2) else p,
            params)

        def micro(g_acc, mb):
            (l, m), g = jax.value_and_grad(
                loss_fn, has_aux=True)(compute_params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return g_acc, l

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        if accum == 1:
            g, losses = micro(g0, jax.tree.map(lambda x: x[0], micro_batches))
            losses = losses[None]
        else:
            g, losses = jax.lax.scan(micro, g0, micro_batches)
        g = jax.tree.map(lambda x: x / accum, g)
        params, ostate, om = opt.adamw_update(ocfg, g, ostate, params)
        return params, ostate, {"loss": jnp.mean(losses), **om}

    return train_step


def make_prefill(cfg: ModelConfig, cell: ShapeCell):
    cap = cache_capacity(cfg, cell)

    def serve_prefill(params, batch):
        logits, caches = M.prefill(cfg, params, batch, cache_capacity=cap)
        return logits, caches

    return serve_prefill


def make_decode(cfg: ModelConfig, cell: ShapeCell, *, seq_sharded: bool):
    def serve_step(params, token, caches, t):
        return M.decode_step(cfg, params, token, caches, t,
                             seq_sharded=seq_sharded)

    return serve_step


def make_search_step(cfg: ModelConfig, pcfg, *, remat: bool = True):
    """UniPruning mirror-descent step (the paper's workload) for dry-runs."""
    from repro.core import mirror

    def loss_fn(W, batch):
        return lm_loss(cfg, W, batch, remat=remat)

    def search_step(state, batch, stats, prunable):
        return mirror.search_step(pcfg, loss_fn, state, batch, stats, prunable)

    return search_step
