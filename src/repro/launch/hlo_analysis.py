"""Post-SPMD HLO analysis with while-loop trip-count awareness.

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE (XLA while
bodies are not multiplied by trip count), which under-counts layer-stacked
models by 10-100x.  This module parses the optimized HLO text, builds the
computation call graph (entry -> while bodies -> fusions), extracts per-
computation dot-FLOPs / materialized bytes / collective bytes, and rolls
them up with multiplicity = product of enclosing while trip counts.

Format notes (XLA:CPU optimized dumps):
  * computation headers start at column 0: ``%name (sig) -> type {``;
    instruction lines are indented; ``}`` closes.
  * operands are referenced by name only - shapes come from each
    instruction's own definition, so we keep a per-computation symbol table.
  * XLA may "widen" (unroll x2) while loops; trip counts come from the
    ``constant(N)`` in the loop condition, so flops stay exact
    (N_wide * 2 bodies == N_orig * 1 body).

Conventions:
  * dot flops        = 2 * numel(out) * prod(lhs contracted dims)
  * bytes            = sum of instruction OUTPUT sizes (parameters, tuples,
    GTEs, bitcasts, whiles, fusion internals excluded) = unique materialized
    buffers; the roofline memory term uses 2x (write + read).
  * collective bytes = wire convention: all-gather/all-to-all/permute ->
    output size; all-reduce -> 2x size; reduce-scatter -> group_size x out.
  * async collectives appear as ``<op>-start`` / ``<op>-done`` pairs; the
    traffic is charged on the -start and the -done is skipped, so each
    pair counts exactly once.
  * dumps may be tab-indented and/or CRLF-terminated (some toolchains
    rewrite them); both are normalized before parsing.
"""
from __future__ import annotations

import dataclasses
import gzip
import re

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
               "token": 0, "s4": 1, "u4": 1}

_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
NO_BYTES_OPS = ("parameter(", "constant(", "get-tuple-element(", "tuple(",
                "bitcast(", "bitcast-convert(", "after-all(", "while(",
                "partition-id(", "replica-id(", "custom-call(",
                "conditional(", "call(")


def _normalize(text: str) -> str:
    """Tolerate rewritten dumps: CRLF line endings, tab indentation."""
    return text.replace("\r\n", "\n").replace("\r", "\n").expandtabs(2)


def _numel(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    bytes_out: float = 0.0
    dus_bytes: float = 0.0   # dynamic-update-slice targets (in-place)
    coll_bytes: float = 0.0
    coll_by_op: dict = dataclasses.field(default_factory=dict)
    calls: list = dataclasses.field(default_factory=list)
    trip_hint: int = 1


def _parse_computations(text: str) -> tuple[dict[str, list[str]], str]:
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if line and not line[0].isspace():
            m = re.match(r"^(ENTRY\s+)?%([\w\.\-]+)\s+\(", line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
            if line.strip().startswith("}"):
                cur = None
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _analyze_comp(lines: list[str]) -> CompStats:
    st = CompStats()
    shapes: dict[str, tuple[str, list[int]]] = {}
    parsed = []
    for line in lines:
        m = _DEF.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        sm = _SHAPE.search(rhs)
        if sm:
            shapes[name] = (sm.group(1),
                            [int(x) for x in sm.group(2).split(",") if x])
        parsed.append((name, rhs))

    max_const = 1
    for name, rhs in parsed:
        dt, dims = shapes.get(name, ("f32", []))
        numel = 1
        for d in dims:
            numel *= d
        nbytes = numel * DTYPE_BYTES.get(dt, 4)

        if " dot(" in rhs:
            cm = _CONTRACT.search(rhs)
            contracted = 1
            if cm is not None:
                dims_idx = [int(x) for x in cm.group(1).split(",") if x]
                inner = rhs.split(" dot(", 1)[1]
                ops = _OPERANDS.findall(inner)
                if ops and ops[0] in shapes:
                    lhs_dims = shapes[ops[0]][1]
                    for di in dims_idx:
                        if di < len(lhs_dims):
                            contracted *= lhs_dims[di]
            st.dot_flops += 2.0 * numel * contracted

        # sync form " all-reduce(" OR async start " all-reduce-start(";
        # the matching "-done(" only materializes the result, skip it so an
        # async pair is charged exactly once (on the -start, which carries
        # the replica_groups).
        is_coll = next((c for c in COLLECTIVES
                        if f" {c}(" in rhs or f" {c}-start(" in rhs), None)
        if is_coll and f" {is_coll}-done(" not in rhs:
            g = _GROUPS.search(rhs)
            gs = int(g.group(2)) if g else 0
            traffic = nbytes
            if is_coll == "all-reduce":
                traffic = 2 * nbytes
            elif is_coll == "reduce-scatter":
                traffic = nbytes * max(gs, 1)
            st.coll_bytes += traffic
            st.coll_by_op[is_coll] = st.coll_by_op.get(is_coll, 0.0) + traffic

        if "dynamic-update-slice" in rhs or "dynamic-update-slice" in name:
            # in-place update (plain op or DUS fusion): a loop's DUS covers
            # its buffer ONCE over all iterations, so this accrues at the
            # multiplicity of the enclosing loop INSTANCE (see visit()).
            st.dus_bytes += nbytes
        elif not any(op in rhs for op in NO_BYTES_OPS):
            st.bytes_out += nbytes

        if " while(" in rhs:
            cond = re.search(r"condition=%?([\w\.\-]+)", rhs)
            body = re.search(r"body=%?([\w\.\-]+)", rhs)
            if cond and body:
                st.calls.append(("while", body.group(1), cond.group(1)))
        elif "fusion(" in rhs:
            c = re.search(r"calls=%?([\w\.\-]+)", rhs)
            if c:
                st.calls.append(("fusion", c.group(1), None))
        elif "conditional(" in rhs:
            for c in re.findall(r"branch_computations=\{([^}]*)\}", rhs):
                for b in re.findall(r"%([\w\.\-]+)", c):
                    st.calls.append(("call", b, None))
        elif " call(" in rhs:
            c = re.search(r"to_apply=%?([\w\.\-]+)", rhs)
            if c:
                st.calls.append(("call", c.group(1), None))

        cm = re.search(r"s32\[\] constant\((\d+)\)", rhs)
        if cm:
            max_const = max(max_const, int(cm.group(1)))
    st.trip_hint = max_const
    return st


@dataclasses.dataclass
class HloSummary:
    dot_flops: float
    bytes_out: float
    coll_bytes: float
    coll_by_op: dict
    n_while: int
    trip_counts: list


def analyze(text: str) -> HloSummary:
    raw, entry = _parse_computations(_normalize(text))
    comps = {name: _analyze_comp(lines) for name, lines in raw.items()}
    if entry is None:
        entry = next(iter(comps))
    total = HloSummary(0.0, 0.0, 0.0, {}, 0, [])

    def visit(name: str, mult: float, parent_mult: float, in_fusion: bool,
              depth: int = 0):
        st = comps.get(name)
        if st is None or depth > 64:
            return
        total.dot_flops += mult * st.dot_flops
        if not in_fusion:
            total.bytes_out += mult * st.bytes_out + \
                parent_mult * st.dus_bytes
            total.coll_bytes += mult * st.coll_bytes
            for k, v in st.coll_by_op.items():
                total.coll_by_op[k] = total.coll_by_op.get(k, 0) + mult * v
        for kind, callee, cond in st.calls:
            if kind == "while":
                trip = comps[cond].trip_hint if cond in comps else 1
                total.n_while += 1
                total.trip_counts.append(trip)
                visit(callee, mult * trip, mult, in_fusion, depth + 1)
            elif kind == "fusion":
                visit(callee, mult, parent_mult, True, depth + 1)
            else:
                visit(callee, mult, parent_mult, in_fusion, depth + 1)

    visit(entry, 1.0, 1.0, False)
    return total


def analyze_file(path) -> HloSummary:
    return analyze(load_text(path))


def load_text(path) -> str:
    """Read an HLO dump, transparently gunzipping ``*.gz``."""
    op = gzip.open if str(path).endswith(".gz") else open
    with op(path, "rt") as f:
        return f.read()


def attribution(text: str) -> list[tuple]:
    """Per-computation attribution of the roofline terms after trip-count
    multiplication: rows of (bytes, dot_flops, coll_bytes, mult, name),
    unsorted.  Localizes the dominant term when the totals from
    ``analyze`` look wrong (CLI: ``python -m repro.analysis hlo``)."""
    raw, entry = _parse_computations(_normalize(text))
    comps = {name: _analyze_comp(lines) for name, lines in raw.items()}
    if entry is None and comps:
        entry = next(iter(comps))
    rows: list[tuple] = []

    def fusion_flops(name, depth=0) -> float:
        """dot flops of a computation INCLUDING its fusion callees - a
        fusion's work belongs to the computation that launches it, so the
        rows sum to analyze()'s totals instead of hiding fused dots."""
        st = comps.get(name)
        if st is None or depth > 64:
            return 0.0
        tot = st.dot_flops
        for kind, callee, _ in st.calls:
            if kind == "fusion":
                tot += fusion_flops(callee, depth + 1)
        return tot

    def visit(name, mult, parent_mult, in_fusion, depth=0):
        st = comps.get(name)
        if st is None or depth > 64:
            return
        if not in_fusion:
            rows.append((mult * st.bytes_out + parent_mult * st.dus_bytes,
                         mult * fusion_flops(name),
                         mult * st.coll_bytes, mult, name))
        for kind, callee, cond in st.calls:
            if kind == "while":
                trip = comps[cond].trip_hint if cond in comps else 1
                visit(callee, mult * trip, mult, in_fusion, depth + 1)
            elif kind == "fusion":
                visit(callee, mult, parent_mult, True, depth + 1)
            else:
                visit(callee, mult, parent_mult, in_fusion, depth + 1)

    visit(entry, 1.0, 1.0, False)
    return rows


_ALIAS_ENTRY = re.compile(
    r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{([\d,\s]*)\},\s*(may-alias|must-alias)\)")


def parse_input_output_aliases(text: str) -> list[dict]:
    """Input->output buffer aliases from a compiled HloModule header.

    The header carries ``input_output_alias={ {out}: (param, {idx}, kind),
    ... }``; each entry is one donated buffer XLA actually aliased.  A
    declared ``donate_argnums`` whose buffer is missing here was silently
    un-donated (dtype mismatch, aliasing hazard) - the jaxpr auditor's
    donation check diffs this list against the declaration.
    """
    m = re.search(r"input_output_alias=\{", text)
    if not m:
        return []
    i, depth = m.end(), 1
    while i < len(text) and depth:
        if text[i] == "{":
            depth += 1
        elif text[i] == "}":
            depth -= 1
        i += 1
    block = text[m.end():i - 1]
    out = []
    for em in _ALIAS_ENTRY.finditer(block):
        ints = lambda s: [int(x) for x in s.replace(" ", "").split(",") if x]
        out.append({"output_index": ints(em.group(1)),
                    "param_number": int(em.group(2)),
                    "param_index": ints(em.group(3)),
                    "kind": em.group(4)})
    return out
