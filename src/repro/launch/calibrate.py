"""Calibration entry point: stats -> mirror-descent search -> MaskBank.

The ONE place the UniPruning calibration pipeline runs.  Everything
downstream - ``launch.serve`` (single engine or ``--fleet``), the table
benchmarks, the examples - consumes the MaskBank artifact this writes and
never re-runs ``collect_stats`` / ``run_search`` inline: calibrate once,
re-threshold to masks at any budget, in any process.

The pipeline itself is the mesh-native one: the jitted sharded stats pass
(``models.model.stats_sumsq``), then ``lax.scan``-chunked jitted search
steps with donated, ``dist.sharding``-placed state (pass ``--mesh`` /
``rules=``), with optional microbatch gradient accumulation
(``--grad-accum``).

  PYTHONPATH=src python -m repro.launch.calibrate --arch llama3.2-1b \
      --smoke --out results/bank/llama --metric wanda --mode nm --steps 30
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --sparse-artifact results/bank/llama --fleet 0.0,0.5,2:4
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
from typing import Any

import jax

from repro import obs
from repro.configs.base import PruneConfig, get_config, get_smoke_config

PyTree = Any


def _stage_annotation(name: str, step: int, annotate: bool):
    """jax.profiler.StepTraceAnnotation when --xprof-dir is live, else a
    nullcontext - the annotations only mean something inside an active
    profiler trace."""
    if not annotate:
        return contextlib.nullcontext()
    return jax.profiler.StepTraceAnnotation(name, step_num=step)


def params_fingerprint(params: PyTree) -> str:
    """Order-stable crc32 of the weights a bank was calibrated against."""
    from repro.sparse.bank import _tree_checksum
    return _tree_checksum(params)


def calibrate_to_bank(out_dir, *, cfg, pcfg: PruneConfig, params: PyTree,
                      calib: list[dict], arch: str, smoke: bool,
                      rules=None, stats_impl: str = "jit",
                      log_every: int = 0, loss_fn=None,
                      extra: dict | None = None, xprof: bool = False):
    """Run the full calibration once and persist the MaskBank artifact.

    Returns the in-memory :class:`~repro.sparse.bank.MaskBank` backed by the
    artifact just written to ``out_dir``.

    Stage timings go through ``obs.timer``: monotonic ``perf_counter``
    clocks with ``jax.block_until_ready`` fencing on each stage's outputs,
    so the seconds recorded in the bank's meta measure the device work the
    stage dispatched, not just the python that launched it (a bare
    ``time.time()`` around async-dispatched jax under-reports and bills
    the tail to the next stage).  ``xprof=True`` wraps each stage in a
    ``jax.profiler.StepTraceAnnotation`` for an active profiler trace.
    """
    from repro.core import calibrate
    from repro.sparse.bank import MaskBank
    with _stage_annotation("calibrate.stats", 0, xprof), \
            obs.timer("calibrate.stats", arch=arch,
                      stats_impl=stats_impl) as t_stats:
        stats = calibrate.collect_stats(cfg, params, calib, pcfg=pcfg,
                                        impl=stats_impl, rules=rules)
        t_stats.fence(stats)
    with _stage_annotation("calibrate.search", 1, xprof), \
            obs.timer("calibrate.search", arch=arch,
                      steps=pcfg.steps) as t_search:
        state, history = calibrate.run_search(cfg, pcfg, params, calib,
                                              stats, rules=rules,
                                              log_every=log_every,
                                              loss_fn=loss_fn)
        t_search.fence(state)
    meta = {"params_fingerprint": params_fingerprint(params),
            "stats_impl": stats_impl,
            "stats_seconds": t_stats.seconds,
            "search_seconds": t_search.seconds,
            "history": history, **(extra or {})}
    with obs.timer("calibrate.save_bank", arch=arch) as t_save:
        bank = MaskBank.save(out_dir, arch=arch, smoke=smoke, state=state,
                             stats=stats, pcfg=pcfg, cfg=cfg, extra=meta)
    obs.log("calibrate.done", arch=arch, out_dir=str(out_dir),
            stats_seconds=t_stats.seconds, search_seconds=t_search.seconds,
            save_seconds=t_save.seconds)
    return bank


def ensure_bank(out_dir, *, cfg, pcfg: PruneConfig, params: PyTree,
                calib: list[dict], arch: str, smoke: bool, **kw):
    """Load the bank at ``out_dir`` if it matches (same PruneConfig, same
    weights fingerprint); otherwise calibrate and (re)write it.  The cache
    that lets many benchmark tables share ONE calibration per model."""
    from repro.sparse.bank import MaskBank
    try:
        bank = MaskBank.load(out_dir, cfg=cfg)
        if (bank.meta.get("pcfg") == dataclasses.asdict(pcfg)
                and bank.meta.get("params_fingerprint")
                == params_fingerprint(params)):
            return bank
    except (FileNotFoundError, ValueError, AssertionError, KeyError):
        pass  # absent/stale/corrupt bank: fall through and recalibrate
    return calibrate_to_bank(out_dir, cfg=cfg, pcfg=pcfg, params=params,
                             calib=calib, arch=arch, smoke=smoke, **kw)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", required=True, help="mask-bank artifact dir")
    ap.add_argument("--metric", default="wanda",
                    choices=["magnitude", "wanda", "ria", "stochria"])
    ap.add_argument("--mode", default="nm",
                    choices=["nm", "unstructured"])
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--stats-batches", type=int, default=4)
    ap.add_argument("--scan-chunk", type=int, default=8,
                    help="search steps per jitted lax.scan dispatch "
                         "(<= 1: eager per-step dispatch)")
    ap.add_argument("--grad-accum", type=int, default=1,
                    help="microbatches per search step (gradient "
                         "accumulation over batch-dim slices)")
    ap.add_argument("--stats-impl", default="jit", choices=["jit", "tape"])
    ap.add_argument("--calib-n", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default=None, choices=[None, "host"],
                    help="'host': shard stats + search state over the "
                         "local host mesh via dist.sharding rules")
    ap.add_argument("--trace-dir", default=None,
                    help="enable the flight recorder and write the JSONL "
                         "event trace (spans, per-chunk search series) + "
                         "a metrics.prom snapshot here")
    ap.add_argument("--xprof-dir", default=None,
                    help="capture a jax profiler trace here, with "
                         "StepTraceAnnotation marks per pipeline stage")
    args = ap.parse_args(argv)

    if args.trace_dir:
        obs.configure(trace_dir=args.trace_dir)

    from repro.data.synthetic import batches_for
    from repro.models import model as M
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(cfg, jax.random.key(0))
    calib = batches_for(cfg, n=args.calib_n, batch=args.batch, seq=args.seq,
                        split="calib")
    pcfg = PruneConfig(local_metric=args.metric, mode=args.mode,
                       steps=args.steps, stats_batches=args.stats_batches,
                       scan_chunk=args.scan_chunk,
                       grad_accum=args.grad_accum)
    rules = None
    if args.mesh == "host":
        from repro.dist.sharding import make_production_rules
        from repro.launch.mesh import make_host_mesh
        rules = make_production_rules(make_host_mesh())

    if args.xprof_dir:
        jax.profiler.start_trace(args.xprof_dir)
    try:
        bank = calibrate_to_bank(args.out, cfg=cfg, pcfg=pcfg,
                                 params=params, calib=calib, arch=args.arch,
                                 smoke=args.smoke, rules=rules,
                                 stats_impl=args.stats_impl,
                                 log_every=args.log_every,
                                 xprof=bool(args.xprof_dir))
    finally:
        if args.xprof_dir:
            jax.profiler.stop_trace()
            print(f"wrote profiler trace -> {args.xprof_dir}")
    n_pr = sum(g.size for g in jax.tree.leaves(
        bank.Gamma, is_leaf=lambda x: x is None) if g is not None)
    print(f"calibrated {args.arch}{' (smoke)' if args.smoke else ''}: "
          f"{pcfg.steps} search steps over {n_pr/1e6:.2f}M prunable params "
          f"(stats {bank.meta['stats_seconds']:.1f}s via "
          f"{args.stats_impl}, search {bank.meta['search_seconds']:.1f}s, "
          f"{pcfg.steps / max(bank.meta['search_seconds'], 1e-9):.2f} "
          f"steps/s)")
    print(f"saved mask bank -> {args.out}")
    if args.trace_dir:
        import pathlib
        prom = pathlib.Path(args.trace_dir) / "metrics.prom"
        prom.write_text(obs.expose())
        obs.flush()
        print(f"wrote trace -> {obs.trace_path()} and {prom}")


if __name__ == "__main__":
    main()
