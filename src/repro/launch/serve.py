"""Batched serving driver: prefill a batch of prompts, then decode tokens.

Demonstrates the serving path end-to-end on host devices, exercising the
same prefill/decode step functions the dry-run lowers for the production
mesh.  Sparse serving has two modes:

* ``--sparse [--save-artifact DIR]`` - run ``launch.calibrate`` (2:4) and
  serve from the resulting mask-bank artifact (written to --save-artifact,
  or a temp dir);
* ``--sparse-artifact DIR [--sparsity S]`` - skip calibration entirely:
  load the bank, re-threshold to masks in one shot, and serve with
  2:4-compressed weights executing through ``kernels.nm_spmm.nm_matmul``
  (``--weight-format masked`` serves the same masks as masked-dense W0*M -
  token-for-token identical, for A/B checks);
* ``--sparse-artifact DIR --fleet 0.0,0.5,2:4 [--ab W,W,...]`` - serve N
  budgets from the SAME bank concurrently behind one router
  (``serve.fleet.SparsityFleet``): tagged round-robin by default, weighted
  A/B traffic splitting with ``--ab`` (per-budget tok/s + token-agreement
  vs the densest member in the printed report);
* ``--fleet ... --spec draft:2:4,verify:0.0,k:4`` - route the batch
  through self-speculative decoding (``serve.spec``): the sparse member
  drafts k tokens per round, the dense member verifies them in one
  teacher-forced jitted pass; output bit-identical to the verifier alone.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --sparse --save-artifact results/bank/llama --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --sparse-artifact results/bank/llama --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --sparse-artifact results/bank/llama --fleet 0.0,0.5,2:4 --ab 1,1,2
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --sparse-artifact results/bank/llama --fleet 0.0,2:4 \
      --spec draft:2:4,verify:0.0,k:4
"""
from __future__ import annotations

import argparse
import contextlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import PruneConfig, get_config, get_smoke_config
from repro.data.synthetic import batches_for
from repro.models import model as M


def _step_annotation(name: str, step: int, annotate: bool):
    """StepTraceAnnotation mark when --xprof-dir captures, else nothing."""
    if not annotate:
        return contextlib.nullcontext()
    return jax.profiler.StepTraceAnnotation(name, step_num=step)


def _calibrate_sparse(cfg, args, params):
    """2:4 UniPruning through the ``launch.calibrate`` entry point: the
    calibration always lands as a MaskBank artifact (a temp dir unless
    ``--save-artifact`` pins it) and serving re-thresholds from the bank -
    no inline stats/search in the serving driver."""
    import tempfile

    from repro.core import masks as masks_mod
    from repro.launch import calibrate as launch_cal
    tmp = None
    if args.save_artifact:
        out = args.save_artifact
    else:  # transient artifact: removed once the masks are extracted
        tmp = tempfile.TemporaryDirectory(prefix="mask-bank-")
        out = tmp.name + "/bank"
    try:
        calib = batches_for(cfg, n=8, batch=4, seq=args.prompt_len,
                            split="calib")
        pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=30)
        bank = launch_cal.calibrate_to_bank(
            out, cfg=cfg, pcfg=pcfg, params=params, calib=calib,
            arch=args.arch, smoke=args.smoke)
        if args.save_artifact:
            print(f"saved mask bank -> {out}")
        print("serving 2:4-pruned weights (masked-dense, bank-backed "
              "calibration)")
        return masks_mod.apply_masks(params, bank.masks_at())
    finally:
        if tmp is not None:
            tmp.cleanup()


def _load_sparse(args, params):
    """Bank-backed sparse params: one-shot re-threshold, no calibration."""
    from repro.sparse.bank import MaskBank
    from repro.sparse.apply import compressed_report
    bank = MaskBank.load(args.sparse_artifact)
    # only the N:M pattern has a compressed execution format; an explicit
    # unstructured --sparsity re-threshold serves masked-dense
    compressed = (args.weight_format == "compressed"
                  and bank.pcfg.mode == "nm" and args.sparsity is None)
    if args.weight_format == "compressed" and not compressed:
        print("note: unstructured budget -> masked-dense serving "
              "(2:4-compressed execution needs the bank's N:M pattern)")
    sparse, masks = bank.sparse_params(params, sparsity=args.sparsity,
                                       compressed=compressed,
                                       idx_bits=args.idx_bits,
                                       with_masks=True)
    if compressed:
        rep = compressed_report(sparse, masks)
        n_comp = sum(not l["fallback"] for l in rep["layers"])
        print(f"serving from bank {args.sparse_artifact}: "
              f"{n_comp} kernels 2:4-compressed "
              f"({args.idx_bits}-bit index storage, "
              f"{rep['kernel_native_packed']} kernel-native packed planes, "
              f"{rep['fallback_leaves']} masked-dense fallbacks), "
              f"{rep['bytes_compressed'] / 1e6:.2f} MB vs "
              f"{rep['bytes_dense_bf16'] / 1e6:.2f} MB dense bf16 "
              f"(ratio {rep['ratio']:.3f})")
    else:
        print(f"serving from bank {args.sparse_artifact} (masked-dense)")
    return bank.cfg, sparse


def _serve_fleet(args, params) -> None:
    """N budgets from one bank behind one router; prints the A/B report."""
    from repro.serve.fleet import SparsityFleet
    budgets = [b for b in args.fleet.split(",") if b]
    capacity = args.prompt_len + args.gen + 1
    fleet = SparsityFleet.from_artifact(
        args.sparse_artifact, params, budgets, slots=args.slots,
        capacity=capacity, idx_bits=args.idx_bits, spec=args.spec)
    cfg = fleet.cfg
    batch = batches_for(cfg, n=1, batch=args.batch, seq=args.prompt_len,
                        split="valid")[0]
    prompts = [np.asarray(batch["tokens"][i]) for i in range(args.batch)]
    names = list(fleet.engines)
    if args.spec:
        rids = [fleet.submit(p, args.gen, spec=True) for p in prompts]
        print(f"self-speculative decoding: {args.spec}")
    elif args.ab:
        weights = [float(w) for w in args.ab.split(",")]
        if len(weights) != len(names):
            raise SystemExit(f"--ab needs {len(names)} weights (one per "
                             f"--fleet budget), got {len(weights)}")
        ab = dict(zip(names, weights))
        rids = [fleet.submit(p, args.gen, ab=ab) for p in prompts]
        print(f"A/B split over {names} with weights {weights}")
    else:
        rids = [fleet.submit(p, args.gen, budget=names[i % len(names)])
                for i, p in enumerate(prompts)]
        print(f"tagged round-robin over {names}")
    t0 = time.time()
    out = fleet.run()
    dt = time.time() - t0
    rep = fleet.report()
    print(f"fleet served {len(out)} requests x {args.gen} tokens from "
          f"{args.sparse_artifact} in {dt:.2f}s "
          f"(reference: {rep['reference']})")
    for name, r in rep["budgets"].items():
        agree = r["token_agreement_vs_reference"]
        p50, p95 = r["decode_ms_p50"], r["decode_ms_p95"]
        print(f"  {name:>6}: slots {r['slots']}, {r['requests']} reqs, "
              f"{(r['tok_s'] or 0):8.1f} tok/s, "
              f"byte ratio {r['weight_bytes_ratio']:.4f} "
              f"({r['compressed_kernels']} compressed, "
              f"{r['fallback_leaves']} masked-dense), "
              f"shared dense leaves {r['shared_dense_leaves']}"
              + (f", agreement vs ref {agree:.3f}" if agree is not None
                 else "")
              + (f", decode p50/p95 {p50:.2f}/{p95:.2f} ms"
                 if p50 is not None else ""))
    spec = rep["spec"]
    if spec is not None:
        print(f"  spec: {spec['draft']} drafts -> {spec['verify']} "
              f"verifies, k={spec['k']}, "
              f"accept rate {(spec['accept_rate'] or 0):.3f} "
              f"(EMA {spec['accept_ema']:.3f}), "
              f"{(spec['accepted_tokens_per_round'] or 0):.2f} tokens/round "
              f"over {spec['rounds']} rounds, "
              f"{spec['rollbacks']} rollbacks, "
              f"{(spec['tok_s'] or 0):.1f} tok/s")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sparse", action="store_true",
                    help="prune 2:4 with UniPruning before serving")
    ap.add_argument("--save-artifact", default=None,
                    help="with --sparse: persist the mask bank here")
    ap.add_argument("--sparse-artifact", default=None,
                    help="serve from a saved mask bank (no calibration)")
    ap.add_argument("--sparsity", type=float, default=None,
                    help="unstructured budget for bank re-threshold "
                         "(default: the bank's calibrated N:M pattern)")
    ap.add_argument("--weight-format", default="compressed",
                    choices=["compressed", "masked"],
                    help="bank serving: 2:4-compressed kernels vs W0*M")
    ap.add_argument("--idx-bits", type=int, default=2, choices=[2, 8],
                    help="compressed index layout: 2 = packed 4-per-byte "
                         "(kernel-native, 9/16 of dense bf16 bytes), "
                         "8 = int8 fallback plane (3/4)")
    ap.add_argument("--fleet", default=None,
                    help="with --sparse-artifact: comma-separated budgets "
                         "served concurrently from the one bank behind one "
                         "router, e.g. 0.0,0.5,2:4")
    ap.add_argument("--ab", default=None,
                    help="with --fleet: comma-separated traffic weights "
                         "aligned with the --fleet budgets (default: "
                         "tagged round-robin)")
    ap.add_argument("--spec", default=None,
                    help="with --fleet: self-speculative decoding, e.g. "
                         "draft:2:4,verify:0.0,k:4 (draft member proposes "
                         "k tokens/round, verify member checks them in one "
                         "teacher-forced pass; lossless vs the verifier)")
    ap.add_argument("--slots", type=int, default=None,
                    help="fleet decode-slot pool partitioned across "
                         "budgets (default: 2 per budget)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--trace-dir", default=None,
                    help="enable the flight recorder and write the JSONL "
                         "event trace + a metrics.prom snapshot here")
    ap.add_argument("--xprof-dir", default=None,
                    help="capture a jax profiler trace here, with "
                         "StepTraceAnnotation marks per prefill/decode "
                         "step")
    args = ap.parse_args(argv)

    if args.trace_dir:
        obs.configure(trace_dir=args.trace_dir)
    if args.xprof_dir:
        jax.profiler.start_trace(args.xprof_dir)
    try:
        _serve(args)
    finally:
        if args.xprof_dir:
            jax.profiler.stop_trace()
            print(f"wrote profiler trace -> {args.xprof_dir}")
        if args.trace_dir:
            import pathlib
            prom = pathlib.Path(args.trace_dir) / "metrics.prom"
            prom.write_text(obs.expose())
            obs.flush()
            print(f"wrote trace -> {obs.trace_path()} and {prom}")


def _serve(args) -> None:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert not cfg.is_encoder_decoder or args.gen > 0
    params = M.init_params(cfg, jax.random.key(0))

    if args.spec and not args.fleet:
        raise SystemExit("--spec rides the fleet router: pass --fleet with "
                         "the draft and verify budgets")
    if args.fleet:
        if not args.sparse_artifact:
            raise SystemExit("--fleet serves from a saved mask bank: "
                             "pass --sparse-artifact DIR")
        _serve_fleet(args, params)
        return
    if args.sparse_artifact:
        cfg, params = _load_sparse(args, params)
    elif args.sparse:
        params = _calibrate_sparse(cfg, args, params)

    B, P = args.batch, args.prompt_len
    batch = batches_for(cfg, n=1, batch=B, seq=P, split="valid")[0]
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    capacity = P + args.gen + (cfg.num_image_tokens if cfg.vit_dim else 0)

    prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b,
                                             cache_capacity=capacity))
    decode = jax.jit(lambda p, tok, c, t: M.decode_step(cfg, p, tok, c, t))

    xprof = bool(args.xprof_dir)
    # obs.timer: perf_counter + block_until_ready fencing on the stage
    # outputs - async dispatch is charged to the stage that launched it
    with _step_annotation("prefill", 0, xprof), \
            obs.timer("launch.prefill", batch=B, prompt_len=P) as tp:
        logits, caches = prefill(params, batch)
        toks = jnp.argmax(logits, axis=-1)
        tp.fence((toks, caches))
    out = [np.asarray(toks)]
    offset = cfg.num_image_tokens if cfg.vit_dim else 0
    with obs.timer("launch.decode", steps=args.gen - 1) as td:
        for i in range(args.gen - 1):
            sp = obs.span("serve.decode_step")
            with sp, _step_annotation("decode", i + 1, xprof):
                logits, caches = decode(params, toks, caches,
                                        jnp.asarray(P + offset + i,
                                                    jnp.int32))
                if args.temperature > 0:
                    key = jax.random.key(100 + i)
                    toks = jax.random.categorical(key,
                                                  logits / args.temperature)
                else:
                    toks = jnp.argmax(logits, axis=-1)
                out.append(np.asarray(toks))
            if sp.seconds is not None:
                obs.observe("serve.decode_step_ms", sp.seconds * 1e3)
        td.fence(toks)
    gen = np.stack(out, axis=1)
    print(f"prefill {B}x{P} in {tp.seconds:.2f}s; "
          f"decoded {args.gen - 1} steps in {td.seconds:.2f}s "
          f"({B * (args.gen - 1) / max(td.seconds, 1e-9):.1f} tok/s)")
    print("sample continuation:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
