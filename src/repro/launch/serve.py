"""Batched serving driver: prefill a batch of prompts, then decode tokens.

Demonstrates the serving path end-to-end on host devices, optionally with
2:4-sparse weights produced by UniPruning (--sparse), exercising the same
prefill/decode step functions the dry-run lowers for the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PruneConfig, get_config, get_smoke_config
from repro.data.synthetic import batches_for
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--sparse", action="store_true",
                    help="prune 2:4 with UniPruning before serving")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    assert not cfg.is_encoder_decoder or args.gen > 0
    params = M.init_params(cfg, jax.random.key(0))

    if args.sparse:
        from repro.core import calibrate
        calib = batches_for(cfg, n=8, batch=4, seq=args.prompt_len,
                            split="calib")
        pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=30)
        pruned, state, _ = calibrate.unipruning_prune(
            cfg, pcfg, params, calib, sparsities=[0.5])
        params = pruned[0.5]
        print("serving 2:4-pruned weights")

    B, P = args.batch, args.prompt_len
    batch = batches_for(cfg, n=1, batch=B, seq=P, split="valid")[0]
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    capacity = P + args.gen + (cfg.num_image_tokens if cfg.vit_dim else 0)

    prefill = jax.jit(lambda p, b: M.prefill(cfg, p, b,
                                             cache_capacity=capacity))
    decode = jax.jit(lambda p, tok, c, t: M.decode_step(cfg, p, tok, c, t))

    t0 = time.time()
    logits, caches = prefill(params, batch)
    toks = jnp.argmax(logits, axis=-1)
    out = [np.asarray(toks)]
    t_prefill = time.time() - t0
    t0 = time.time()
    offset = cfg.num_image_tokens if cfg.vit_dim else 0
    for i in range(args.gen - 1):
        logits, caches = decode(params, toks, caches,
                                jnp.asarray(P + offset + i, jnp.int32))
        if args.temperature > 0:
            key = jax.random.key(100 + i)
            toks = jax.random.categorical(key, logits / args.temperature)
        else:
            toks = jnp.argmax(logits, axis=-1)
        out.append(np.asarray(toks))
    dt = time.time() - t0
    gen = np.stack(out, axis=1)
    print(f"prefill {B}x{P} in {t_prefill:.2f}s; "
          f"decoded {args.gen - 1} steps in {dt:.2f}s "
          f"({B * (args.gen - 1) / max(dt, 1e-9):.1f} tok/s)")
    print("sample continuation:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
