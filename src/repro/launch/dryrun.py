import os
_FORCE = "--xla_force_host_platform_device_count=512"
if _FORCE not in os.environ.get("XLA_FLAGS", ""):
    # append, never assign: a bare assignment would clobber user-set flags
    # (lint rule REPRO007 guards this pattern repo-wide)
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + _FORCE).strip()

# NOTE: the lines above MUST run before any other import (jax locks the
# device count on first init), which is why the docstring sits below them and
# no `from __future__` import is used in this file.
_DOC = """Multi-pod dry-run launcher: thin shim over ``repro.analysis.zoo``.

The AOT lower/compile loop (every (arch x shape x mesh) cell, per-device
memory_analysis, collective traffic, fits-16GB) lives in
``repro.analysis.zoo`` (:func:`repro.analysis.zoo.run_cell`) so the static
auditor and this launcher share one implementation.  This module only owns
the pre-jax-import device forcing and the CLI:

  python -m repro.launch.dryrun --arch yi-6b --cell train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out results/dryrun

Equivalent: ``python -m repro.analysis --devices 512 zoo --cells ...``.
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--accum", type=int, default=0,
                    help="grad-accum override (perf iterations)")
    ap.add_argument("--bf16-cast", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    from repro.analysis import zoo
    raise SystemExit(zoo.run_cells_main(args))


if __name__ == "__main__":
    main()
