import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), which is why the docstring sits below them and
# no `from __future__` import is used in this file.
_DOC = """Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware:
  * jax.jit(step).lower(**ShapeDtypeStructs).compile() must succeed,
  * memory_analysis() shows the per-device footprint fits a v5e (16 GB),
  * cost_analysis() + the partitioned HLO's collective ops feed the roofline
    (benchmarks/roofline.py).

Usage:
  python -m repro.launch.dryrun --arch yi-6b --cell train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] --out results/dryrun
"""

import argparse
import json
import pathlib
import re
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPE_CELLS, ModelConfig,
                                PruneConfig, ShapeCell, get_config)
from repro.dist import sharding as shd
from repro.dist.axes import use_rules
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim import optimizers as opt

# long_500k requires sub-quadratic service; skipped for pure full-attention
# archs (see DESIGN.md section 6)
LONG_OK = {"zamba2-7b", "xlstm-125m", "gemma2-2b", "gemma3-1b"}

COLLECTIVE_RE = re.compile(
    r"(\w+)\[([\d,]*)\][^ ]* (all-gather|all-reduce|reduce-scatter|"
    r"all-to-all|collective-permute)\(")
GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3fn": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2}


def cell_skipped(cfg: ModelConfig, cell: ShapeCell) -> str | None:
    if cell.name == "long_500k" and cfg.name not in LONG_OK:
        return "full-attention arch: 500k dense-KV decode not serviceable"
    return None


def parse_collectives(hlo: str) -> dict:
    """Sum per-device collective bytes from partitioned optimized HLO."""
    out: dict[str, float] = {}
    details = []
    for line in hlo.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m:
            continue
        dt, dims, op = m.groups()
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        size = n * DTYPE_BYTES.get(dt, 4)
        g = GROUPS_RE.search(line)
        group_size = int(g.group(2)) if g else 0
        if op == "all-reduce":
            traffic = 2 * size  # ring: reduce-scatter + all-gather
        elif op == "reduce-scatter":
            traffic = size * max(group_size, 1)
        else:
            traffic = size
        out[op] = out.get(op, 0.0) + traffic
        details.append({"op": op, "bytes": size, "group_size": group_size})
    out["total_bytes"] = sum(v for k, v in out.items() if k != "total_bytes")
    out["ops"] = details[:512]
    return out


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh, pcfg=None,
               accum_override: int = 0, cast_bf16: bool = False):
    """Returns (fn, arg_specs, in_shardings, donate) for the cell."""
    kv_mode = "all" if cell.name == "long_500k" else (
        "model" if cell.is_serve else False)
    rules = shd.make_production_rules(
        mesh, seq_shard_kv=kv_mode, seq_parallel=cell.kind == "train")
    params_s = M.param_shapes(cfg)
    if cell.is_serve:  # deployment: bf16 weights
        params_s = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype),
            params_s)
    axes = M.param_axes(cfg)
    p_sh = shd.params_sharding(axes, params_s, rules)
    if cell.is_serve:
        # serving layout: embedding table vocab-TP only (no FSDP dim) so the
        # tied unembed matmul shards cleanly instead of replicating
        p_sh["embed"]["table"] = NamedSharding(mesh, P("model", None))
    specs = steps_mod.input_specs(cfg, cell)
    dp = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            dp *= mesh.shape[a]

    if cell.kind == "train":
        accum = accum_override or steps_mod.choose_accum(cfg, cell, dp)
        ocfg = opt.AdamWConfig()
        fn = steps_mod.make_train_step(cfg, ocfg, accum=accum, remat=True,
                                       cast_bf16=cast_bf16)
        ostate_s = jax.eval_shape(opt.adamw_init, params_s)
        o_sh = jax.tree.map(lambda _: None, ostate_s)
        o_sh = opt.AdamWState(mu=p_sh, nu=p_sh,
                              count=NamedSharding(mesh, P()))
        b_sh = shd.batch_sharding_tree(specs["batch"], mesh)
        return (fn, (params_s, ostate_s, specs["batch"]),
                (p_sh, o_sh, b_sh), rules, {"accum": accum, "donate": (0, 1)})
    if cell.kind == "prefill":
        fn = steps_mod.make_prefill(cfg, cell)
        b_sh = shd.batch_sharding_tree(specs["batch"], mesh)
        return fn, (params_s, specs["batch"]), (p_sh, b_sh), rules, {}
    # decode: partial-softmax attention over sharded KV (seq or model axis)
    fn = steps_mod.make_decode(cfg, cell, seq_sharded=True)
    c_sh = shd.cache_sharding(specs["caches"], mesh)
    tok_sh = (NamedSharding(mesh, P(("pod", "data")
                                    if "pod" in mesh.axis_names else "data"))
              if cell.global_batch % dp == 0
              else NamedSharding(mesh, P(None)))
    return (fn, (params_s, specs["token"], specs["caches"], specs["t"]),
            (p_sh, tok_sh, c_sh, NamedSharding(mesh, P())), rules,
            {"donate": (2,)})


def run_cell(arch: str, cell_name: str, *, multi_pod: bool = False,
             hlo_path=None, accum_override: int = 0,
             cast_bf16: bool = False) -> dict:
    cfg = get_config(arch)
    cell = SHAPE_CELLS[cell_name]
    rec: dict = {"arch": arch, "cell": cell_name, "multi_pod": multi_pod,
                 "mesh": "(2,16,16)" if multi_pod else "(16,16)"}
    skip = cell_skipped(cfg, cell)
    if skip:
        rec["skipped"] = skip
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    t0 = time.time()
    fn, arg_specs, in_sh, rules, extra = build_cell(
        cfg, cell, mesh, accum_override=accum_override, cast_bf16=cast_bf16)
    donate = extra.pop("donate", ())
    rec.update(extra)
    with mesh, use_rules(rules):
        lowered = jax.jit(fn, in_shardings=in_sh,
                          donate_argnums=donate).lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        print({k: v for k, v in (ca or {}).items()
               if not k.startswith(("bytes accessed0", "bytes accessed1",
                                    "utilization"))})
        hlo = compiled.as_text()
    if hlo_path is not None:
        import gzip
        with gzip.open(hlo_path, "wt") as f:
            f.write(hlo)
    rec.update({
        "devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes": getattr(ma, "peak_memory_in_bytes", 0),
        },
        "cost": {k: v for k, v in (ca or {}).items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": parse_collectives(hlo),
        "hlo_bytes": len(hlo),
    })
    per_dev = (rec["memory"]["argument_bytes"] - rec["memory"]["alias_bytes"]
               + rec["memory"]["temp_bytes"] + rec["memory"]["output_bytes"])
    rec["fits_16gb"] = bool(per_dev < 16e9)
    rec["per_device_hbm_bytes"] = per_dev
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--accum", type=int, default=0,
                    help="grad-accum override (perf iterations)")
    ap.add_argument("--bf16-cast", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    jobs = []
    if args.all:
        for a in ARCH_IDS:
            for c in SHAPE_CELLS:
                jobs.append((a, c))
    else:
        assert args.arch and args.cell, "--arch/--cell or --all"
        jobs.append((args.arch, args.cell))

    for arch, cell in jobs:
        tag = f"{arch}__{cell}__{'multipod' if args.multi_pod else 'pod'}"
        print(f"=== {tag} ===", flush=True)
        try:
            rec = run_cell(arch, cell, multi_pod=args.multi_pod,
                           hlo_path=outdir / f"{tag}.hlo.gz",
                           accum_override=args.accum,
                           cast_bf16=args.bf16_cast)
        except Exception as e:  # a failure here is a bug in our sharding
            rec = {"arch": arch, "cell": cell, "multi_pod": args.multi_pod,
                   "error": f"{type(e).__name__}: {e}"}
            print("FAILED:", rec["error"], flush=True)
        (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
        ok = "SKIP" if rec.get("skipped") else (
            "ERROR" if rec.get("error") else "ok")
        print(f"--- {tag}: {ok} "
              f"compile={rec.get('compile_s', '-')}s "
              f"hbm/dev={rec.get('per_device_hbm_bytes', 0)/1e9:.2f}GB",
              flush=True)


if __name__ == "__main__":
    main()
