"""Training / pruning-search launcher.

Runs on whatever devices exist (CPU smoke -> TPU pod): builds the mesh,
shards params/optimizer with the production rules, wires the data loader,
checkpoints atomically every --ckpt-every steps and resumes (weights, opt
state, data cursor) after a restart.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 20 --batch 8 --seq 256 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import get_config, get_smoke_config
from repro.data.synthetic import DataCursor, ShardedLoader
from repro.dist import sharding as shd
from repro.dist.axes import use_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import optimizers as opt


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(model=args.model_axis)
    rules = shd.make_production_rules(mesh)
    ocfg = opt.AdamWConfig(lr=args.lr, total_steps=args.steps,
                           warmup_steps=max(args.steps // 10, 1))

    with mesh, use_rules(rules):
        params = M.init_params(cfg, jax.random.key(0))
        p_sh = shd.params_sharding(M.param_axes(cfg), params, rules)
        params = jax.device_put(params, p_sh)
        ostate = opt.adamw_init(params)
        step_fn = jax.jit(make_train_step(cfg, ocfg, accum=args.accum,
                                          remat=True),
                          donate_argnums=(0, 1))

        start = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir)
            if mgr.latest_step() is not None:
                (params, ostate), meta = mgr.restore(
                    (params, ostate),
                    shardings=(p_sh, jax.tree.map(lambda _: None, ostate)))
                params = jax.device_put(params, p_sh)
                start = meta["next_step"]
                print(f"resumed at step {start}")

        loader = ShardedLoader(cfg, global_batch=args.batch, seq=args.seq,
                               cursor=DataCursor(index=start))
        t0 = time.time()
        for step in range(start, args.steps):
            batch = {k: jax.numpy.asarray(v) for k, v in
                     next(loader).items()}
            params, ostate, metrics = step_fn(params, ostate, batch)
            if step % args.log_every == 0:
                # deliberate log-interval sync: pulling the loss every
                # log_every steps IS the progress heartbeat
                print(f"step {step} loss "
                      f"{float(metrics['loss']):.4f} "  # noqa: REPRO001
                      f"gnorm {float(metrics['grad_norm']):.3f} "  # noqa: REPRO001
                      f"({time.time() - t0:.1f}s)", flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save_async(step + 1, (params, ostate),
                               metadata={"next_step": step + 1})
        if mgr:
            mgr.save(args.steps, (params, ostate),
                     metadata={"next_step": args.steps})
        print("done:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
