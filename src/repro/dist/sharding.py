"""Sharding derivation: params / batch / KV-cache NamedSharding trees.

Specs are derived from the logical-axis annotations the model emits
(``models.model.param_axes``) through a :class:`~repro.dist.axes.ShardingRules`
mapping, with a per-dimension divisibility fallback (a dim that the mapped
mesh axes do not divide is replicated instead of erroring).

Compressed leaves (``sparse.formats.SparseTensor`` / ``BitMask``) shard too:
a SparseTensor standing in for a dense (K, N) kernel inherits the dense
kernel's logical axes - ``vals`` (K/2, N) and ``idx`` (K/2 or K/8, N) both
take the N-axis sharding, and keep the K-axis sharding whenever the halved
(vals) / packed-eighthed (idx) dim still divides the mesh axes.  Expert-
banked leaves ((E, K, N) per layer step, possibly under a leading "layers"
scan axis) carry the expert dim through unchanged: only the trailing two
dims are compressed, so the leading "experts" logical axis maps onto its
mesh axes exactly as for the dense bank, with the (K, N) component rules
applying per expert.  BitMask bits are a flat byte buffer with no
meaningful axis: replicated.  So a MaskBank-loaded compressed tree placed
with ``params_sharding`` serves under the production mesh instead of
replicating every sparse leaf.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.axes import ShardingRules, make_rules, spec_for_shape
from repro.sparse.formats import BitMask, SparseTensor

PyTree = Any


def make_production_rules(mesh, *, seq_shard_kv: Any = False,
                          seq_parallel: bool = False) -> ShardingRules:
    """Rules for the production mesh (pod/data FSDP + model TP)."""
    return make_rules(mesh, seq_parallel=seq_parallel,
                      seq_shard_kv=seq_shard_kv)


def _data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _one(axes):
    return axes[0] if isinstance(axes, tuple) and len(axes) == 1 else axes


def sparse_leaf_sharding(axes_str: str | None, st: SparseTensor,
                         rules: ShardingRules) -> SparseTensor:
    """Sharding for one SparseTensor leaf, as a matching pytree node.

    Both components reuse the dense kernel's logical axis names (leading
    "layers" / "experts" axes of stacked and expert-banked leaves included -
    compression only halves/packs the trailing (K, N) dims, so every leading
    axis keeps the dense mapping verbatim); only the divisibility check
    sees the component's actual shape, so the K-dim sharding survives
    exactly when K/2 (vals) resp. K/2-or-K/8 (idx) still divides the mapped
    mesh axes.  Returned as a SparseTensor of NamedShardings so the tree is
    a valid device_put / in_shardings target for the compressed params.
    """
    if axes_str is None:
        rep = NamedSharding(rules.mesh, P())
        return SparseTensor(rep, rep, idx_bits=st.idx_bits)
    names = axes_str.split("|")
    return SparseTensor(
        NamedSharding(rules.mesh,
                      spec_for_shape(rules, names, st.vals.shape)),
        NamedSharding(rules.mesh,
                      spec_for_shape(rules, names, st.idx.shape)),
        idx_bits=st.idx_bits)


def params_sharding(axes_tree: PyTree, shapes_tree: PyTree,
                    rules: ShardingRules) -> PyTree:
    """'|'-joined logical-axis strings + shapes -> NamedSharding tree.

    ``shapes_tree`` may be ``models.model.param_shapes`` output or an actual
    params tree; SparseTensor leaves (compressed kernels) get component-wise
    specs via :func:`sparse_leaf_sharding`, BitMask leaves replicate.
    """
    def leaf(axes_str, shape_like):
        if isinstance(shape_like, SparseTensor):
            return sparse_leaf_sharding(axes_str, shape_like, rules)
        if isinstance(shape_like, BitMask):
            return BitMask(NamedSharding(rules.mesh, P()), shape_like.shape)
        if axes_str is None or shape_like is None:
            return NamedSharding(rules.mesh, P())
        names = axes_str.split("|")
        spec = spec_for_shape(rules, names, shape_like.shape)
        return NamedSharding(rules.mesh, spec)

    return jax.tree.map(leaf, axes_tree, shapes_tree,
                        is_leaf=lambda x: x is None)


def search_state_sharding(axes_tree: PyTree, state, rules: ShardingRules):
    """NamedSharding tree for a ``core.mirror.SearchState`` on the mesh.

    The trainable copy W inherits the dense parameter rules verbatim (it IS
    the params tree in fp32); Gamma and V are prunable-leaf shadows of W, so
    each non-None leaf reuses its kernel's sharding - the three full-size
    fp32 trees of the mirror-descent search live distributed instead of
    replicated.  step/rng replicate.  The result pairs leaf-for-leaf with
    the state for ``jax.device_put`` / jit in_shardings.
    """
    from repro.core.mirror import SearchState
    base = params_sharding(axes_tree, state.W, rules)
    rep = NamedSharding(rules.mesh, P())

    def gv(g, sh):
        return None if g is None else sh

    return SearchState(
        W=base,
        Gamma=jax.tree.map(gv, state.Gamma, base,
                           is_leaf=lambda x: x is None),
        V=jax.tree.map(gv, state.V, base, is_leaf=lambda x: x is None),
        step=rep, rng=rep)


def stacked_batch_sharding(stacked_tree: PyTree, mesh) -> PyTree:
    """Scan-stacked calibration chunks, leaves (steps, B, ...): the scan
    axis stays unsharded (consumed sequentially), the batch dim shards over
    the data axes when divisible - the chunked search streams each step's
    microbatch already distributed."""
    data = _one(_data_axes(mesh))
    dp = 1
    for a in _data_axes(mesh):
        dp *= mesh.shape[a]

    def leaf(s):
        if s is None:
            return NamedSharding(mesh, P())
        spec: list = [None] * len(s.shape)
        if len(s.shape) >= 2 and s.shape[1] % dp == 0:
            spec[1] = data
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, stacked_tree, is_leaf=lambda x: x is None)


def batch_sharding_tree(batch_tree: PyTree, mesh) -> PyTree:
    """Input batches: leading batch dim over the data axes, rest replicated."""
    data = _one(_data_axes(mesh))
    dp = 1
    for a in _data_axes(mesh):
        dp *= mesh.shape[a]

    def leaf(s):
        if s is None:
            return NamedSharding(mesh, P())
        b = _one(tuple(a for a in _data_axes(mesh)))
        spec = [b if s.shape and s.shape[0] % dp == 0 else None]
        spec += [None] * (len(s.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, batch_tree, is_leaf=lambda x: x is None)


def cache_sharding(cache_tree: PyTree, mesh) -> PyTree:
    """Decode KV caches, leaves (layers, B, capacity, ...).

    * layers axis: never sharded (scanned over),
    * B > 1: batch over the data axes, capacity over "model" (decode
      attention reduces over capacity with a partial softmax - GSPMD lowers
      it to a tiny all-reduce, no KV all-gather),
    * B == 1 (long-context): capacity over every divisible mesh axis.
    """
    data = _data_axes(mesh)
    dp = 1
    for a in data:
        dp *= mesh.shape[a]

    def leaf(s):
        if s is None:
            return NamedSharding(mesh, P())
        shape = s.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 3:
            B, C = shape[1], shape[2]
            if B > 1 and B % dp == 0:
                spec[1] = _one(data)
                if C % mesh.shape["model"] == 0:
                    spec[2] = "model"
            else:
                axes = tuple(a for a in data + ("model",)
                             if C % mesh.shape[a] == 0)
                # nested-tuple product divisibility
                n = 1
                keep = []
                for a in axes:
                    if C % (n * mesh.shape[a]) == 0:
                        keep.append(a)
                        n *= mesh.shape[a]
                if keep:
                    spec[2] = keep[0] if len(keep) == 1 else tuple(keep)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, cache_tree, is_leaf=lambda x: x is None)
