"""Sharding derivation: params / batch / KV-cache NamedSharding trees.

Specs are derived from the logical-axis annotations the model emits
(``models.model.param_axes``) through a :class:`~repro.dist.axes.ShardingRules`
mapping, with a per-dimension divisibility fallback (a dim that the mapped
mesh axes do not divide is replicated instead of erroring).

Compressed leaves (``sparse.formats.SparseTensor`` / ``BitMask``) shard too,
and the K (contraction) dim is FIRST-CLASS: a SparseTensor standing in for
a dense (K, N) kernel inherits the dense kernel's logical axes, and its K
sharding is decided once for the *leaf* - both components shard K iff the
shard-local slices stay kernel-executable, i.e. K % (8 * devices) == 0 for
2-bit-packed planes (whole index bytes per shard) resp. K % (4 * devices)
== 0 for int8 planes (whole 2:4 groups).  A leaf that cannot honor its K
rule replicates BOTH components along K and says so loudly
(``obs.log(warn=...)`` with the leaf path and axis) instead of the old
silent per-component divisibility fallback, which could leave ``vals``
K-sharded with a replicated ``idx`` - a layout no kernel executes.
K-shardable leaves additionally get a static ``shard`` tag
(:func:`tag_compressed`) that routes dispatch through the shard-mapped
kernels in ``kernels/shard.py`` (explicit K-partial accumulation).  Expert-
banked leaves ((E, K, N) per layer step, possibly under a leading "layers"
scan axis) carry the expert dim through unchanged.  BitMask bits are a
flat byte buffer with no meaningful axis: replicated.

``REPRO_FORCE_REPLICATED=1`` forces the replicated-K fallback everywhere
(no tags stamped, specs keep K unsharded) - the escape hatch for bisecting
mesh/collective bugs.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import keystr, tree_map_with_path

from repro import obs
from repro.dist.axes import ShardingRules, make_rules, spec_for_shape
from repro.sparse.formats import BitMask, SparseTensor

PyTree = Any


def make_production_rules(mesh, *, seq_shard_kv: Any = False,
                          seq_parallel: bool = False) -> ShardingRules:
    """Rules for the production mesh (pod/data FSDP + model TP)."""
    return make_rules(mesh, seq_parallel=seq_parallel,
                      seq_shard_kv=seq_shard_kv)


def _data_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _one(axes):
    return axes[0] if isinstance(axes, tuple) and len(axes) == 1 else axes


def _axes_size(mesh, entry) -> int:
    if entry is None:
        return 1
    n = 1
    for a in ((entry,) if isinstance(entry, str) else tuple(entry)):
        n *= mesh.shape[a]
    return n


def _site_for(path: str) -> str:
    """Projection-group label for collective accounting, from the leaf path."""
    if "['moe']" in path:
        return "moe"
    if "['attn']" in path:
        return "attn"
    if "['mlp']" in path or "['shared']" in path:
        return "mlp"
    return "dense"


def sparse_component_layout(axes_str: str | None, st: SparseTensor,
                            rules: ShardingRules, *, path: str = "",
                            quiet: bool = False):
    """One compressed leaf -> (vals_spec, idx_spec, shard_tag).

    The single source of the K-sharding decision, shared by
    :func:`sparse_leaf_sharding` (the NamedSharding tree) and
    :func:`tag_compressed` (the dispatch tag) so placement and execution can
    never disagree.  K shards iff ``K % (group * devices) == 0`` with group
    8 (2-bit-packed planes: whole index bytes per shard; a byte-padded
    plane has K % 8 != 0 and never qualifies) resp. 4 (int8 planes: whole
    2:4 groups per shard); otherwise BOTH components replicate K and a
    structured warning names the leaf and axis (suppressed with ``quiet``,
    and entirely under ``REPRO_FORCE_REPLICATED``).  Leading dims (layers /
    experts) and N keep the dense per-dim divisibility fallback.  The tag
    is ``(site, *entries)`` over the *executed* dims (leading "layers"
    stripped - lax.scan slices it away before dispatch) and is None unless
    K actually shards.
    """
    from repro.kernels.shard import replicated_forced
    mesh = rules.mesh
    if axes_str is None:
        return P(), P(), None
    names = axes_str.split("|")
    shape = st.shape
    dense_spec = tuple(rules.spec(names))
    entries = list(dense_spec) + [None] * (len(shape) - len(dense_spec))
    lead = []
    for i, e in enumerate(entries[:-2]):
        sz = _axes_size(mesh, e)
        lead.append(e if sz <= 1 or shape[i] % sz == 0 else None)
    K, N = shape[-2], shape[-1]
    k_e, n_e = entries[-2], entries[-1]
    n_keep = n_e if N % _axes_size(mesh, n_e) == 0 else None
    d = _axes_size(mesh, k_e)
    forced = replicated_forced()
    group = 8 if st.idx_bits == 2 else 4
    k_tag = None
    spec_k = k_e
    if k_e is not None and d > 1:
        if not forced and K % (group * d) == 0:
            k_tag = k_e
        else:
            spec_k = None
            if not quiet and not forced:
                obs.log(
                    "dist.sparse_k_replicated", level="warn",
                    leaf=path or axes_str, axis=str(k_e), dim=K,
                    devices=d, idx_bits=st.idx_bits,
                    warn=(f"compressed leaf {path or axes_str}: K={K} "
                          f"cannot shard over mesh axis {k_e!r} "
                          f"({d} devices, needs K % {group * d} == 0 for "
                          f"{'2-bit-packed' if group == 8 else 'int8'} "
                          f"index planes); vals AND idx replicate along K"))
    vals_spec = P(*lead, spec_k, n_keep)
    idx_spec = P(*lead, spec_k, n_keep)
    tag = None
    if k_tag is not None:
        exec_entries = lead[1:] if names[0] == "layers" else lead
        tag = (_site_for(path),
               *(e if _axes_size(mesh, e) > 1 else None
                 for e in exec_entries),
               k_tag,
               n_keep if _axes_size(mesh, n_keep) > 1 else None)
    return vals_spec, idx_spec, tag


def sparse_leaf_sharding(axes_str: str | None, st: SparseTensor,
                         rules: ShardingRules,
                         path: str = "") -> SparseTensor:
    """Sharding for one SparseTensor leaf, as a matching pytree node.

    Both components reuse the dense kernel's logical axis names; the K dim
    is decided leaf-wise by :func:`sparse_component_layout` (all-or-nothing
    across vals/idx, loud on fallback).  Returned as a SparseTensor of
    NamedShardings - carrying the *input* leaf's static aux (idx_bits and
    any shard tag) verbatim, so the tree is a valid device_put /
    in_shardings target whether or not the params were tagged first.
    """
    vals_spec, idx_spec, _ = sparse_component_layout(axes_str, st, rules,
                                                     path=path)
    return SparseTensor(NamedSharding(rules.mesh, vals_spec),
                        NamedSharding(rules.mesh, idx_spec),
                        idx_bits=st.idx_bits, shard=st.shard)


def tag_compressed(axes_tree: PyTree, params: PyTree,
                   rules: ShardingRules) -> PyTree:
    """Stamp every SparseTensor leaf with its tensor-parallel dispatch tag.

    The tag ((site, *mesh-axis entries), static aux - see
    ``SparseTensor.shard``) is what ``sparse.apply`` dispatches on at trace
    time: K-sharded leaves route through the shard-mapped kernels with
    explicit psum accumulation.  Quiet (no fallback warnings): callers pair
    this with :func:`params_sharding`, which is the loud pass.  Every other
    leaf passes through untouched (by identity).
    """
    def leaf(kp, axes_str, w):
        if isinstance(w, SparseTensor):
            _, _, tag = sparse_component_layout(
                axes_str, w, rules, path=keystr(kp), quiet=True)
            return w.with_shard(tag) if tag != w.shard else w
        return w

    return tree_map_with_path(leaf, axes_tree, params,
                              is_leaf=lambda x: x is None)


def params_sharding(axes_tree: PyTree, shapes_tree: PyTree,
                    rules: ShardingRules) -> PyTree:
    """'|'-joined logical-axis strings + shapes -> NamedSharding tree.

    ``shapes_tree`` may be ``models.model.param_shapes`` output or an actual
    params tree; SparseTensor leaves (compressed kernels) get component-wise
    specs via :func:`sparse_leaf_sharding`, BitMask leaves replicate.
    """
    def leaf(kp, axes_str, shape_like):
        if isinstance(shape_like, SparseTensor):
            return sparse_leaf_sharding(axes_str, shape_like, rules,
                                        path=keystr(kp))
        if isinstance(shape_like, BitMask):
            return BitMask(NamedSharding(rules.mesh, P()), shape_like.shape)
        if axes_str is None or shape_like is None:
            return NamedSharding(rules.mesh, P())
        names = axes_str.split("|")
        spec = spec_for_shape(rules, names, shape_like.shape)
        return NamedSharding(rules.mesh, spec)

    return tree_map_with_path(leaf, axes_tree, shapes_tree,
                              is_leaf=lambda x: x is None)


def search_state_sharding(axes_tree: PyTree, state, rules: ShardingRules):
    """NamedSharding tree for a ``core.mirror.SearchState`` on the mesh.

    The trainable copy W inherits the dense parameter rules verbatim (it IS
    the params tree in fp32); Gamma and V are prunable-leaf shadows of W, so
    each non-None leaf reuses its kernel's sharding - the three full-size
    fp32 trees of the mirror-descent search live distributed instead of
    replicated.  step/rng replicate.  The result pairs leaf-for-leaf with
    the state for ``jax.device_put`` / jit in_shardings.
    """
    from repro.core.mirror import SearchState
    base = params_sharding(axes_tree, state.W, rules)
    rep = NamedSharding(rules.mesh, P())

    def gv(g, sh):
        return None if g is None else sh

    return SearchState(
        W=base,
        Gamma=jax.tree.map(gv, state.Gamma, base,
                           is_leaf=lambda x: x is None),
        V=jax.tree.map(gv, state.V, base, is_leaf=lambda x: x is None),
        step=rep, rng=rep)


def stacked_batch_sharding(stacked_tree: PyTree, mesh) -> PyTree:
    """Scan-stacked calibration chunks, leaves (steps, B, ...): the scan
    axis stays unsharded (consumed sequentially), the batch dim shards over
    the data axes when divisible - the chunked search streams each step's
    microbatch already distributed."""
    data = _one(_data_axes(mesh))
    dp = 1
    for a in _data_axes(mesh):
        dp *= mesh.shape[a]

    def leaf(s):
        if s is None:
            return NamedSharding(mesh, P())
        spec: list = [None] * len(s.shape)
        if len(s.shape) >= 2 and s.shape[1] % dp == 0:
            spec[1] = data
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, stacked_tree, is_leaf=lambda x: x is None)


def batch_sharding_tree(batch_tree: PyTree, mesh) -> PyTree:
    """Input batches: leading batch dim over the data axes, rest replicated."""
    data = _one(_data_axes(mesh))
    dp = 1
    for a in _data_axes(mesh):
        dp *= mesh.shape[a]

    def leaf(s):
        if s is None:
            return NamedSharding(mesh, P())
        b = _one(tuple(a for a in _data_axes(mesh)))
        spec = [b if s.shape and s.shape[0] % dp == 0 else None]
        spec += [None] * (len(s.shape) - 1)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, batch_tree, is_leaf=lambda x: x is None)


def cache_sharding(cache_tree: PyTree, mesh) -> PyTree:
    """Decode KV caches, leaves (layers, B, capacity, ...).

    * layers axis: never sharded (scanned over),
    * B > 1: batch over the data axes, capacity over "model" (decode
      attention reduces over capacity with a partial softmax - GSPMD lowers
      it to a tiny all-reduce, no KV all-gather),
    * B == 1 (long-context): capacity over every divisible mesh axis.
    """
    data = _data_axes(mesh)
    dp = 1
    for a in data:
        dp *= mesh.shape[a]

    def leaf(s):
        if s is None:
            return NamedSharding(mesh, P())
        shape = s.shape
        spec: list = [None] * len(shape)
        if len(shape) >= 3:
            B, C = shape[1], shape[2]
            if B > 1 and B % dp == 0:
                spec[1] = _one(data)
                if C % mesh.shape["model"] == 0:
                    spec[2] = "model"
            else:
                axes = tuple(a for a in data + ("model",)
                             if C % mesh.shape[a] == 0)
                # nested-tuple product divisibility
                n = 1
                keep = []
                for a in axes:
                    if C % (n * mesh.shape[a]) == 0:
                        keep.append(a)
                        n *= mesh.shape[a]
                if keep:
                    spec[2] = keep[0] if len(keep) == 1 else tuple(keep)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, cache_tree, is_leaf=lambda x: x is None)
