"""Distribution layer: logical-axis sharding rules and spec derivation."""
