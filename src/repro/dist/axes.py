"""Logical axis names -> mesh axes.

Model code annotates every parameter and activation dimension with a
*logical* name ("embed", "mlp", "batch", ...).  A :class:`ShardingRules`
maps logical names onto mesh axes; :func:`constrain` applies the mapping as
a ``with_sharding_constraint`` whenever rules are installed (``use_rules``)
and is the identity otherwise, so the same model code runs on one CPU
device in tests and under the production mesh unchanged.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_local = threading.local()


@dataclasses.dataclass
class ShardingRules:
    """mesh + {logical axis name: mesh axis | tuple of mesh axes | None}."""
    mesh: Any
    rules: dict[str, Any]

    def spec(self, names) -> P:
        """PartitionSpec for a sequence of logical names.

        A mesh axis may appear at most once in a spec; later dims that map
        onto an already-used mesh axis fall back to None (replicated).
        """
        used: set[str] = set()
        out = []
        for name in names:
            axes = self.rules.get(name) if name else None
            if axes is None:
                out.append(None)
                continue
            if isinstance(axes, str):
                axes = (axes,)
            axes = tuple(a for a in axes
                         if a in self.mesh.axis_names and a not in used)
            used.update(axes)
            if not axes:
                out.append(None)
            elif len(axes) == 1:
                out.append(axes[0])
            else:
                out.append(axes)
        return P(*out)


def make_rules(mesh, *, seq_parallel: bool = False,
               seq_shard_kv: Any = False) -> ShardingRules:
    """Default logical->mesh mapping (FSDP over 'data', TP over 'model').

    seq_parallel: shard activation seq ("act_seq") over the TP axis
    (Megatron SP).  seq_shard_kv: False | "model" | "all" - how decode KV
    caches shard their capacity dim (see sharding.cache_sharding).
    """
    multi_pod = "pod" in mesh.axis_names
    data: Any = ("pod", "data") if multi_pod else "data"
    if seq_shard_kv == "all":
        kv_seq: Any = (("pod", "data", "model") if multi_pod
                       else ("data", "model"))
    elif seq_shard_kv:
        kv_seq = "model"
    else:
        kv_seq = None
    rules = {
        # parameters
        "embed": data, "mlp": "model", "qkv": "model",
        "vocab": "model", "experts": "model", "ssm": "model",
        "embed_act": None, "layers": None,
        # activations
        "batch": data, "seq": None, "heads": "model",
        "kv_heads": "model",
        "act_seq": "model" if seq_parallel else None,
        "kv_seq": kv_seq,
    }
    return ShardingRules(mesh=mesh, rules=rules)


def current_rules() -> ShardingRules | None:
    return getattr(_local, "rules", None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules):
    prev = current_rules()
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def _divisible(shape, spec, mesh) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        ax = (axes,) if isinstance(axes, str) else tuple(axes)
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        out.append(axes if dim % n == 0 else None)
    return P(*out)


def spec_for_shape(rules: ShardingRules, names, shape) -> P:
    """Divisibility-checked PartitionSpec for logical ``names`` on ``shape``.

    The shared primitive under both dense-leaf and compressed-leaf sharding
    derivation: compressed components (vals K/2, idx K/8 of the same dense
    kernel) reuse the dense kernel's logical names and only the per-dim
    divisibility check differs.
    """
    return _divisible(shape, rules.spec(names), rules.mesh)


def constrain(x: jax.Array, *names) -> jax.Array:
    """with_sharding_constraint under installed rules; identity otherwise."""
    rules = current_rules()
    if rules is None:
        return x
    spec = spec_for_shape(rules, names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec))
