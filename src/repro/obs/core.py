"""Flight-recorder core: global switch, spans, timers, structured logs.

Everything funnels through one process-global :class:`_ObsState`:

* ``span(name, **attrs)`` - timing context for a hot-path unit of work.
  When telemetry is DISABLED it returns a single shared no-op object
  (``obs.span(a) is obs.span(b)``): no allocation, no clock read, no event
  - the instrumented code path is byte-for-byte the uninstrumented one
  plus a bool check.  When enabled, the span records wall + monotonic
  time, its parent (thread-local stack -> nested parenting), and emits a
  JSONL event at exit.  ``sp.fence(x)`` registers a jax pytree to
  ``block_until_ready`` before the exit clock read, so async-dispatched
  device work is charged to the span that launched it instead of whoever
  syncs next.

* ``timer(name, **attrs)`` - like ``span`` but ALWAYS measures (and still
  only emits when enabled).  For stage timings that feed artifacts/meta
  regardless of telemetry (e.g. ``launch.calibrate`` stats/search
  seconds): the fencing fix must hold even with the recorder off.

* ``log(event, **fields)`` - structured log record into the same JSONL
  stream as spans.  ``warn="..."`` additionally raises a stdlib warning
  (always, enabled or not), so warning semantics - pytest.warns,
  -W error - are preserved while the structured copy lands in the trace.

Metric writes (``inc`` / ``set_gauge`` / ``observe``) delegate to
``registry.Registry`` and are no-ops while disabled.
"""
from __future__ import annotations

import itertools
import threading
import time
import warnings
from collections import deque
from typing import Any

from repro.obs.export import JsonlSink
from repro.obs.registry import Registry

try:  # fencing needs jax; the recorder itself must not
    import jax as _jax
except ImportError:  # pragma: no cover - jax is present in this repo
    _jax = None


class _ObsState:
    def __init__(self):
        self.enabled = False
        self.registry = Registry()
        self.sink: JsonlSink | None = None
        # in-memory tail of the event stream (tests, summaries) - kept even
        # when a JSONL sink is attached
        self.events: deque[dict] = deque(maxlen=4096)
        self.span_ids = itertools.count(1)


STATE = _ObsState()
_tls = threading.local()


def _span_stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def enabled() -> bool:
    return STATE.enabled


def configure(*, enabled: bool = True, trace_dir=None,
              buffer_events: int = 4096) -> None:
    """Turn the recorder on (and optionally attach a JSONL trace sink).

    Metrics and buffered events accumulated so far are kept; use
    :func:`reset` for a clean slate.
    """
    STATE.enabled = enabled
    STATE.events = deque(STATE.events, maxlen=buffer_events)
    if trace_dir is not None:
        if STATE.sink is not None and \
                str(STATE.sink.dir) != str(trace_dir):
            STATE.sink.close()
            STATE.sink = None
        if STATE.sink is None:
            STATE.sink = JsonlSink(trace_dir)


def disable() -> None:
    STATE.enabled = False
    if STATE.sink is not None:
        STATE.sink.flush()


def reset() -> None:
    """Tests/benches: drop every metric, event, and the trace sink."""
    STATE.enabled = False
    STATE.registry.reset()
    STATE.events.clear()
    if STATE.sink is not None:
        STATE.sink.close()
        STATE.sink = None
    _span_stack().clear()


def flush() -> None:
    if STATE.sink is not None:
        STATE.sink.flush()


def trace_path():
    return None if STATE.sink is None else STATE.sink.path


def emit(event: dict) -> None:
    """Stamp + route one event (buffer always, sink when attached)."""
    event.setdefault("ts", time.time())
    STATE.events.append(event)
    if STATE.sink is not None:
        STATE.sink.write(event)


def events() -> list[dict]:
    return list(STATE.events)


# -- spans -------------------------------------------------------------------


class Span:
    """Measuring span; emits a JSONL event at exit when the recorder is on."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "depth",
                 "seconds", "_t0", "_wall0", "_fence")

    def __init__(self, name: str, fence=None, attrs: dict | None = None):
        self.name = name
        self.attrs = attrs or {}
        self.span_id = next(STATE.span_ids)
        self.parent_id = None
        self.depth = 0
        self.seconds: float | None = None
        self._fence = fence

    def fence(self, tree) -> None:
        """Pytree to block_until_ready before the exit clock read."""
        self._fence = tree

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def __enter__(self) -> "Span":
        stack = _span_stack()
        if stack:
            self.parent_id = stack[-1].span_id
            self.depth = len(stack)
        stack.append(self)
        self._wall0 = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if self._fence is not None and _jax is not None:
            _jax.block_until_ready(self._fence)
        self.seconds = time.perf_counter() - self._t0
        stack = _span_stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:  # exited out of order: drop self, keep others
            stack.remove(self)
        if STATE.enabled:
            emit({"ts": self._wall0, "kind": "span", "name": self.name,
                  "dur_ms": self.seconds * 1e3, "span_id": self.span_id,
                  "parent_id": self.parent_id, "depth": self.depth,
                  "ok": exc_type is None,
                  **({"attrs": self.attrs} if self.attrs else {})})
        return False


class _NoopSpan:
    """Shared disabled-path span: every method is a constant no-op."""

    __slots__ = ()
    seconds = None
    span_id = parent_id = None
    depth = 0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def fence(self, tree):
        pass

    def set(self, **attrs):
        pass


NOOP_SPAN = _NoopSpan()


def span(name: str, fence=None, **attrs):
    """Hot-path span: a real measuring span when enabled, THE no-op
    singleton otherwise."""
    if not STATE.enabled:
        return NOOP_SPAN
    return Span(name, fence, attrs)


def timer(name: str, fence=None, **attrs) -> Span:
    """Always-measuring span (stage timings that outlive the recorder)."""
    return Span(name, fence, attrs)


# -- structured log ----------------------------------------------------------


def log(event: str, *, level: str = "info", warn: str | None = None,
        warn_category: type = UserWarning, **fields) -> None:
    """One structured record into the trace stream.

    ``warn=`` additionally raises ``warnings.warn(warn, warn_category)``
    whether or not the recorder is enabled - callers that used to call
    ``warnings.warn`` directly route here and keep their stdlib-warning
    contract (filters, pytest.warns) intact.
    """
    if STATE.enabled:
        emit({"kind": "log", "event": event, "level": level, **fields})
    if warn is not None:
        warnings.warn(warn, warn_category, stacklevel=3)


# -- metrics -----------------------------------------------------------------


def inc(name: str, value: float = 1.0, **labels) -> None:
    if STATE.enabled:
        STATE.registry.inc(name, value, labels)


def set_gauge(name: str, value: float, **labels) -> None:
    if STATE.enabled:
        STATE.registry.set_gauge(name, value, labels)


def observe(name: str, value: float, **labels) -> None:
    if STATE.enabled:
        STATE.registry.observe(name, value, labels)


def declare_hist(name: str, edges) -> None:
    STATE.registry.declare_hist(name, edges)


def counter_value(name: str, **labels) -> float:
    return STATE.registry.counter_value(name, labels)


def gauge_value(name: str, **labels) -> float | None:
    return STATE.registry.gauge_value(name, labels)


def percentile(name: str, q: float, **labels) -> float | None:
    return STATE.registry.percentile(name, q, labels)


def expose() -> str:
    """Prometheus-style text snapshot of the whole registry."""
    return STATE.registry.expose()


def summary() -> dict:
    """JSON-ready registry snapshot (merged into BENCH_*.json)."""
    return STATE.registry.summary()
