"""Flight recorder: unified tracing + metrics across calibration, serving,
and the fleet.

Three faces, one dependency-free package:

* **spans** - ``obs.span("prefill", slot=s)`` context managers with
  ``jax.block_until_ready`` fencing at exit (``sp.fence(outputs)``),
  thread-local nested parenting, and a shared no-op singleton on the
  disabled path (zero allocation, zero clock reads).
* **metrics** - a process-local registry of counters, gauges, and
  fixed-bucket histograms (``obs.inc`` / ``obs.set_gauge`` /
  ``obs.observe``; read back via ``obs.percentile`` / ``obs.summary``).
* **exporters** - a JSONL event log under ``--trace-dir``
  (``obs.configure(trace_dir=...)``), a Prometheus-style text snapshot
  via ``obs.expose()``, and ``obs.summary()`` merged into the
  ``BENCH_*.json`` artifacts.

Disabled (the default) every call is a cheap bool check; nothing is
recorded and no event is written, so the serving/calibration hot paths
run the exact uninstrumented dispatch sequence.  Enable with
``obs.configure()`` (optionally ``trace_dir=``), snapshot with
``obs.summary()`` / ``obs.expose()``, and wipe with ``obs.reset()``.
"""
from repro.obs.core import (NOOP_SPAN, Span, configure, counter_value,
                            declare_hist, disable, emit, enabled, events,
                            expose, flush, gauge_value, inc, log, observe,
                            percentile, reset, set_gauge, span, summary,
                            timer, trace_path)
from repro.obs.export import JsonlSink, read_jsonl
from repro.obs.registry import DEFAULT_MS_BUCKETS, Histogram, Registry

__all__ = [
    "NOOP_SPAN", "Span", "configure", "counter_value", "declare_hist",
    "disable", "emit", "enabled", "events", "expose", "flush",
    "gauge_value", "inc", "log", "observe", "percentile", "reset",
    "set_gauge", "span", "summary", "timer", "trace_path",
    "JsonlSink", "read_jsonl",
    "DEFAULT_MS_BUCKETS", "Histogram", "Registry",
]
