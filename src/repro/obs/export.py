"""Event exporters: JSONL trace sink + trace readback.

One event = one JSON object on one line.  Shared schema across every
producer (spans, structured logs, metric points):

  {"ts": <unix seconds, float>, "kind": "span" | "log", ...}

span events add  name, dur_ms, span_id, parent_id (or null), depth, attrs
log events add   event, level, plus arbitrary structured fields

Writes are line-buffered through one file handle; ``flush()`` pushes
buffered lines to disk (and runs automatically at interpreter exit), so a
crash loses at most the current buffer, never corrupts earlier lines.
"""
from __future__ import annotations

import atexit
import io
import json
import pathlib
import threading
from typing import Any, Iterator


def _default(o: Any):
    """Best-effort JSON for numpy/jax scalars and arrays."""
    item = getattr(o, "item", None)
    if callable(item) and getattr(o, "ndim", 1) == 0:
        return item()
    tolist = getattr(o, "tolist", None)
    if callable(tolist):
        return tolist()
    return repr(o)


class JsonlSink:
    """Append-only events.jsonl writer under a trace directory."""

    def __init__(self, trace_dir):
        self.dir = pathlib.Path(trace_dir)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.path = self.dir / "events.jsonl"
        self._fh: io.TextIOBase | None = None
        self._lock = threading.Lock()
        atexit.register(self.flush)

    def write(self, event: dict) -> None:
        line = json.dumps(event, default=_default)
        with self._lock:
            if self._fh is None:
                self._fh = open(self.path, "a", buffering=1024 * 64)
            self._fh.write(line + "\n")

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None


def read_jsonl(path) -> Iterator[dict]:
    """Yield events from a trace file (skips partially-written last line)."""
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                yield json.loads(line)
            except json.JSONDecodeError:
                return  # partial trailing line (writer mid-flush): stop
