"""Process-local metrics registry: counters, gauges, fixed-bucket histograms.

Pure Python + stdlib - no jax, no numpy.  Metrics are keyed by
``(name, sorted label items)``; a histogram's buckets are fixed at first
use (declare non-default edges up front with :func:`Registry.declare_hist`),
so ``observe`` is a bisect + two adds on the hot path.

Bucket semantics follow the Prometheus ``le`` convention: bucket ``i``
counts observations ``v <= edges[i]`` (and ``> edges[i-1]``); one implicit
overflow bucket catches everything above the last edge.  Percentiles are
estimated by linear interpolation inside the winning bucket, clamped to the
observed min/max so tiny sample counts never extrapolate past real data.
"""
from __future__ import annotations

import bisect
import threading
from typing import Any, Iterable, Mapping

# default histogram edges, in milliseconds: spans sub-0.1ms python overhead
# through multi-second calibration stages
DEFAULT_MS_BUCKETS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                      100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0)

MetricKey = tuple[str, tuple[tuple[str, Any], ...]]


def metric_key(name: str, labels: Mapping[str, Any] | None) -> MetricKey:
    if not labels:
        return (name, ())
    return (name, tuple(sorted((k, v) for k, v in labels.items())))


def _render_labels(items: Iterable[tuple[str, Any]]) -> str:
    body = ",".join(f'{k}="{v}"' for k, v in items)
    return "{" + body + "}" if body else ""


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


class Histogram:
    """Fixed-bucket histogram with exact sum/count/min/max."""

    __slots__ = ("edges", "counts", "sum", "count", "min", "max")

    def __init__(self, edges: Iterable[float] = DEFAULT_MS_BUCKETS):
        self.edges = tuple(sorted(float(e) for e in edges))
        assert self.edges, "histogram needs at least one bucket edge"
        self.counts = [0] * (len(self.edges) + 1)  # +1: overflow bucket
        self.sum = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.edges, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def percentile(self, q: float) -> float | None:
        """Bucket-interpolated q-th percentile (q in [0, 100])."""
        if self.count == 0:
            return None
        target = (q / 100.0) * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= target:
                lo = self.edges[i - 1] if i > 0 else min(self.min, self.edges[0])
                hi = self.edges[i] if i < len(self.edges) else self.max
                frac = (target - seen) / c
                est = lo + (hi - lo) * max(0.0, min(1.0, frac))
                return max(self.min, min(self.max, est))
            seen += c
        return self.max

    def snapshot(self) -> dict:
        return {"count": self.count, "sum": self.sum,
                "min": self.min if self.count else None,
                "max": self.max if self.count else None,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                "buckets": {("+Inf" if i == len(self.edges)
                             else repr(self.edges[i])): c
                            for i, c in enumerate(self.counts)}}


class Registry:
    """Thread-safe process-local metric store."""

    def __init__(self):
        self._lock = threading.Lock()
        self.counters: dict[MetricKey, float] = {}
        self.gauges: dict[MetricKey, float] = {}
        self.hists: dict[MetricKey, Histogram] = {}
        self._hist_edges: dict[str, tuple[float, ...]] = {}

    # -- writes --------------------------------------------------------------

    def inc(self, name: str, value: float = 1.0, labels=None) -> None:
        k = metric_key(name, labels)
        with self._lock:
            self.counters[k] = self.counters.get(k, 0.0) + value

    def set_gauge(self, name: str, value: float, labels=None) -> None:
        self.gauges[metric_key(name, labels)] = float(value)

    def declare_hist(self, name: str, edges: Iterable[float]) -> None:
        """Pin non-default bucket edges for every series of ``name``.

        Must run before the first ``observe`` of that name (an existing
        series keeps its edges - changing them mid-flight would corrupt
        the counts).
        """
        self._hist_edges[name] = tuple(sorted(float(e) for e in edges))

    def observe(self, name: str, value: float, labels=None) -> None:
        k = metric_key(name, labels)
        h = self.hists.get(k)
        if h is None:
            with self._lock:
                h = self.hists.setdefault(
                    k, Histogram(self._hist_edges.get(name,
                                                      DEFAULT_MS_BUCKETS)))
        h.observe(value)

    # -- reads ---------------------------------------------------------------

    def counter_value(self, name: str, labels=None) -> float:
        return self.counters.get(metric_key(name, labels), 0.0)

    def gauge_value(self, name: str, labels=None) -> float | None:
        return self.gauges.get(metric_key(name, labels))

    def hist(self, name: str, labels=None) -> Histogram | None:
        return self.hists.get(metric_key(name, labels))

    def percentile(self, name: str, q: float, labels=None) -> float | None:
        h = self.hist(name, labels)
        return None if h is None else h.percentile(q)

    def summary(self) -> dict:
        """JSON-ready snapshot (merged into BENCH_*.json artifacts)."""
        def render(d):
            return {n + _render_labels(items): v
                    for (n, items), v in sorted(d.items())}
        return {"counters": render(self.counters),
                "gauges": render(self.gauges),
                "histograms": {n + _render_labels(items): h.snapshot()
                               for (n, items), h in sorted(self.hists.items())}}

    def expose(self) -> str:
        """Prometheus text-exposition snapshot of every metric."""
        lines: list[str] = []
        for (n, items), v in sorted(self.counters.items()):
            lines.append(f"# TYPE {_prom_name(n)} counter")
            lines.append(f"{_prom_name(n)}{_render_labels(items)} {v:g}")
        for (n, items), v in sorted(self.gauges.items()):
            lines.append(f"# TYPE {_prom_name(n)} gauge")
            lines.append(f"{_prom_name(n)}{_render_labels(items)} {v:g}")
        for (n, items), h in sorted(self.hists.items()):
            pn = _prom_name(n)
            lines.append(f"# TYPE {pn} histogram")
            cum = 0
            for i, c in enumerate(h.counts):
                cum += c
                le = "+Inf" if i == len(h.edges) else f"{h.edges[i]:g}"
                lab = _render_labels(tuple(items) + (("le", le),))
                lines.append(f"{pn}_bucket{lab} {cum}")
            lab = _render_labels(items)
            lines.append(f"{pn}_sum{lab} {h.sum:g}")
            lines.append(f"{pn}_count{lab} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.hists.clear()
            self._hist_edges.clear()
