"""Pack/unpack converters: mask pytrees (core/masks.py) -> compressed formats.

The mask, not a top-k recomputation, is the source of truth: UniPruning's
export ties are broken by the dual V (see ``mirror.export_masks``), so
re-deriving positions from |W| here could disagree with the exported mask.
Packing from the mask guarantees ``to_dense() == W * mask`` bit-exactly.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.sparse.formats import BitMask, SparseTensor, _pack_idx2


def nm_positions(mask: jax.Array, *, m: int = 4, n: int = 2) -> jax.Array:
    """2:4 keep-mask (..., K, N) -> kept in-group positions (..., K/2, N) int8.

    Requires exactly ``n`` kept entries per contiguous group of ``m`` along
    the second-to-last dim (what ``masks.nm_masks`` produces); positions come
    out ascending within each group, matching the kernel layout.
    """
    *lead, k, cols = mask.shape
    assert k % m == 0, (k, m)
    g = mask.reshape(*lead, k // m, m, cols)
    r = jnp.arange(m, dtype=jnp.int8)[:, None]
    # kept entries sort to the front (their position), dropped sort to m
    key = jnp.where(g, r, jnp.int8(m))
    pos = jnp.sort(key, axis=-2)[..., :n, :]
    return pos.reshape(*lead, (k // m) * n, cols).astype(jnp.int8)


def pack_nm(w: jax.Array, mask: jax.Array, *, idx_bits: int = 8,
            dtype=None) -> SparseTensor:
    """Dense weight + 2:4 keep-mask -> SparseTensor.

    dtype: storage dtype for the surviving values (e.g. the serving compute
    dtype); default keeps ``w.dtype``.  ``idx_bits=2`` packs positions
    4-per-byte; when K % 8 != 0 the packed plane is zero-padded to the byte
    boundary (``SparseTensor.unpacked_idx`` slices the pad back off).
    """
    *lead, k, cols = w.shape
    idx = nm_positions(mask)
    g = w.reshape(*lead, k // 4, 4, cols)
    gi = idx.reshape(*lead, k // 4, 2, cols).astype(jnp.int32)
    vals = jnp.take_along_axis(g, gi, axis=-2).reshape(*lead, k // 2, cols)
    if dtype is not None:
        vals = vals.astype(dtype)
    if idx_bits == 2:
        return SparseTensor(vals, _pack_idx2(idx), idx_bits=2)
    return SparseTensor(vals, idx, idx_bits=8)


def pack_mask_tree(masks: Any) -> Any:
    """Boolean mask pytree -> BitMask pytree (None leaves stay None)."""
    return jax.tree.map(
        lambda m: None if m is None else BitMask.pack(m),
        masks, is_leaf=lambda x: x is None)


def unpack_mask_tree(packed: Any) -> Any:
    """BitMask pytree -> boolean mask pytree (None leaves stay None)."""
    return jax.tree.map(
        lambda b: b.to_dense() if isinstance(b, BitMask) else None,
        packed, is_leaf=lambda x: x is None or isinstance(x, BitMask))
