"""Sparse inference runtime: compressed formats, mask bank, execution.

Three layers close the loop from UniPruning calibration to serving:

* **Formats** (``formats``, ``pack``) - compressed weight layouts as pytree
  nodes:

  - ``SparseTensor``: the 2:4 layout ``kernels/nm_spmm.py`` executes.
    For a dense kernel (..., K, N) pruned 2:4 along K it stores
    ``vals`` (..., K/2, N) in the serving compute dtype plus in-group
    positions, either int8 (``idx_bits=8``, (..., K/2, N)) or 2-bit-packed
    uint8 (``idx_bits=2``, (..., ceil(K/8), N), the default - 4 positions
    per byte, zero-padded to the byte boundary when K % 8 != 0).  bf16 HBM
    bytes: 9/16 of dense (2-bit) / 3/4 (int8).  The layout tag
    (``LAYOUT_PACKED2``/``LAYOUT_INT8``) names the storage;
    ``kernel_layout`` names what the kernel streams - packed planes with
    K % 8 == 0 go to the Pallas kernel as stored and unpack in VMEM after
    the HBM->VMEM copy.  Only ``idx_bits`` is static, so ``lax.scan``
    slices stacked layer kernels through it transparently.
  - ``BitMask``: unstructured keep-masks packed 8-per-byte for artifact
    storage; unpacks to the boolean pytrees ``core/masks.py`` produces.

* **Mask bank** (``bank``) - persistence of post-calibration state so one
  search serves arbitrary budgets across process restarts.  Artifact schema
  (``unipruning.mask-bank/v1``, written by ``ckpt.save_artifact``): a
  directory with ``manifest.json`` + one ``leaf_NNNNNN.npy`` per non-None
  leaf, committed atomically via tmp-dir rename.  The manifest carries
  ``metadata = {schema, format_version, arch, smoke, pcfg:
  asdict(PruneConfig), steps_run, checksum}`` and the saved tree is
  ``{"Gamma": <saliency>, "V": <dual>, "stats": <activation norms>}``, each
  in the model's params structure (None on non-prunable leaves).  The
  crc32 ``checksum`` over every leaf (format_version >= 2) makes a
  truncated or corrupt artifact fail loudly at load.
  ``MaskBank.load(dir).masks_at(sparsity | nm)`` re-thresholds via
  ``mirror.export_masks`` - bit-identical to an in-process export, no
  re-search.

* **Execution** (``apply``) - ``sparsify_params`` swaps 2:4-maskable
  kernels for ``SparseTensor`` leaves; ``models.common.dense`` dispatches
  on leaf type so those kernels route through ``nm_matmul`` (Pallas on TPU,
  interpret mode on CPU) while dense leaves keep the existing path.
  ``ServeEngine`` / ``launch.serve`` consume it via
  ``--sparse-artifact``/``--sparsity``.
"""
from repro.sparse.formats import BitMask, SparseTensor  # noqa: F401
from repro.sparse.pack import pack_mask_tree, pack_nm, unpack_mask_tree  # noqa: F401
from repro.sparse.bank import MaskBank  # noqa: F401
from repro.sparse.apply import (  # noqa: F401
    compressed_report, shared_leaves, sparse_dense, sparse_dense2,
    sparse_moe_dense, sparsify_params)
