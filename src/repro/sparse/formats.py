"""Compressed weight formats (pytree nodes).

``SparseTensor``: the 2:4 layout ``kernels/nm_spmm.py`` executes - per group
of 4 along the reduction dim, the two surviving values (``vals``,
(..., K/2, N), compute dtype) and their in-group positions.  Leading dims
pass through untouched: a scan-stacked kernel keeps its "layers" axis and a
MoE expert bank (E, K, N) keeps its expert axis (executed by the
expert-grid ``nm_matmul_expert``), stacked banks carry both.  Positions are
stored either as int8 (``idx_bits=8``: (..., K/2, N)) or packed 4-per-byte
(``idx_bits=2``: (..., ceil(K/8), N) uint8, position rows zero-padded to
the byte boundary when K % 8 != 0), moving 9/16 of the dense-bf16 HBM
bytes.  The *layout tag* (:data:`LAYOUT_INT8` / :data:`LAYOUT_PACKED2`)
names the storage; ``kernel_layout`` names what the matmul kernel streams:
packed storage whose K divides 8 is consumed 2-bit-native by the Pallas
kernel (unpacked HBM->VMEM inside the kernel), anything else falls back to
an int8 index plane unpacked at dispatch.  Registered as a pytree node
whose only static data is ``idx_bits``, so ``lax.scan`` over stacked layer
parameters slices the leading layer axis of ``vals``/``idx`` exactly like a
dense kernel leaf.

``BitMask``: 8-masks-per-byte storage format for unstructured keep-masks
(bank artifacts); unpacks back to the boolean pytrees ``core/masks.py``
produces.  Not executed - unstructured serving stays masked-dense.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# Layout tags and the 2-bit unpack are owned by the kernel module that
# dispatches on / streams them (single source of truth for the bit layout):
#   LAYOUT_INT8:    idx (..., K/2, N) int8, one position per byte
#   LAYOUT_PACKED2: idx (..., ceil(K/8), N) uint8, 4 per byte
from repro.kernels.nm_spmm import (  # noqa: F401
    LAYOUT_INT8, LAYOUT_PACKED2, unpack_idx2 as _unpack_idx2)


def _pack_idx2(idx: jax.Array) -> jax.Array:
    """(..., K/2, N) int8 (values 0..3) -> (..., ceil(K/8), N) uint8.

    Position rows are zero-padded to the byte boundary when K % 8 != 0, so
    any K % 4 == 0 kernel packs; the pad codes decode to position 0 and are
    sliced off again by ``SparseTensor.unpacked_idx``.
    """
    *lead, rows, n = idx.shape
    pad = -rows % 4
    if pad:
        idx = jnp.concatenate(
            [idx, jnp.zeros((*lead, pad, n), idx.dtype)], axis=-2)
        rows += pad
    g = idx.astype(jnp.uint8).reshape(*lead, rows // 4, 4, n)
    out = jnp.zeros(g.shape[:-2] + (n,), jnp.uint8)
    for j in range(4):
        out = out | (g[..., j, :] << (2 * j))
    return out


@jax.tree_util.register_pytree_node_class
class SparseTensor:
    """2:4-compressed weight standing in for a dense (..., K, N) kernel.

    ``shard`` is an optional static tensor-parallel tag stamped by
    ``dist.sharding.tag_compressed``: ``(site, *dim_entries)`` where
    ``site`` labels the projection group ("mlp" / "attn" / "moe" / "dense")
    and ``dim_entries`` name the mesh axes of the leaf's *executed* dense
    dims - ``(k, n)`` for a 2-D kernel, ``(e, k, n)`` for an expert bank
    (the leading "layers" scan axis is excluded so ``lax.scan`` slicing
    preserves the tag).  Each entry is None, a mesh-axis name, or a tuple
    of names.  A non-None K entry routes dispatch through the shard-mapped
    kernels in ``kernels/shard.py``; None (the default) keeps the
    single-device / GSPMD path.
    """

    def __init__(self, vals: jax.Array, idx: jax.Array, idx_bits: int = 8,
                 shard: tuple | None = None):
        assert idx_bits in (2, 8), idx_bits
        self.vals = vals
        self.idx = idx
        self.idx_bits = idx_bits
        self.shard = None if shard is None else tuple(shard)

    def tree_flatten(self):
        return (self.vals, self.idx), (self.idx_bits, self.shard)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, idx_bits=aux[0], shard=aux[1])

    def with_shard(self, shard: tuple | None) -> "SparseTensor":
        """Same components, new tensor-parallel tag."""
        return SparseTensor(self.vals, self.idx, idx_bits=self.idx_bits,
                            shard=shard)

    @property
    def shard_site(self) -> str | None:
        return None if self.shard is None else self.shard[0]

    @property
    def k_shard(self):
        """Mesh axes of the contraction dim, or None (replicated K)."""
        return None if self.shard is None else self.shard[-2]

    # -- metadata (trace-safe: shapes only) ---------------------------------

    @property
    def shape(self) -> tuple[int, ...]:
        *lead, half_k, n = self.vals.shape
        return (*lead, half_k * 2, n)

    @property
    def ndim(self) -> int:
        return len(self.vals.shape)

    @property
    def dtype(self):
        return self.vals.dtype

    @property
    def nbytes(self) -> int:
        return (int(np.prod(self.vals.shape)) * self.vals.dtype.itemsize
                + int(np.prod(self.idx.shape)) * self.idx.dtype.itemsize)

    @property
    def layout(self) -> str:
        """Storage layout tag for the index plane."""
        return LAYOUT_PACKED2 if self.idx_bits == 2 else LAYOUT_INT8

    @property
    def kernel_layout(self) -> str:
        """Layout the matmul kernel streams.

        Packed storage is kernel-native only when K % 8 == 0 (no padding
        rows inside a tile); a padded plane unpacks to int8 at dispatch.
        """
        if self.idx_bits == 2 and self.shape[-2] % 8 == 0:
            return LAYOUT_PACKED2
        return LAYOUT_INT8

    # -- conversions --------------------------------------------------------

    def unpacked_idx(self) -> jax.Array:
        """int8 (..., K/2, N) positions regardless of storage packing."""
        if self.idx_bits != 2:
            return self.idx
        half_k = self.vals.shape[-2]
        return _unpack_idx2(self.idx)[..., :half_k, :]

    def to_dense(self) -> jax.Array:
        """Decompress to the dense (..., K, N) array (masked positions = 0)."""
        vals, idx = self.vals, self.unpacked_idx()
        *lead, half_k, n = vals.shape
        g = half_k // 2
        v = vals.reshape(*lead, g, 2, n)
        p = idx.reshape(*lead, g, 2, n).astype(jnp.int32)
        r = jnp.arange(4)[:, None]
        dense = jnp.zeros((*lead, g, 4, n), vals.dtype)
        for j in range(2):
            hit = p[..., j:j + 1, :] == r
            dense = dense + jnp.where(hit, v[..., j:j + 1, :], 0)
        return dense.reshape(*lead, g * 4, n)

    def __repr__(self):
        tag = f", shard={self.shard}" if self.shard is not None else ""
        return (f"SparseTensor(shape={self.shape}, dtype={self.dtype}, "
                f"idx_bits={self.idx_bits}{tag})")


@jax.tree_util.register_pytree_node_class
class BitMask:
    """Boolean mask packed 8-per-byte (flat uint8 buffer + static shape)."""

    def __init__(self, bits: jax.Array, shape: tuple[int, ...]):
        self.bits = bits
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.bits,), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux[0])

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.bits.shape))

    @classmethod
    def pack(cls, mask: jax.Array) -> "BitMask":
        flat = jnp.ravel(mask).astype(jnp.uint8)
        pad = -flat.size % 8
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.uint8)])
        b = flat.reshape(-1, 8)
        weights = (1 << jnp.arange(8, dtype=jnp.uint8))
        return cls(jnp.sum(b * weights, axis=-1).astype(jnp.uint8),
                   tuple(mask.shape))

    def to_dense(self) -> jax.Array:
        n = int(np.prod(self.shape))
        b = self.bits[:, None] >> jnp.arange(8, dtype=jnp.uint8)[None, :]
        flat = (b & 1).reshape(-1)[:n]
        return flat.astype(jnp.bool_).reshape(self.shape)


def sparse_leaves(tree: Any) -> list[SparseTensor]:
    """All SparseTensor nodes in a pytree (treated as subtree roots)."""
    found: list[SparseTensor] = []
    jax.tree.map(lambda x: found.append(x) if isinstance(x, SparseTensor)
                 else None, tree,
                 is_leaf=lambda x: isinstance(x, SparseTensor))
    return found
