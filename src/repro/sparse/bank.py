"""Persistent mask bank: one calibration, arbitrary budgets, any process.

UniPruning's one-shot property (paper §4.3: "generate pruning masks for
arbitrary sparsity levels" after a brief calibration) only pays off if the
calibration state outlives the Python process.  The bank persists the
post-search state - Gamma, the dual V, the activation stats, and the
PruneConfig - as a named on-disk artifact (``ckpt.save_artifact``:
manifest.json + one .npy per leaf, atomic commit).  ``masks_at`` then
re-thresholds via ``mirror.export_masks`` in one shot: no mirror-descent
re-run per sparsity level, across restarts.

Global-update baselines (SparseLLM, ADMM pruning) re-solve per target
configuration; here a new budget is a quantile of a saved tensor.
"""
from __future__ import annotations

import dataclasses
import zlib
from collections import OrderedDict
from typing import Any, Iterable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.ckpt import checkpoint as ckpt
from repro.configs.base import PruneConfig, get_config, get_smoke_config

PyTree = Any

# masks_at memoization bound: a full mask tree is ~half the prunable-weight
# bytes, and the autoscale path mints budgets live - unbounded growth here
# is an OOM on long-lived fleets.  8 covers every concurrently-served
# budget seen in practice (fleet tests use <= 4); LRU eviction just means
# a re-threshold on the next request for an evicted budget.
MASK_CACHE_ENTRIES = 8

SCHEMA = "unipruning.mask-bank/v1"
# Artifact header version.  v1: no integrity fields (legacy, still loads).
# v2: adds {format_version, checksum} - a truncated/bit-rotted leaf or an
# artifact written by a newer format fails loudly at load instead of
# silently re-thresholding to wrong masks.
FORMAT_VERSION = 2


def _cfg_for(arch: str, smoke: bool):
    return get_smoke_config(arch) if smoke else get_config(arch)


def _tree_checksum(tree: PyTree) -> str:
    """Order-stable crc32 over materialized leaves (path, dtype, shape,
    bytes).  None leaves are skipped entirely - load rebuilds the tree
    through the full params template, which expands a saved ``stats=None``
    into a subtree of None leaves, so hashing None *structure* would reject
    a valid artifact."""
    flat, _ = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)
    crc = 0
    for kp, leaf in flat:
        if leaf is None:
            continue
        crc = zlib.crc32(jax.tree_util.keystr(kp).encode(), crc)
        a = np.ascontiguousarray(np.asarray(leaf))
        crc = zlib.crc32(f"{a.dtype}{a.shape}".encode(), crc)
        crc = zlib.crc32(a.tobytes(), crc)
    return f"{crc:08x}"


def _params_template(cfg) -> PyTree:
    """Params-structure tree of placeholder leaves (no allocation).

    load_artifact only uses the template for structure + key paths; leaves
    stored as None in the manifest come back None.
    """
    from repro.models import model as M
    return jax.tree.map(lambda s: 0, M.param_shapes(cfg))


class MaskBank:
    """Saved calibration state; re-threshold to masks at any budget."""

    def __init__(self, cfg, pcfg: PruneConfig, Gamma: PyTree, V: PyTree,
                 stats: PyTree, meta: dict):
        self.cfg = cfg
        self.pcfg = pcfg
        self.Gamma = Gamma
        self.V = V
        self.stats = stats
        self.meta = meta
        # budget-key -> exported keep-mask tree.  Re-thresholding is a full
        # pass over the calibration state (global quantile of |Gamma|), so a
        # fleet building one engine per budget - or repeated sparse_params
        # calls at the same budget - must threshold once per budget, not
        # once per caller.  Mask trees are immutable jax arrays: sharing the
        # cached tree across callers is safe.  Bounded LRU (recency =
        # insertion + hit order), MASK_CACHE_ENTRIES deep.
        self._mask_cache: OrderedDict[tuple, PyTree] = OrderedDict()

    # -- persistence ---------------------------------------------------------

    @classmethod
    def save(cls, directory, *, arch: str, smoke: bool, state,
             stats: PyTree = None, pcfg: PruneConfig,
             extra: dict | None = None, cfg=None) -> "MaskBank":
        """state: core.mirror.SearchState (or any object with Gamma/V).

        cfg: explicit ModelConfig for archs outside the registry (benchmark
        families, example models); registry archs resolve from ``arch``.
        """
        tree = {"Gamma": state.Gamma, "V": state.V, "stats": stats}
        meta = {"schema": SCHEMA, "format_version": FORMAT_VERSION,
                "arch": arch, "smoke": bool(smoke),
                "pcfg": dataclasses.asdict(pcfg),
                "steps_run": int(state.step) if hasattr(state, "step") else None,
                "checksum": _tree_checksum(tree),
                **(extra or {})}
        ckpt.save_artifact(directory, tree, metadata=meta)
        return cls(cfg if cfg is not None else _cfg_for(arch, smoke),
                   pcfg, state.Gamma, state.V, stats, meta)

    @classmethod
    def load(cls, directory, *, cfg=None) -> "MaskBank":
        probe = {"Gamma": 0}  # metadata first: the template needs the arch
        _, meta = ckpt.load_artifact(directory, probe)
        assert meta.get("schema") == SCHEMA, meta
        version = meta.get("format_version", 1)
        if version > FORMAT_VERSION:
            raise ValueError(
                f"mask bank at {directory} has format_version {version}, "
                f"this build reads <= {FORMAT_VERSION}: refusing a stale "
                "reader on a newer artifact")
        if version < 2:
            # obs.log keeps the stdlib UserWarning contract (filters,
            # pytest.warns) AND lands the structured record in the same
            # JSONL stream as the calibration/serving spans
            obs.log("bank.legacy_format", level="warning",
                    directory=str(directory), format_version=version,
                    warn=(
                        f"mask bank at {directory} is a LEGACY "
                        "format_version=1 artifact with no integrity "
                        "checksum: a truncated or bit-rotted leaf would "
                        "silently re-threshold to wrong masks.  Re-save it "
                        "(launch.calibrate / MaskBank.save) to get "
                        "checksummed format_version=2."))
        if cfg is None:
            cfg = _cfg_for(meta["arch"], meta["smoke"])
        tpl = _params_template(cfg)
        tree, _ = ckpt.load_artifact(
            directory, {"Gamma": tpl, "V": tpl, "stats": tpl})
        if version >= 2:
            got = _tree_checksum(tree)
            if got != meta["checksum"]:
                raise ValueError(
                    f"mask bank at {directory} failed its integrity check "
                    f"(stored {meta['checksum']}, recomputed {got}): "
                    "artifact is truncated or corrupt, refusing to serve "
                    "masks from it")
        to_dev = lambda t: jax.tree.map(
            lambda x: None if x is None else jnp.asarray(x), t,
            is_leaf=lambda x: x is None)
        pcfg = PruneConfig(**meta["pcfg"])
        return cls(cfg, pcfg, to_dev(tree["Gamma"]), to_dev(tree["V"]),
                   to_dev(tree["stats"]), meta)

    # -- one-shot mask export ------------------------------------------------

    def masks_at(self, sparsity: float | None = None,
                 nm: tuple[int, int] | None = None) -> PyTree:
        """Keep-mask pytree at an arbitrary budget, bit-identical to an
        in-process ``mirror.export_masks`` on the live SearchState.

        sparsity: unstructured global budget; nm: (n, m) semi-structured.
        With neither, the bank's calibrated PruneConfig decides (nm mode ->
        its n:m pattern; unstructured requires an explicit sparsity).

        Memoized per budget: the first call at a given (sparsity | nm) key
        runs the quantile pass over the calibration state, repeats return
        the cached mask tree (jax arrays, immutable).
        """
        from repro.core import mirror
        pcfg = self.pcfg
        if nm is not None:
            pcfg = dataclasses.replace(pcfg, mode="nm", nm_n=nm[0],
                                       nm_m=nm[1])
            key = ("nm", (int(nm[0]), int(nm[1])))
        elif sparsity is not None:
            pcfg = dataclasses.replace(pcfg, mode="unstructured")
            key = ("unstructured", float(sparsity))
        else:
            assert pcfg.mode == "nm", \
                "unstructured bank needs an explicit sparsity"
            key = ("nm", (int(pcfg.nm_n), int(pcfg.nm_m)))
        masks = self._mask_cache.get(key)
        if masks is not None:
            self._mask_cache.move_to_end(key)
            return masks
        sp = obs.span("bank.threshold", budget=str(key))
        with sp:
            masks = mirror.export_masks(
                pcfg, self.Gamma, 0.5 if sparsity is None else sparsity,
                V=self.V)
            sp.fence(masks)
        obs.inc("bank.threshold_passes")
        self._mask_cache[key] = masks
        while len(self._mask_cache) > MASK_CACHE_ENTRIES:
            self._mask_cache.popitem(last=False)
        obs.set_gauge("analysis.mask_cache_entries", len(self._mask_cache))
        return masks

    def masks_grid(self, sparsities: Iterable[float]) -> dict[float, PyTree]:
        return {s: self.masks_at(sparsity=s) for s in sparsities}

    # -- serving-ready parameter trees --------------------------------------

    def sparse_params(self, params0: PyTree, *, sparsity: float | None = None,
                      nm: tuple[int, int] | None = None,
                      compressed: bool = True, idx_bits: int = 2,
                      dtype=None, with_masks: bool = False) -> PyTree:
        """W0 -> pruned params: compressed (SparseTensor kernels - expert
        banks included - routed through the nm_spmm kernels) or masked-dense
        (W0 * mask).  with_masks=True also returns the keep-mask tree, so
        callers can feed ``compressed_report(params, masks)`` and surface
        masked-dense fallback leaves without re-thresholding."""
        from repro.core import masks as masks_mod
        from repro.models import model as M
        from repro.sparse import apply as apply_mod
        if nm is None and sparsity is None and self.pcfg.mode == "nm":
            nm = (self.pcfg.nm_n, self.pcfg.nm_m)
        masks = self.masks_at(sparsity=sparsity, nm=nm)
        if not compressed or nm is None:
            out = masks_mod.apply_masks(params0, masks)
            return (out, masks) if with_masks else out
        if dtype is None:
            from repro.models.common import COMPUTE_DTYPE
            dtype = COMPUTE_DTYPE
        out = apply_mod.sparsify_params(
            params0, masks, axes=M.param_axes(self.cfg), idx_bits=idx_bits,
            dtype=dtype)
        return (out, masks) if with_masks else out
