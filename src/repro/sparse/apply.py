"""Sparse execution: route SparseTensor kernels through ``nm_matmul``.

``models.common.dense`` dispatches on leaf type, so a params tree whose
prunable kernels were replaced by :func:`sparsify_params` serves through the
compressed kernel (Pallas on TPU, interpret mode on CPU) while every dense
leaf keeps the existing path.  MoE expert banks (E, d_in, d_out) dispatch
the same way through ``models.common.expert_dense`` ->
:func:`sparse_moe_dense`, which consumes the dispatch buffer (G, E, C, d)
directly against the expert-grid kernel ``nm_matmul_expert``.  The leaf's ``kernel_layout`` tag decides what
the kernel streams: 2-bit-packed index planes (K % 8 == 0) go to the kernel
*as stored* - the unpack happens inside the kernel after the HBM->VMEM copy,
so there is no host-side ``unpacked_idx()`` round-trip on the serving path.
Byte-padded planes (K % 8 != 0) and int8 storage take the int8 fallback.
On CPU the whole GEMM runs as a single tile (interpret mode has no VMEM
limit), which keeps the accumulation order identical to XLA's dense bf16
dot - sparse serving reproduces masked-dense serving token-for-token.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.nm_spmm import (LAYOUT_INT8, LAYOUT_PACKED2, nm_matmul,
                                   nm_matmul_expert)
from repro.sparse import pack as pack_mod
from repro.sparse.formats import SparseTensor

PyTree = Any


def _largest_block(dim: int, cap: int, mult: int = 1) -> int:
    """Largest b <= cap with dim % b == 0 and b % mult == 0.

    mult encodes the TPU tiling preference (lane dim = multiples of 128,
    reduction tiles = multiples of 4 for the 2:4 groups); callers drop the
    preference when the dim itself cannot satisfy it.
    """
    for b in range(min(cap, dim), mult - 1, -1):
        if dim % b == 0 and b % mult == 0:
            return b
    return dim  # dim < mult: single block


def _run_nm(x: jax.Array, vals: jax.Array, idx: jax.Array, layout: str,
            kernel=nm_matmul, out_dtype=None) -> jax.Array:
    """Pick block sizes and dispatch: x (M, K) through ``nm_matmul`` or,
    with ``kernel=nm_matmul_expert``, a per-expert batch (E, M, K) through
    the expert-grid kernel (block selection only sees the trailing dims)."""
    m, k = x.shape[-2:]
    n = vals.shape[-1]
    if jax.default_backend() == "tpu":
        bn = (_largest_block(n, 256, 128) if n % 128 == 0
              else _largest_block(n, 256))
        # packed tiles must cover whole index bytes (8 dense rows/byte row)
        bk_mult = 8 if layout == LAYOUT_PACKED2 else 4
        return kernel(x, vals, idx, bm=_largest_block(m, 128),
                      bk=_largest_block(k, 512, bk_mult), bn=bn,
                      layout=layout, out_dtype=out_dtype)
    # interpret mode: one tile (per expert) = one fp32 dot, bit-matching the
    # dense path's contraction
    return kernel(x, vals, idx, bm=m, bk=k, bn=n, layout=layout,
                  interpret=True, out_dtype=out_dtype)


def _kernel_operand(st: SparseTensor) -> tuple[jax.Array, str]:
    """Index plane + layout tag as the kernel consumes it.

    Kernel-native packed storage ships the stored bytes untouched; padded
    or int8 storage unpacks to the int8 fallback plane at dispatch.
    """
    layout = st.kernel_layout
    if layout == LAYOUT_PACKED2:
        return st.idx, layout
    return st.unpacked_idx(), layout


def _tp(st: SparseTensor) -> bool:
    """Route through the shard-mapped K-partial kernels?  True when the
    leaf carries a K-shard tag (``dist.sharding.tag_compressed``) and rules
    are installed at trace time (``serve.engine.EngineFns(rules=...)``)."""
    from repro.kernels.shard import k_sharded
    return k_sharded(st)


def sparse_dense(st: SparseTensor, x: jax.Array) -> jax.Array:
    """x: (..., K) @ compressed (K, N) -> (..., N) in x.dtype."""
    assert len(st.vals.shape) == 2, (
        "per-layer kernels only; stacked leaves are sliced by lax.scan")
    *lead, k = x.shape
    x2 = x.reshape(-1, k)
    if _tp(st):
        from repro.kernels import shard as ksh
        y = ksh.nm_dense_sharded(st, x2, site=st.shard_site)
        return y.reshape(*lead, st.shape[-1])
    idx, layout = _kernel_operand(st)
    y = _run_nm(x2, st.vals.astype(x.dtype), idx, layout)
    return y.reshape(*lead, st.shape[-1])


def sparse_moe_dense(st: SparseTensor, buf: jax.Array) -> jax.Array:
    """MoE dispatch buffer (G, E, C, d) @ compressed expert bank (E, d, N)
    -> (G, E, C, N) in buf.dtype.

    Consumes the dispatch buffer directly: tokens regroup per expert to
    (E, G*C, d) and run through ``nm_matmul_expert`` - one kernel invocation
    covers every expert's GEMM, replacing ``moe_apply``'s masked-dense
    einsum.  The index plane ships exactly as :func:`_kernel_operand`
    decides for 2-D kernels (packed 2-bit when K % 8 == 0, int8 fallback
    otherwise).
    """
    assert st.ndim == 3, (
        "expert banks are (E, K, N); stacked (layers, E, K, N) leaves are "
        "sliced by lax.scan before reaching the kernel")
    G, E, C, d = buf.shape
    assert st.shape[0] == E and st.shape[1] == d, (st.shape, buf.shape)
    x3 = buf.swapaxes(0, 1).reshape(E, G * C, d)
    if _tp(st):
        from repro.kernels import shard as ksh
        y = ksh.nm_moe_sharded(st, x3, site=st.shard_site)
        return y.reshape(E, G, C, st.shape[-1]).swapaxes(0, 1)
    idx, layout = _kernel_operand(st)
    y = _run_nm(x3, st.vals.astype(buf.dtype), idx, layout,
                kernel=nm_matmul_expert)
    return y.reshape(E, G, C, st.shape[-1]).swapaxes(0, 1)


def sparse_dense2(st_a: SparseTensor, st_b: SparseTensor, x: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Fused pair sharing the reduction dim (gated-MLP up+gate).

    Three routes, decided at trace time:

    * K-shard-tagged pair (``kernels.shard.pair_k_sharded``): two local
      kernels under one shard_map, ONE deferred variadic psum for the whole
      projection group.
    * TPU, untagged: two separate kernel calls (a pre-concat of vals/idx
      would re-copy the weights every step, costing more HBM traffic than
      the saved launch).
    * CPU/interpret, untagged: one kernel pass over [A | B] concatenated
      along N, then split (per-call overhead dominates there).
    """
    from repro.kernels import shard as ksh
    *lead, k = x.shape
    na, nb = st_a.shape[-1], st_b.shape[-1]
    x2 = x.reshape(-1, k)
    if ksh.pair_k_sharded(st_a, st_b):
        ya, yb = ksh.nm_dense2_sharded(st_a, st_b, x2,
                                       site=st_a.shard_site)
        return ya.reshape(*lead, na), yb.reshape(*lead, nb)
    if jax.default_backend() == "tpu":
        return sparse_dense(st_a, x), sparse_dense(st_b, x)
    vals = jnp.concatenate([st_a.vals, st_b.vals], axis=-1).astype(x.dtype)
    if (st_a.kernel_layout == LAYOUT_PACKED2
            and st_b.kernel_layout == LAYOUT_PACKED2):
        # packed planes share the byte layout along K: concat stays packed
        idx = jnp.concatenate([st_a.idx, st_b.idx], axis=-1)
        layout = LAYOUT_PACKED2
    else:
        idx = jnp.concatenate(
            [st_a.unpacked_idx(), st_b.unpacked_idx()], axis=-1)
        layout = LAYOUT_INT8
    y = _run_nm(x2, vals, idx, layout)
    return (y[:, :na].reshape(*lead, na), y[:, na:].reshape(*lead, nb))


def sparse_moe_dense2(st_up: SparseTensor, st_gate: SparseTensor,
                      buf: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Fused up+gate expert banks over one dispatch buffer (K-shard-tagged
    pair only): two local expert-grid kernels, one deferred psum across the
    pair and the expert grid.  Callers check
    ``kernels.shard.pair_k_sharded`` first."""
    from repro.kernels import shard as ksh
    G, E, C, d = buf.shape
    x3 = buf.swapaxes(0, 1).reshape(E, G * C, d)
    h, g = ksh.nm_moe2_sharded(st_up, st_gate, x3, site=st_up.shard_site)
    return (h.reshape(E, G, C, st_up.shape[-1]).swapaxes(0, 1),
            g.reshape(E, G, C, st_gate.shape[-1]).swapaxes(0, 1))


# ---------------------------------------------------------------------------
# Tree conversion
# ---------------------------------------------------------------------------

def _stacked(axes_str: str | None) -> bool:
    return bool(axes_str) and axes_str.startswith("layers|")


def _aligned_leaves(ref_flat, ref_treedef, tree: PyTree, name: str) -> list:
    """Flatten ``tree`` and validate it is structure-identical to params.

    A silently mis-paired zip here would compress kernels against the wrong
    masks (or worse, truncate the iteration); mismatches raise with the
    first offending key path instead.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: x is None)
    if treedef != ref_treedef:
        ref_paths = [jax.tree_util.keystr(kp) for kp, _ in ref_flat]
        got_paths = [jax.tree_util.keystr(kp) for kp, _ in flat]
        for rp, gp in zip(ref_paths, got_paths):
            if rp != gp:
                raise ValueError(
                    f"{name} tree does not match params: first offending "
                    f"key path {gp!r} ({name}) vs {rp!r} (params)")
        if len(ref_paths) != len(got_paths):
            longer, which = ((ref_paths, "params") if len(ref_paths)
                             > len(got_paths) else (got_paths, name))
            raise ValueError(
                f"{name} tree does not match params: {len(got_paths)} "
                f"leaves vs {len(ref_paths)} params leaves; first unmatched "
                f"key path "
                f"{longer[min(len(ref_paths), len(got_paths))]!r} ({which})")
        # every key path matches: the trees differ only in container types
        raise ValueError(
            f"{name} tree does not match params: same {len(ref_paths)} leaf "
            f"paths but different container structure "
            f"({treedef} vs params {ref_treedef})")
    return [leaf for _, leaf in flat]


def _is_expert_bank(path: str, eff_ndim: int) -> bool:
    """3-D-per-layer-step MoE expert bank (E, d_in, d_out)?

    The leading dim must be an expert axis the consumer
    (``moe_apply`` -> :func:`sparse_moe_dense`) dispatches over - keyed on
    the ``['moe']`` subtree so unrelated 3-D kernels (e.g. per-head
    recurrent weights) never get a layout their call sites cannot execute.
    """
    return eff_ndim == 3 and "['moe']" in path


def sparsify_params(params: PyTree, masks: PyTree, *, axes: PyTree = None,
                    idx_bits: int = 2, dtype=None,
                    predicate: Callable[[str], bool] | None = None) -> PyTree:
    """Replace 2:4-maskable kernels with SparseTensor leaves; mask the rest.

    masks: keep-mask pytree from ``mirror.export_masks`` (mode="nm").  A
    kernel is compressed when its mask is 2:4-valid along the reduction dim
    and it is, per layer step, either 2-D or a 3-D MoE expert bank
    (E, d_in, d_out) (``axes`` - the ``models.model.param_axes`` tree -
    identifies scan-stacked leaves, whose leading "layers" axis is sliced by
    ``lax.scan`` before execution).  Non-compressible masked leaves get
    ``W * mask``; None-mask leaves pass through untouched.

    masks/axes must be structure-identical to params: a mismatched tree
    raises with the first offending key path instead of silently truncating
    the zip and pairing kernels with the wrong masks.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = _aligned_leaves(flat, treedef, masks, "masks")
    flat_a = (_aligned_leaves(flat, treedef, axes, "axes")
              if axes is not None else [None] * len(flat))
    out = []
    for (kp, w), mk, ax in zip(flat, flat_m, flat_a, strict=True):
        if mk is None:
            out.append(w)
            continue
        path = jax.tree_util.keystr(kp)
        eff_ndim = w.ndim - (1 if _stacked(ax) else 0)
        k_dim = w.shape[-2]
        compressible = ((eff_ndim == 2 or _is_expert_bank(path, eff_ndim))
                        and k_dim % 4 == 0
                        and (predicate is None or predicate(path))
                        and _is_nm(mk))
        if compressible:
            # k_dim % 8 != 0 no longer widens to int8: the packed plane is
            # zero-padded to the byte boundary instead (the kernel takes the
            # int8 fallback there, but storage keeps the 2-bit byte win)
            out.append(pack_mod.pack_nm(w, mk, idx_bits=idx_bits,
                                        dtype=dtype))
        else:
            out.append(w * mk.astype(w.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def shared_leaves(params0: PyTree, tree: PyTree) -> int:
    """How many of ``tree``'s leaves are ``params0``'s buffers, unchanged.

    Pruning replaces only the pruned kernels (SparseTensor or ``W * mask``);
    every None-mask leaf - embeddings, norms, biases - must pass through by
    object identity, so N budget variants built from one ``params0`` share
    ONE copy of the untouched leaves instead of N.  This is the fleet's
    memory-sharing invariant; SparseTensor leaves are new storage by
    definition and never count.
    """
    ids = {id(leaf) for leaf in jax.tree.leaves(params0)}
    return sum(
        id(leaf) in ids
        for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, SparseTensor))
        if not isinstance(leaf, SparseTensor))


def _is_nm(mask: jax.Array, m: int = 4, n: int = 2) -> bool:
    """Host-side check: exactly n kept per contiguous group of m."""
    if mask.shape[-2] % m:
        return False
    g = np.asarray(mask).reshape(*mask.shape[:-2], mask.shape[-2] // m, m,
                                 mask.shape[-1])
    return bool((g.sum(-2) == n).all())


def compressed_report(params: PyTree, masks: PyTree = None) -> dict:
    """Per-leaf and total weight bytes: compressed vs dense-bf16 equivalent.

    ``layout`` is the storage layout tag; ``kernel_layout`` is what the
    matmul actually streams (a byte-padded packed plane executes through the
    int8 fallback), so the bytes accounting stays honest: ``nbytes`` counts
    the stored (padded) plane, never a phantom unpadded one.

    With ``masks`` (the keep-mask tree the params were sparsified against),
    pruned leaves that did NOT compress - masked-dense fallbacks serving the
    full dense byte footprint - are reported too, with
    ``bytes_compressed == bytes_dense_bf16``, ``kernel_layout ==
    "masked-dense"`` and ``fallback: True``, and they count into the
    headline ratio; without masks only SparseTensor leaves are visible and
    the ratio covers compressed leaves alone.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, SparseTensor))
    flat_m = (_aligned_leaves(flat, treedef, masks, "masks")
              if masks is not None else [None] * len(flat))
    layers = []
    for (kp, leaf), mk in zip(flat, flat_m, strict=True):
        if isinstance(leaf, SparseTensor):
            d = 1
            for s in leaf.shape:
                d *= s
            d *= 2  # bf16 serving layout
            layers.append({"path": jax.tree_util.keystr(kp),
                           "shape": list(leaf.shape),
                           "idx_bits": leaf.idx_bits,
                           "layout": leaf.layout,
                           "kernel_layout": leaf.kernel_layout,
                           "bytes_compressed": leaf.nbytes,
                           "bytes_dense_bf16": d,
                           "ratio": leaf.nbytes / d,
                           "fallback": False})
        elif mk is not None:
            # pruned but served masked-dense: full dense bytes move
            d = 2 * int(np.prod(leaf.shape))
            layers.append({"path": jax.tree_util.keystr(kp),
                           "shape": list(leaf.shape), "idx_bits": None,
                           "layout": None, "kernel_layout": "masked-dense",
                           "bytes_compressed": d, "bytes_dense_bf16": d,
                           "ratio": 1.0, "fallback": True})
    comp = sum(r["bytes_compressed"] for r in layers)
    dense_eq = sum(r["bytes_dense_bf16"] for r in layers)
    kernel_native = sum(r["kernel_layout"] == LAYOUT_PACKED2 for r in layers)
    return {"layers": layers, "bytes_compressed": comp,
            "bytes_dense_bf16": dense_eq,
            "kernel_native_packed": kernel_native,
            "fallback_leaves": sum(r["fallback"] for r in layers),
            "ratio": comp / dense_eq if dense_eq else None}
