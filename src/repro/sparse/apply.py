"""Sparse execution: route SparseTensor kernels through ``nm_matmul``.

``models.common.dense`` dispatches on leaf type, so a params tree whose
prunable kernels were replaced by :func:`sparsify_params` serves through the
compressed kernel (Pallas on TPU, interpret mode on CPU) while every dense
leaf keeps the existing path.  The leaf's ``kernel_layout`` tag decides what
the kernel streams: 2-bit-packed index planes (K % 8 == 0) go to the kernel
*as stored* - the unpack happens inside the kernel after the HBM->VMEM copy,
so there is no host-side ``unpacked_idx()`` round-trip on the serving path.
Byte-padded planes (K % 8 != 0) and int8 storage take the int8 fallback.
On CPU the whole GEMM runs as a single tile (interpret mode has no VMEM
limit), which keeps the accumulation order identical to XLA's dense bf16
dot - sparse serving reproduces masked-dense serving token-for-token.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels.nm_spmm import LAYOUT_INT8, LAYOUT_PACKED2, nm_matmul
from repro.sparse import pack as pack_mod
from repro.sparse.formats import SparseTensor

PyTree = Any


def _largest_block(dim: int, cap: int, mult: int = 1) -> int:
    """Largest b <= cap with dim % b == 0 and b % mult == 0.

    mult encodes the TPU tiling preference (lane dim = multiples of 128,
    reduction tiles = multiples of 4 for the 2:4 groups); callers drop the
    preference when the dim itself cannot satisfy it.
    """
    for b in range(min(cap, dim), mult - 1, -1):
        if dim % b == 0 and b % mult == 0:
            return b
    return dim  # dim < mult: single block


def _run_nm(x2: jax.Array, vals: jax.Array, idx: jax.Array, layout: str
            ) -> jax.Array:
    m, k = x2.shape
    n = vals.shape[-1]
    if jax.default_backend() == "tpu":
        bn = (_largest_block(n, 256, 128) if n % 128 == 0
              else _largest_block(n, 256))
        # packed tiles must cover whole index bytes (8 dense rows/byte row)
        bk_mult = 8 if layout == LAYOUT_PACKED2 else 4
        return nm_matmul(x2, vals, idx, bm=_largest_block(m, 128),
                         bk=_largest_block(k, 512, bk_mult), bn=bn,
                         layout=layout)
    # interpret mode: one tile = one fp32 dot, bit-matching the dense path
    return nm_matmul(x2, vals, idx, bm=m, bk=k, bn=n, layout=layout,
                     interpret=True)


def _kernel_operand(st: SparseTensor) -> tuple[jax.Array, str]:
    """Index plane + layout tag as the kernel consumes it.

    Kernel-native packed storage ships the stored bytes untouched; padded
    or int8 storage unpacks to the int8 fallback plane at dispatch.
    """
    layout = st.kernel_layout
    if layout == LAYOUT_PACKED2:
        return st.idx, layout
    return st.unpacked_idx(), layout


def sparse_dense(st: SparseTensor, x: jax.Array) -> jax.Array:
    """x: (..., K) @ compressed (K, N) -> (..., N) in x.dtype."""
    assert len(st.vals.shape) == 2, (
        "per-layer kernels only; stacked leaves are sliced by lax.scan")
    *lead, k = x.shape
    x2 = x.reshape(-1, k)
    idx, layout = _kernel_operand(st)
    y = _run_nm(x2, st.vals.astype(x.dtype), idx, layout)
    return y.reshape(*lead, st.shape[-1])


def sparse_dense2(st_a: SparseTensor, st_b: SparseTensor, x: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    """Fused pair sharing the reduction dim (gated-MLP up+gate): one kernel
    pass over x against [A | B] concatenated along N, then split."""
    *lead, k = x.shape
    na, nb = st_a.shape[-1], st_b.shape[-1]
    x2 = x.reshape(-1, k)
    vals = jnp.concatenate([st_a.vals, st_b.vals], axis=-1).astype(x.dtype)
    if (st_a.kernel_layout == LAYOUT_PACKED2
            and st_b.kernel_layout == LAYOUT_PACKED2):
        # packed planes share the byte layout along K: concat stays packed
        idx = jnp.concatenate([st_a.idx, st_b.idx], axis=-1)
        layout = LAYOUT_PACKED2
    else:
        idx = jnp.concatenate(
            [st_a.unpacked_idx(), st_b.unpacked_idx()], axis=-1)
        layout = LAYOUT_INT8
    y = _run_nm(x2, vals, idx, layout)
    return (y[:, :na].reshape(*lead, na), y[:, na:].reshape(*lead, nb))


# ---------------------------------------------------------------------------
# Tree conversion
# ---------------------------------------------------------------------------

def _stacked(axes_str: str | None) -> bool:
    return bool(axes_str) and axes_str.startswith("layers|")


def sparsify_params(params: PyTree, masks: PyTree, *, axes: PyTree = None,
                    idx_bits: int = 2, dtype=None,
                    predicate: Callable[[str], bool] | None = None) -> PyTree:
    """Replace 2:4-maskable kernels with SparseTensor leaves; mask the rest.

    masks: keep-mask pytree from ``mirror.export_masks`` (mode="nm").  A
    kernel is compressed when its mask is 2:4-valid along the reduction dim
    and it is 2-D per layer step (``axes`` - the ``models.model.param_axes``
    tree - identifies scan-stacked leaves; >3-D leaves such as MoE expert
    banks stay masked-dense until the kernel grows an expert axis).
    Non-compressible masked leaves get ``W * mask``; None-mask leaves pass
    through untouched.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    flat_m = jax.tree_util.tree_flatten(
        masks, is_leaf=lambda x: x is None)[0]
    flat_a = (jax.tree_util.tree_flatten(
        axes, is_leaf=lambda x: x is None)[0] if axes is not None
        else [None] * len(flat))
    out = []
    for (kp, w), mk, ax in zip(flat, flat_m, flat_a):
        if mk is None:
            out.append(w)
            continue
        path = jax.tree_util.keystr(kp)
        eff_ndim = w.ndim - (1 if _stacked(ax) else 0)
        k_dim = w.shape[-2]
        compressible = (eff_ndim == 2 and k_dim % 4 == 0
                        and (predicate is None or predicate(path))
                        and _is_nm(mk))
        if compressible:
            # k_dim % 8 != 0 no longer widens to int8: the packed plane is
            # zero-padded to the byte boundary instead (the kernel takes the
            # int8 fallback there, but storage keeps the 2-bit byte win)
            out.append(pack_mod.pack_nm(w, mk, idx_bits=idx_bits,
                                        dtype=dtype))
        else:
            out.append(w * mk.astype(w.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def _is_nm(mask: jax.Array, m: int = 4, n: int = 2) -> bool:
    """Host-side check: exactly n kept per contiguous group of m."""
    import numpy as np
    if mask.shape[-2] % m:
        return False
    g = np.asarray(mask).reshape(*mask.shape[:-2], mask.shape[-2] // m, m,
                                 mask.shape[-1])
    return bool((g.sum(-2) == n).all())


def compressed_report(params: PyTree) -> dict:
    """Per-leaf and total weight bytes: compressed vs dense-bf16 equivalent.

    ``layout`` is the storage layout tag; ``kernel_layout`` is what the
    matmul actually streams (a byte-padded packed plane executes through the
    int8 fallback), so the bytes accounting stays honest: ``nbytes`` counts
    the stored (padded) plane, never a phantom unpadded one.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, SparseTensor))
    layers = []
    comp = dense_eq = 0
    for kp, leaf in flat:
        if not isinstance(leaf, SparseTensor):
            continue
        d = 1
        for s in leaf.shape:
            d *= s
        d *= 2  # bf16 serving layout
        layers.append({"path": jax.tree_util.keystr(kp),
                       "shape": list(leaf.shape), "idx_bits": leaf.idx_bits,
                       "layout": leaf.layout,
                       "kernel_layout": leaf.kernel_layout,
                       "bytes_compressed": leaf.nbytes,
                       "bytes_dense_bf16": d,
                       "ratio": leaf.nbytes / d})
    comp = sum(r["bytes_compressed"] for r in layers)
    dense_eq = sum(r["bytes_dense_bf16"] for r in layers)
    kernel_native = sum(r["kernel_layout"] == LAYOUT_PACKED2 for r in layers)
    return {"layers": layers, "bytes_compressed": comp,
            "bytes_dense_bf16": dense_eq,
            "kernel_native_packed": kernel_native,
            "ratio": comp / dense_eq if dense_eq else None}
