"""Mixture-of-Experts FFN with scatter-based token dispatch.

Dispatch is gather/scatter (argfree cumsum positioning), NOT one-hot einsum,
so compiled HLO FLOPs reflect the true active-expert compute (important for
the roofline's MODEL_FLOPS / HLO_FLOPS ratio).

Expert banks execute through ``common.expert_dense``: 2:4-compressed
SparseTensor banks (``sparse.apply.sparsify_params``) run the expert-grid
``nm_matmul_expert`` kernel over the dispatch buffer, dense banks keep the
einsum.  During calibration the stats tape records the dispatch buffer with
per-expert routed-token counts so capacity padding never dilutes saliency.

Sharding: if num_experts divides the `model` axis the expert dim is
expert-parallel ("experts" logical axis); otherwise each expert's hidden dim
is tensor-parallel ("mlp").
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.axes import constrain
from repro.models import common as cm
from repro.models.common import Builder


PyTree = Any


def moe_init(b: Builder, *, d_model: int, d_ff: int, num_experts: int,
             num_shared: int = 0, expert_sharded: bool = False) -> PyTree:
    e_ax = "experts" if expert_sharded else None
    f_ax = None if expert_sharded else "mlp"
    p = {
        "router": {"kernel": b.param((d_model, num_experts), ("embed", None),
                                     scale=d_model ** -0.5)},
        "up": {"kernel": b.param((num_experts, d_model, d_ff),
                                 (e_ax, "embed", f_ax))},
        "gate": {"kernel": b.param((num_experts, d_model, d_ff),
                                   (e_ax, "embed", f_ax))},
        "down": {"kernel": b.param((num_experts, d_ff, d_model),
                                   (e_ax, f_ax, "embed"))},
    }
    if num_shared:
        from repro.models.mlp import mlp_init
        p["shared"] = mlp_init(b, d_model, num_shared * d_ff, gated=True)
    return p


def _dp_setup():
    """(n_groups, batch_axes, mesh) from the installed sharding rules."""
    from repro.dist.axes import current_rules
    rules = current_rules()
    if rules is None:
        return 1, (), None
    n = 1
    batch_axes = rules.rules.get("batch") or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    batch_axes = tuple(a for a in batch_axes if a in rules.mesh.axis_names)
    for a in batch_axes:
        n *= rules.mesh.shape[a]
    return n, batch_axes, rules.mesh


def _positions_in_expert(flat_e: jax.Array, E: int, C: int):
    """flat_e: (..., A) expert ids -> (e_idx, p_idx, keep, onehot)."""
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos_all = jnp.cumsum(oh, axis=-2) - oh
    pos = jnp.take_along_axis(pos_all, flat_e[..., None], axis=-1)[..., 0]
    keep = pos < C
    e_idx = jnp.where(keep, flat_e, E)  # OOB -> dropped by scatter
    p_idx = jnp.where(keep, pos, 0)
    return e_idx, p_idx, keep, oh


def moe_apply(p: PyTree, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25, act: str = "silu",
              expert_sharded: bool = False) -> tuple[jax.Array, jax.Array]:
    """Returns (y, aux_load_balance_loss).

    Dispatch is GROUP-LOCAL: tokens are viewed as (dp_groups, T/dp, d)
    aligned with the batch sharding, capacity positions come from a cumsum
    *within* each group, and the scatter/gather carry the group dim - so
    GSPMD keeps every dispatch buffer dp-sharded instead of replicating a
    global-capacity buffer (a ~16 GB/device temp for mixtral otherwise).
    """
    orig_shape = x.shape
    d = x.shape[-1]
    T = x.size // d
    G, batch_axes, mesh = _dp_setup()
    if T % G != 0 or (T // G) < 8:
        G, batch_axes, mesh = 1, (), None
    Tl = T // G
    xg = constrain(x.reshape(G, Tl, d), "batch", None, None)
    E = p["router"]["kernel"].shape[-1]
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        p["router"]["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tl, E)
    gate_vals, idx = jax.lax.top_k(probs, top_k)  # (G, Tl, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    C = int(capacity_factor * Tl * top_k / E)
    C = min(max(8, -(-C // 8) * 8), Tl)
    flat_e = idx.reshape(G, Tl * top_k)  # expert id per assignment

    def dispatch_local(xg_l, flat_e_l):
        """Per-dp-shard scatter into (g_loc, E, C, d); runs under shard_map
        so the scatter is device-local (GSPMD replicates it otherwise)."""
        gl = xg_l.shape[0]
        e_idx, p_idx, keep, _ = _positions_in_expert(flat_e_l, E, C)
        src = jnp.repeat(xg_l, top_k, axis=1)  # (gl, Tl*k, d)
        g_iota = jnp.broadcast_to(jnp.arange(gl)[:, None], e_idx.shape)
        buf = jnp.zeros((gl, E, C, d), xg_l.dtype)
        buf = buf.at[g_iota, e_idx, p_idx].set(src, mode="drop")
        return buf, e_idx, p_idx, keep

    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        dispatch_local = cm.shard_map(
            dispatch_local, mesh=mesh,
            in_specs=(P(batch_axes, None, None), P(batch_axes, None)),
            out_specs=(P(batch_axes, None, None, None), P(batch_axes, None),
                       P(batch_axes, None), P(batch_axes, None)))
    buf, e_idx, p_idx, keep = dispatch_local(xg, flat_e)
    buf = constrain(buf, "batch", None, None, None)

    from repro.core import tape as _tape
    t = _tape.current_tape()
    if t is not None:  # per-(expert, input-feature) activation stats
        # The capacity buffer is zero-padded (unfilled slots, dropped
        # tokens): zeros add nothing to the sum of squares, but the
        # per-expert sample size is the routed-row count, not G*C - record
        # it so the stat renormalizes to the T tokens a dense-FFN layer
        # sees instead of reading diluted under one global budget.
        routed = jnp.sum(e_idx[..., None] == jnp.arange(E), axis=(0, 1))
        t.record(p["up"]["kernel"], buf.swapaxes(0, 1),   # (E, G, C, d)
                 count=routed, ref_count=T)
        t.record(p["gate"]["kernel"], buf.swapaxes(0, 1),
                 count=routed, ref_count=T)
    f_ax = None if expert_sharded else "mlp"
    e_ax = "experts" if expert_sharded else None
    # expert_dense dispatches on the bank leaf type: compressed SparseTensor
    # banks run the expert-grid nm_matmul_expert kernel over the dispatch
    # buffer, dense banks keep the einsum.  The pair helper fuses the shared
    # reduction dim when both banks are K-shard-tagged: one deferred psum
    # for the whole up+gate projection group.
    h, g = cm.expert_dense_pair(p["up"], p["gate"], buf)
    if act == "silu":
        g = jax.nn.silu(g)
    else:
        g = jax.nn.gelu(g, approximate=True)
    h = h * g
    h = constrain(h, "batch", e_ax, None, f_ax)
    if t is not None:
        t.record(p["down"]["kernel"], h.swapaxes(0, 1),
                 count=routed, ref_count=T)
    out_buf = cm.expert_dense(p["down"], h)
    out_buf = constrain(out_buf, "batch", None, None, None)

    def combine_local(out_buf_l, e_idx_l, p_idx_l, keep_l, gate_l):
        gl = out_buf_l.shape[0]
        g_iota = jnp.broadcast_to(jnp.arange(gl)[:, None], e_idx_l.shape)
        y_tk = out_buf_l.at[g_iota, e_idx_l, p_idx_l].get(
            mode="fill", fill_value=0)  # (gl, Tl*k, d)
        y_tk = y_tk * keep_l[..., None].astype(y_tk.dtype)
        y_tk = y_tk * gate_l.reshape(gl, -1)[..., None].astype(y_tk.dtype)
        return jnp.sum(y_tk.reshape(gl, Tl, top_k, d), axis=2)

    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        combine_local = cm.shard_map(
            combine_local, mesh=mesh,
            in_specs=(P(batch_axes, None, None, None), P(batch_axes, None),
                      P(batch_axes, None), P(batch_axes, None),
                      P(batch_axes, None, None)),
            out_specs=P(batch_axes, None, None))
    y = combine_local(out_buf, e_idx, p_idx, keep, gate_vals)
    y = y.reshape(orig_shape)

    if "shared" in p:
        from repro.models.mlp import mlp_apply
        y = y + mlp_apply(p["shared"], x, act=act)

    # Switch-style load-balance aux loss: E * sum_e f_e * P_e, f_e = the
    # fraction of assignments routed to e (sums to 1 across experts), so
    # uniform routing gives aux == 1 and imbalance grows it.
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.float32)
    frac = jnp.mean(oh, axis=(0, 1)) * E
    mean_prob = jnp.mean(probs, axis=(0, 1)) * E
    aux = jnp.mean(frac * mean_prob)
    return y, aux
