"""Top-level language model: stage-compressed layer stacks under ``lax.scan``.

The layer stack is partitioned into *stages*: (pattern, repeats) pairs where
``pattern`` is a tuple of block kinds applied sequentially inside one scan
step and ``repeats`` is the scan length.  Per-layer parameters are stacked on
a leading "layers" axis, so the lowered HLO contains each distinct block kind
exactly once regardless of depth - essential for fast 512-device AOT compiles.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.axes import constrain
from repro.models import blocks as blk
from repro.models import common as cm
from repro.models.blocks import Ctx
from repro.models.common import Builder

PyTree = Any


def make_stages(cfg: ModelConfig, num_layers: int | None = None,
                pattern: tuple[str, ...] | None = None):
    """Compress the layer-kind sequence into (pattern, repeats) stages."""
    L = num_layers if num_layers is not None else cfg.num_layers
    pat = pattern if pattern is not None else cfg.pattern
    stages = []
    if pattern is None and cfg.pattern_prefix:
        stages.append((tuple(cfg.pattern_prefix), 1))
        L -= len(cfg.pattern_prefix)
    p = len(pat)
    if L // p:
        stages.append((tuple(pat), L // p))
    if L % p:
        stages.append((tuple(pat[:L % p]), 1))
    return stages


def _stage_init(b: Builder, cfg: ModelConfig, pattern, repeats) -> PyTree:
    if b.mode == "axes":
        single = {str(j): blk.block_init(k, Builder("axes"), cfg)
                  for j, k in enumerate(pattern)}
        return jax.tree.map(lambda s: "layers|" + s, single)
    key = b._next_key()

    def one(k):
        bb = Builder("init", k)
        return {str(j): blk.block_init(kind, bb.child(), cfg)
                for j, kind in enumerate(pattern)}

    return jax.vmap(one)(jax.random.split(key, repeats))


def _build(cfg: ModelConfig, b: Builder) -> PyTree:
    p: dict[str, Any] = {"embed": cm.embed_init(b, cfg.vocab_size, cfg.d_model)}
    if cfg.vit_dim:
        p["vit_proj"] = cm.dense_init(b, cfg.vit_dim, cfg.d_model,
                                      (None, "embed"))
    if cfg.is_encoder_decoder:
        p["frame_proj"] = cm.dense_init(b, cfg.d_model, cfg.d_model,
                                        ("embed", "embed"))
        p["pos_embed"] = b.param((32768, cfg.d_model), (None, "embed"),
                                 scale=0.02)
        p["enc_stages"] = [
            _stage_init(b, cfg, pat, rep)
            for pat, rep in make_stages(cfg, cfg.encoder_layers, ("enc",))]
        p["enc_norm"] = blk._norm_init(b, cfg)
    p["stages"] = [_stage_init(b, cfg, pat, rep)
                   for pat, rep in make_stages(cfg)]
    if "mamba_shared" in cfg.layer_kinds:
        p["shared"] = blk.shared_block_init(b, cfg)
    p["final_norm"] = blk._norm_init(b, cfg)
    if not cfg.tie_embeddings:
        p["lm_head"] = cm.dense_init(b, cfg.d_model, cfg.vocab_size,
                                     ("embed", "vocab"))
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    return _build(cfg, Builder("init", key))


def param_axes(cfg: ModelConfig) -> PyTree:
    """Pytree (same structure as params) of '|'-joined logical axis strings."""
    return _build(cfg, Builder("axes"))


def param_shapes(cfg: ModelConfig) -> PyTree:
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params: PyTree, batch: dict) -> jax.Array:
    x = cm.embed_lookup(params["embed"], batch["tokens"])
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    if cfg.vit_dim and "patches" in batch:
        img = cm.dense(params["vit_proj"],
                       batch["patches"].astype(cm.COMPUTE_DTYPE))
        x = jnp.concatenate([img, x], axis=1)
    return x


def _run_encoder(cfg: ModelConfig, params: PyTree, frames: jax.Array, *,
                 unroll: bool = False, stats: dict | None = None):
    x = cm.dense(params["frame_proj"], frames.astype(cm.COMPUTE_DTYPE))
    pe = cm.sinusoidal_positions(x.shape[1], cfg.d_model)
    x = x + jnp.asarray(pe, x.dtype)
    B, S, _ = x.shape
    ctx = Ctx(positions=jnp.broadcast_to(jnp.arange(S), (B, S)))
    for s, (spec, sp) in enumerate(zip(
            make_stages(cfg, cfg.encoder_layers, ("enc",)),
            params["enc_stages"])):
        if stats is not None:
            x, layer_ss, _ = _stage_stats(cfg, spec, sp, x, ctx, None)
            for path, arr in layer_ss.items():
                stats[f"['enc_stages'][{s}]" + path] = arr
        else:
            x, _, _ = _stage_apply_full(
                cfg, spec, sp, x, ctx, None, remat=False,
                unroll=f"['enc_stages'][{s}]" if unroll else False)
    return blk._norm(cfg, params["enc_norm"], x)


def _stage_apply_full(cfg, spec, stage_params, x, ctx: Ctx, shared,
                      *, remat: bool, unroll: bool = False):
    pattern, repeats = spec

    def body(h, layer_p):
        aux = jnp.zeros((), jnp.float32)
        cache_out = {}
        h = constrain(h, "batch", "act_seq", None)
        for j, kind in enumerate(pattern):
            h, aux_j, c = blk.block_apply_full(kind, cfg, layer_p[str(j)], h,
                                               ctx, shared=shared)
            aux = aux + aux_j
            cache_out[str(j)] = c
        return h, (aux, cache_out)

    if unroll:  # eager per-layer execution (stats-tape calibration pass)
        from repro.core import tape as _tape
        t = _tape.current_tape()
        aux_total = jnp.zeros((), jnp.float32)
        caches = None
        for i in range(repeats):
            layer_p = jax.tree.map(lambda a: a[i], stage_params)
            if t is not None and unroll is not True:  # unroll = path prefix
                t.register_layer(layer_p, unroll, i)
            x, (aux, _) = body(x, layer_p)
            aux_total += aux
        return x, aux_total, caches
    f = jax.checkpoint(body) if remat else body
    x, (auxs, caches) = jax.lax.scan(f, x, stage_params)
    return x, jnp.sum(auxs), caches


def _stage_stats(cfg, spec, stage_params, x, ctx: Ctx, shared):
    """One scanned stage of the jitted stats pass.

    The ``lax.scan`` body installs a trace-compatible :class:`~repro.core.
    tape.JitTape` over the sliced layer tree (plus the shared block, if any)
    and returns the per-kernel input sum-of-squares as scan OUTPUTS, so the
    stacked result already carries the leading layer axis the stats tree
    needs - the whole stage lowers to one scan regardless of depth, exactly
    like the forward pass, and shards under installed rules via the same
    ``constrain`` calls the blocks already make.

    Returns (x, {relpath: (repeats, ...) sumsq}, {shared_relpath: ...}).
    """
    from repro.core import tape as _tape
    pattern, repeats = spec

    def body(h, layer_p):
        t = _tape.JitTape()
        t.register_layer(layer_p, "", 0)
        if shared is not None:
            t.register_layer(shared, "", -1)
        with _tape.recording(t):
            h = constrain(h, "batch", "act_seq", None)
            for j, kind in enumerate(pattern):
                h, _, _ = blk.block_apply_full(kind, cfg, layer_p[str(j)], h,
                                               ctx, shared=shared)
        return h, (t.stats(0), t.stats(-1))

    x, (layer_ss, shared_ss) = jax.lax.scan(body, x, stage_params)
    return x, layer_ss, shared_ss


def stats_sumsq(cfg: ModelConfig, params: PyTree, batch: dict) -> PyTree:
    """Jit-compatible stats pass: one calibration batch -> per-input-feature
    activation sum-of-squares, as a pytree matching ``params``.

    The production-scale sibling of the eager tape pass: stage-compressed
    ``lax.scan`` execution (per-layer stats stacked by the scan), traceable
    under ``jax.jit``, sharding constraints applied under installed rules.
    Covers every kernel inside the layer stacks plus the shared block;
    leaves the pass does not project through (embeddings, heads, routers,
    frame/vit projections - all non-prunable) come back None.  Accumulate
    over batches and sqrt to get the tape-identical ||X_j||_2.
    """
    by_path: dict[str, jax.Array] = {}
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(cfg, params, batch["frames"], stats=by_path)
        x = x + params["pos_embed"][:S].astype(x.dtype)[None]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ctx = Ctx(positions=pos, encoder_out=enc_out)
    shared = params.get("shared")
    shared_acc: dict[str, jax.Array] = {}
    for s, (spec, sp) in enumerate(zip(make_stages(cfg), params["stages"])):
        x, layer_ss, shared_ss = _stage_stats(cfg, spec, sp, x, ctx, shared)
        for path, arr in layer_ss.items():
            by_path[f"['stages'][{s}]" + path] = arr
        for path, arr in shared_ss.items():  # stacked over layers: reduce
            arr = jnp.sum(arr, axis=0)
            prev = shared_acc.get(path)
            shared_acc[path] = arr if prev is None else prev + arr
    for path, arr in shared_acc.items():
        by_path["['shared']" + path] = arr

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = [by_path.get(jax.tree_util.keystr(kp)) for kp, _ in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def forward(cfg: ModelConfig, params: PyTree, batch: dict, *,
            remat: bool = False, cache_capacity: int = 0,
            unroll: bool = False):
    """Full forward. Returns (logits fp32, aux, caches)."""
    if unroll:
        from repro.core import tape as _tape
        t = _tape.current_tape()
        if t is not None:  # unstacked leaves (embed, shared block, ...)
            t.register_layer(params, "", -1)
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(cfg, params, batch["frames"], unroll=unroll)
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = x + params["pos_embed"][:S].astype(x.dtype)[None]
    else:
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ctx = Ctx(positions=pos, cache_capacity=cache_capacity,
              encoder_out=enc_out)
    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    shared = params.get("shared")
    for s, (spec, sp) in enumerate(zip(make_stages(cfg), params["stages"])):
        x, aux, cache = _stage_apply_full(
            cfg, spec, sp, x, ctx, shared,
            remat=remat and not cache_capacity,
            unroll=f"['stages'][{s}]" if unroll else False)
        aux_total += aux
        caches.append(cache)
    x = blk._norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, aux_total, caches


def _unembed(cfg: ModelConfig, params: PyTree, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = cm.unembed(params["embed"], x)
    else:
        logits = cm.dense(params["lm_head"], x).astype(jnp.float32)
    if cfg.final_softcap:
        logits = cm.softcap(logits, cfg.final_softcap)
    return constrain(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_caches(cfg: ModelConfig, batch: int, capacity: int,
                enc_len: int = 0) -> list:
    caches = []
    for pattern, repeats in make_stages(cfg):
        single = {str(j): blk.block_init_cache(k, cfg, batch, capacity, enc_len)
                  for j, k in enumerate(pattern)}
        caches.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (repeats,) + a.shape), single))
    return caches


def prefill(cfg: ModelConfig, params: PyTree, batch: dict, *,
            cache_capacity: int):
    """Process a prompt, fill KV caches, return last-position logits."""
    logits, _, caches = forward(cfg, params, batch,
                                cache_capacity=cache_capacity)
    return logits[:, -1], caches


def decode_step(cfg: ModelConfig, params: PyTree, token: jax.Array,
                caches: list, t: jax.Array, *, seq_sharded: bool = False):
    """One decode step.  token: (B,) int32; t: position index - a scalar
    (whole batch in lockstep) or a (B,) vector of per-row positions (the
    serve engine's fused batched decode: one invocation advances every slot
    at its own position, ring writes and attention masks row-local)."""
    batch = {"tokens": token[:, None]}
    x = cm.embed_lookup(params["embed"], batch["tokens"])
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    if cfg.is_encoder_decoder:
        if jnp.ndim(t) == 1:
            pe = jnp.take(params["pos_embed"], t, axis=0)[:, None]
        else:
            pe = jax.lax.dynamic_slice_in_dim(
                params["pos_embed"], t, 1, axis=0)[None]
        x = x + pe.astype(x.dtype)
    shared = params.get("shared")
    new_caches = []
    for (pattern, repeats), sp, cache in zip(make_stages(cfg),
                                             params["stages"], caches):
        def body(h, xs):
            layer_p, layer_c = xs
            nc = {}
            for j, kind in enumerate(pattern):
                h, c = blk.block_apply_decode(
                    kind, cfg, layer_p[str(j)], h, layer_c[str(j)], t,
                    shared=shared, seq_sharded=seq_sharded)
                nc[str(j)] = c
            return h, nc

        x, nc = jax.lax.scan(body, x, (sp, cache))
        new_caches.append(nc)
    x = blk._norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits[:, 0], new_caches


def verify_step(cfg: ModelConfig, params: PyTree, tokens: jax.Array,
                caches: list, t: jax.Array, *, seq_sharded: bool = False):
    """Teacher-forced S-token decode in ONE batched pass (spec verify).

    tokens: (B, S) int32 - S fed tokens per row; t: (B,) per-row start
    positions.  Column i's logits are the model's continuation of the fed
    prefix ``tokens[:, :i + 1]``, bit-identical to feeding the same tokens
    through ``decode_step`` one at a time (write-then-attend ring updates,
    per-query position masks; see ``blocks.block_apply_verify``), but the
    layer op graph executes once for all S positions instead of S times -
    the verifier of ``serve.spec`` prices k draft tokens at roughly one
    decode step.  Caller guarantees max(t) + S <= cache capacity (no ring
    wrap).  Returns (logits (B, S, V), new_caches)."""
    assert not cfg.is_encoder_decoder, "spec verify is decoder-only"
    x = cm.embed_lookup(params["embed"], tokens)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    shared = params.get("shared")
    new_caches = []
    for (pattern, repeats), sp, cache in zip(make_stages(cfg),
                                             params["stages"], caches):
        def body(h, xs):
            layer_p, layer_c = xs
            nc = {}
            for j, kind in enumerate(pattern):
                h, c = blk.block_apply_verify(
                    kind, cfg, layer_p[str(j)], h, layer_c[str(j)], t,
                    shared=shared, seq_sharded=seq_sharded)
                nc[str(j)] = c
            return h, nc

        x, nc = jax.lax.scan(body, x, (sp, cache))
        new_caches.append(nc)
    x = blk._norm(cfg, params["final_norm"], x)
    return _unembed(cfg, params, x), new_caches
