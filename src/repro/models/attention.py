"""Attention: GQA + RoPE + sliding-window + softcap + QK-norm + MLA.

Three execution paths:

* ``flash_attention``   - chunked, custom-VJP, O(S) memory; used for train and
  prefill shapes (4k-32k).  Outer Python loop over query blocks (static,
  triangle-exact for causal masks), inner ``lax.scan`` over kv blocks with a
  running (m, l, acc) softmax state.  The backward pass recomputes logits
  flash-style, so nothing quadratic is ever saved.
* ``decode_attend``     - single-token decode against a KV cache (ring buffer
  for sliding-window layers).  For sequence-sharded caches (long-context,
  batch=1) the softmax reductions run over the sharded seq dim and GSPMD
  lowers them to tiny all-reduces - no KV all-gather.
* MLA (DeepSeek-style low-rank KV) with absorbed-matmul decode.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.axes import constrain
from repro.models import common as cm
from repro.models.common import Builder

PyTree = Any
NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Flash attention (pure-jnp, custom VJP)
# ---------------------------------------------------------------------------

def _mask_bias(qpos, kpos, *, causal: bool, window: int, kv_valid: int | None):
    """Additive mask bias (0 or NEG_INF). qpos: (Sq,), kpos: (Sk,)."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window:
        ok &= qpos[:, None] - kpos[None, :] < window
    if kv_valid is not None:
        ok &= (kpos < kv_valid)[None, :]
    return jnp.where(ok, 0.0, NEG_INF)


def _qk(q, k, scale, softcap):
    # q: (B, Sq, K, G, D)  k: (B, Sk, K, D) -> (B, K, G, Sq, Sk) fp32
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if softcap:
        s = cm.softcap(s, softcap)
    return s


def _flash_fwd_block(q_blk, k, v, *, qpos, causal, window, kv_valid, softcap,
                     scale, kv_block):
    """One query block vs all (needed) kv blocks. Returns (o, m, l)."""
    B, Sq, K, G, D = q_blk.shape
    Sk = k.shape[1]
    nkv = Sk // kv_block

    def body(carry, ikv):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ikv * kv_block, kv_block, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ikv * kv_block, kv_block, axis=1)
        kpos = ikv * kv_block + jnp.arange(kv_block)
        s = _qk(q_blk, ks, scale, softcap)
        s = s + _mask_bias(qpos, kpos, causal=causal, window=window,
                           kv_valid=kv_valid)[None, None, None]
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), vs,
                        preferred_element_type=jnp.float32)
        acc = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l, acc), None

    Dv = v.shape[-1]
    m0 = jnp.full((B, K, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Sq, K, G, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nkv))
    o = acc / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return o, m, l


def _flash_bwd_block(res, do_blk):
    """Backward for one query block. Returns (dq_blk, dk, dv) fp32 full-size."""
    (q_blk, k, v, o_blk, L_blk, qpos, causal, window, kv_valid, softcap, scale,
     kv_block) = res
    B, Sq, K, G, D = q_blk.shape
    Sk = k.shape[1]
    nkv = Sk // kv_block
    do_f = do_blk.astype(jnp.float32)
    Drow = jnp.sum(do_f * o_blk.astype(jnp.float32), axis=-1)  # (B,Sq,K,G)
    Drow = Drow.transpose(0, 2, 3, 1)  # (B,K,G,Sq)

    def body(carry, ikv):
        dq, dk, dv = carry
        ks = jax.lax.dynamic_slice_in_dim(k, ikv * kv_block, kv_block, axis=1)
        vs = jax.lax.dynamic_slice_in_dim(v, ikv * kv_block, kv_block, axis=1)
        kpos = ikv * kv_block + jnp.arange(kv_block)
        raw = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, ks,
                         preferred_element_type=jnp.float32) * scale
        if softcap:
            t = jnp.tanh(raw / softcap)
            s = t * softcap
        else:
            s = raw
        bias = _mask_bias(qpos, kpos, causal=causal, window=window,
                          kv_valid=kv_valid)[None, None, None]
        p = jnp.exp(s + bias - L_blk[..., None])  # (B,K,G,Sq,Sk)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", do_f, vs.astype(jnp.float32))
        dvs = jnp.einsum("bkgqs,bqkgd->bskd", p, do_f)
        ds = p * (dp - Drow[..., None])
        if softcap:
            ds = ds * (1.0 - t * t)
        ds = ds * scale
        dq = dq + jnp.einsum("bkgqs,bskd->bqkgd", ds, ks.astype(jnp.float32))
        dks = jnp.einsum("bkgqs,bqkgd->bskd", ds, q_blk.astype(jnp.float32))
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, ikv * kv_block, kv_block, 1) + dks,
            ikv * kv_block, axis=1)
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, ikv * kv_block, kv_block, 1) + dvs,
            ikv * kv_block, axis=1)
        return (dq, dk, dv), None

    Dv = v.shape[-1]
    dq0 = jnp.zeros((B, Sq, K, G, D), jnp.float32)
    dk0 = jnp.zeros((B, Sk, K, D), jnp.float32)
    dv0 = jnp.zeros((B, Sk, K, Dv), jnp.float32)
    (dq, dk, dv), _ = jax.lax.scan(body, (dq0, dk0, dv0), jnp.arange(nkv))
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, kv_valid, softcap, scale, q_block, kv_block):
    out, _ = _flash_fwd(q, k, v, causal, window, kv_valid, softcap, scale,
                        q_block, kv_block)
    return out


def _flash_fwd(q, k, v, causal, window, kv_valid, softcap, scale, q_block, kv_block):
    B, Sq, K, G, D = q.shape
    Sk = k.shape[1]
    os, Ls = [], []
    for iq in range(Sq // q_block):
        qpos = (Sk - Sq) + iq * q_block + jnp.arange(q_block)
        q_blk = q[:, iq * q_block:(iq + 1) * q_block]
        # causal: only kv blocks whose start can be visible (static bound)
        if causal:
            hi = min(Sk, (Sk - Sq) + (iq + 1) * q_block)
            nkv = -(-hi // kv_block)
        else:
            nkv = Sk // kv_block
        o, m, l = _flash_fwd_block(
            q_blk, k[:, :nkv * kv_block], v[:, :nkv * kv_block], qpos=qpos,
            causal=causal, window=window, kv_valid=kv_valid, softcap=softcap,
            scale=scale, kv_block=kv_block)
        os.append(o)
        Ls.append(m + jnp.log(jnp.maximum(l, 1e-30)))
    out = jnp.concatenate(os, axis=1).astype(q.dtype)
    L = jnp.concatenate(Ls, axis=3)  # (B,K,G,Sq)
    return out, (q, k, v, out, L)


def _flash_bwd(causal, window, kv_valid, softcap, scale, q_block, kv_block,
               res, do):
    q, k, v, out, L = res
    B, Sq, K, G, D = q.shape
    Sk = k.shape[1]
    dqs = []
    dk = jnp.zeros((B, Sk, K, D), jnp.float32)
    dv = jnp.zeros((B, Sk, K, v.shape[-1]), jnp.float32)
    for iq in range(Sq // q_block):
        sl = slice(iq * q_block, (iq + 1) * q_block)
        qpos = (Sk - Sq) + iq * q_block + jnp.arange(q_block)
        if causal:
            hi = min(Sk, (Sk - Sq) + (iq + 1) * q_block)
            nkv = -(-hi // kv_block)
        else:
            nkv = Sk // kv_block
        n = nkv * kv_block
        dq_blk, dk_p, dv_p = _flash_bwd_block(
            (q[:, sl], k[:, :n], v[:, :n], out[:, sl], L[:, :, :, sl], qpos,
             causal, window, kv_valid, softcap, scale, kv_block), do[:, sl])
        dqs.append(dq_blk)
        dk = dk.at[:, :n].add(dk_p)
        dv = dv.at[:, :n].add(dv_p)
    dq = jnp.concatenate(dqs, axis=1).astype(q.dtype)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal=True, window=0, kv_valid=None,
                    attn_softcap=0.0, scale=None, q_block=None, kv_block=None):
    """q: (B,Sq,H,D) or (B,Sq,K,G,D); k,v: (B,Sk,K,D). Returns (B,Sq,H,D)."""
    squeeze = q.ndim == 4
    if squeeze:
        B, Sq, H, D = q.shape
        K = k.shape[2]
        q = q.reshape(B, Sq, K, H // K, D)
    B, Sq, K, G, D = q.shape
    Sk = k.shape[1]
    scale = D ** -0.5 if scale is None else scale
    q_block = q_block or min(512, Sq)
    kv_block = kv_block or min(512, Sk)
    assert Sq % q_block == 0 and Sk % kv_block == 0, (Sq, q_block, Sk, kv_block)
    out = _flash(q, k, v, causal, window, kv_valid, attn_softcap, scale,
                 q_block, kv_block)
    return out.reshape(B, Sq, K * G, v.shape[-1]) if squeeze else out


def reference_attention(q, k, v, *, causal=True, window=0, kv_valid=None,
                        attn_softcap=0.0, scale=None):
    """Materialized-logits oracle for tests."""
    B, Sq, H, D = q.shape
    K = k.shape[2]
    qg = q.reshape(B, Sq, K, H // K, D)
    scale = D ** -0.5 if scale is None else scale
    s = _qk(qg, k, scale, attn_softcap)
    Sk = k.shape[1]
    qpos = (Sk - Sq) + jnp.arange(Sq)
    s = s + _mask_bias(qpos, jnp.arange(Sk), causal=causal, window=window,
                       kv_valid=kv_valid)[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, v.shape[-1])


# ---------------------------------------------------------------------------
# Standard attention module (init/apply)
# ---------------------------------------------------------------------------

def attn_init(b: Builder, *, d_model: int, num_heads: int, num_kv: int,
              head_dim: int, qk_norm: bool = False) -> PyTree:
    p = {
        "wq": cm.dense_init(b, d_model, num_heads * head_dim, ("embed", "qkv")),
        "wk": cm.dense_init(b, d_model, num_kv * head_dim, ("embed", "qkv")),
        "wv": cm.dense_init(b, d_model, num_kv * head_dim, ("embed", "qkv")),
        "wo": cm.dense_init(b, num_heads * head_dim, d_model, ("qkv", "embed")),
    }
    if qk_norm:
        p["q_norm"] = {"scale": b.param((head_dim,), (None,), init="zeros")}
        p["k_norm"] = {"scale": b.param((head_dim,), (None,), init="zeros")}
    return p


def _qk_normed(p, q, k):
    if "q_norm" in p:
        q = cm.rmsnorm(p["q_norm"], q)
        k = cm.rmsnorm(p["k_norm"], k)
    return q, k


def make_kv_cache(batch: int, capacity: int, num_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> PyTree:
    return {
        "k": jnp.zeros((batch, capacity, num_kv, head_dim), dtype),
        "v": jnp.zeros((batch, capacity, num_kv, head_dim), dtype),
    }


def attn_apply_full(p: PyTree, x: jax.Array, *, positions: jax.Array,
                    num_heads: int, num_kv: int, head_dim: int,
                    rope_theta: float = 1e4, use_rope: bool = True,
                    causal: bool = True, window: int = 0,
                    attn_softcap: float = 0.0, scale: float | None = None,
                    cache_capacity: int = 0,
                    kv_override: tuple[jax.Array, jax.Array] | None = None,
                    qkv_delta=None,
                    ) -> tuple[jax.Array, PyTree | None]:
    """Train / prefill path. Returns (y, kv_cache or None)."""
    B, S, _ = x.shape
    dq = dk = dv = 0
    if qkv_delta is not None:  # LoRA deltas (zamba2 shared block)
        dq, dk, dv = qkv_delta
    q = (cm.dense(p["wq"], x) + dq).reshape(B, S, num_heads, head_dim)
    if kv_override is None:
        k = (cm.dense(p["wk"], x) + dk).reshape(B, S, num_kv, head_dim)
        v = (cm.dense(p["wv"], x) + dv).reshape(B, S, num_kv, head_dim)
    else:  # cross-attention: kv computed from encoder output elsewhere
        k, v = kv_override
    q, k = _qk_normed(p, q, k)
    if use_rope:
        q = cm.rope(q, positions, theta=rope_theta)
        if kv_override is None:
            k = cm.rope(k, positions, theta=rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    k = constrain(k, "batch", "seq", "kv_heads", None)
    v = constrain(v, "batch", "seq", "kv_heads", None)
    o = flash_attention(q, k, v, causal=causal, window=window,
                        attn_softcap=attn_softcap, scale=scale)
    o = constrain(o, "batch", "seq", "heads", None)
    y = cm.dense(p["wo"], o.reshape(B, S, num_heads * head_dim))
    cache = None
    if cache_capacity:
        C = min(cache_capacity, window) if window else cache_capacity
        cache = {"k": constrain(_ring_store(k, C), "batch", "kv_seq",
                                "kv_heads", None),
                 "v": constrain(_ring_store(v, C), "batch", "kv_seq",
                                "kv_heads", None)}
    return y, cache


def _ring_store(x: jax.Array, capacity: int) -> jax.Array:
    """Store the last min(S, C) tokens of x (B, S, ...) into ring slots p % C."""
    B, S = x.shape[:2]
    n = min(S, capacity)
    pos = jnp.arange(S - n, S)
    last = x[:, S - n:]
    buf = jnp.zeros((B, capacity) + x.shape[2:], jnp.bfloat16)
    return buf.at[:, pos % capacity].set(last.astype(jnp.bfloat16))


def ring_slot(t: jax.Array, capacity: int) -> jax.Array:
    return jnp.mod(t, capacity)


def ring_positions(t: jax.Array, capacity: int) -> jax.Array:
    """Position stored in each ring slot after writing token t at t%C.

    Slot j holds the latest position p <= t with p % C == j (or is empty,
    encoded as p > t via a large value, never matches the mask).  t may be a
    scalar (-> (C,)) or a per-row position vector (B,) (-> (B, C)), the
    batched-decode case where every row sits at its own position.
    """
    j = jnp.arange(capacity)
    tt = jnp.asarray(t, jnp.int32)[..., None]    # () -> (1,) | (B,) -> (B,1)
    p = tt - jnp.mod(tt - j, capacity)           # broadcasts to (C,) | (B,C)
    return jnp.where(p >= 0, p, tt + 1 + capacity)  # invalid -> masked out


def decode_attend(q, cache_k, cache_v, kpos, t, *, attn_softcap=0.0,
                  scale=None, window=0, seq_sharded: bool = False):
    """One-token attention against a cache.

    q: (B, H, D); cache_k/v: (B, C, K, D); kpos: global position of each
    slot, (C,) shared across the batch or (B, C) per row; t: current
    position, scalar or (B,) per row (fused batched decode).  Valid slots:
    kpos <= t and (window).
    """
    B, H, D = q.shape
    K = cache_k.shape[2]
    G = H // K
    scale = D ** -0.5 if scale is None else scale
    qg = q.reshape(B, K, G, D)
    if not attn_softcap and not seq_sharded:
        # Tensor-parallel serving (rules installed, capacity-sharded cache
        # per dist.sharding.cache_sharding): run the partial softmax
        # shard-mapped over the capacity axis with an explicit pmax/psum
        # combine, so decode never gathers the KV cache or falls back to a
        # replicated layout.  No-op (empty axes) off the mesh.
        from repro.kernels import shard as ksh
        kv_axes = ksh.kv_shard_axes(B, cache_k.shape[1])
        if kv_axes:
            kb_s = kpos if kpos.ndim == 2 else kpos[None]
            tq_s = jnp.asarray(t, jnp.int32)
            tb_s = tq_s[:, None] if tq_s.ndim == 1 else tq_s
            valid = kb_s <= tb_s
            if window:
                valid &= tb_s - kb_s < window
            valid = jnp.broadcast_to(valid, (B, cache_k.shape[1]))
            o = ksh.decode_attend_sharded(qg, cache_k, cache_v, valid,
                                          axes=kv_axes, scale=scale)
            return o.reshape(B, H, D).astype(q.dtype)
    seq_ax = "kv_seq" if seq_sharded else None
    ck = constrain(cache_k, "batch", seq_ax, "kv_heads", None)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, ck,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap:
        s = cm.softcap(s, attn_softcap)
    kb = kpos if kpos.ndim == 2 else kpos[None]             # (1|B, C)
    tq = jnp.asarray(t, jnp.int32)
    tb = tq[:, None] if tq.ndim == 1 else tq                # (B, 1) | ()
    ok = kb <= tb
    if window:
        ok &= tb - kb < window
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    s = constrain(s, "batch", "kv_heads", None, seq_ax)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    cv = constrain(cache_v, "batch", seq_ax, "kv_heads", None)
    o = jnp.einsum("bkgc,bckd->bkgd", (p / l).astype(cache_v.dtype), cv,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, D).astype(q.dtype)


def attn_apply_decode(p: PyTree, x: jax.Array, cache: PyTree, t: jax.Array, *,
                      num_heads: int, num_kv: int, head_dim: int,
                      rope_theta: float = 1e4, use_rope: bool = True,
                      window: int = 0, attn_softcap: float = 0.0,
                      scale: float | None = None, seq_sharded: bool = False,
                      update_cache: bool = True, qkv_delta=None,
                      ) -> tuple[jax.Array, PyTree]:
    """Decode one token per row.  x: (B, 1, d); t: position of this token,
    scalar (whole batch in lockstep) or (B,) (fused batched decode - each
    row writes its own ring slot and masks at its own position)."""
    B, S, _ = x.shape
    assert S == 1
    C = cache["k"].shape[1]
    dq = dk = dv = 0
    if qkv_delta is not None:
        dq, dk, dv = qkv_delta
    q = (cm.dense(p["wq"], x) + dq).reshape(B, 1, num_heads, head_dim)
    k = (cm.dense(p["wk"], x) + dk).reshape(B, 1, num_kv, head_dim)
    v = (cm.dense(p["wv"], x) + dv).reshape(B, 1, num_kv, head_dim)
    q, k = _qk_normed(p, q, k)
    per_row = jnp.ndim(t) == 1
    pos = (jnp.asarray(t, jnp.int32)[:, None] if per_row
           else jnp.full((B, 1), t, jnp.int32))
    if use_rope:
        q = cm.rope(q, pos, theta=rope_theta)
        k = cm.rope(k, pos, theta=rope_theta)
    if update_cache:
        slot = ring_slot(t, C)
        if per_row:  # row b writes its own ring slot t[b] % C
            rows = jnp.arange(B)
            cache = {
                "k": cache["k"].at[rows, slot].set(
                    k[:, 0].astype(cache["k"].dtype)),
                "v": cache["v"].at[rows, slot].set(
                    v[:, 0].astype(cache["v"].dtype)),
            }
        else:
            cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), slot, axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), slot, axis=1),
            }
    kpos = ring_positions(t, C)
    o = decode_attend(q[:, 0], cache["k"], cache["v"], kpos, t,
                      attn_softcap=attn_softcap, scale=scale, window=window,
                      seq_sharded=seq_sharded)
    y = cm.dense(p["wo"], o.reshape(B, 1, num_heads * head_dim))
    return y, cache


def attn_apply_verify(p: PyTree, x: jax.Array, cache: PyTree, t: jax.Array, *,
                      num_heads: int, num_kv: int, head_dim: int,
                      rope_theta: float = 1e4, use_rope: bool = True,
                      attn_softcap: float = 0.0, scale: float | None = None,
                      seq_sharded: bool = False) -> tuple[jax.Array, PyTree]:
    """Teacher-forced S-token decode in ONE pass (speculative verify).

    x: (B, S, d) - S fed tokens per row; t: (B,) per-row start positions,
    so row b's token i sits at position t[b] + i.  All S ring rows are
    written FIRST, then every query attends over the full ring with the
    per-query mask kpos <= t + i: later chunk rows hold positions > t + i,
    so in-chunk causality falls out of the same position mask sequential
    decode uses - no separate triangular mask, and the output column i is
    bit-identical to what ``attn_apply_decode`` would produce after feeding
    tokens 0..i one at a time.  The caller must guarantee max(t) + S <=
    capacity (no ring wrap, ``serve.spec`` clamps k accordingly); a wrap
    would evict a row some earlier in-chunk query still needs.  Windowed
    (ring-capped) caches are excluded for the same reason.
    """
    B, S, _ = x.shape
    C = cache["k"].shape[1]
    q = cm.dense(p["wq"], x).reshape(B, S, num_heads, head_dim)
    k = cm.dense(p["wk"], x).reshape(B, S, num_kv, head_dim)
    v = cm.dense(p["wv"], x).reshape(B, S, num_kv, head_dim)
    q, k = _qk_normed(p, q, k)
    pos = jnp.asarray(t, jnp.int32)[:, None] + jnp.arange(S)      # (B, S)
    if use_rope:
        q = cm.rope(q, pos, theta=rope_theta)
        k = cm.rope(k, pos, theta=rope_theta)
    rows = jnp.arange(B)[:, None]
    slot = ring_slot(pos, C)
    cache = {
        "k": cache["k"].at[rows, slot].set(k.astype(cache["k"].dtype)),
        "v": cache["v"].at[rows, slot].set(v.astype(cache["v"].dtype)),
    }
    K, G = num_kv, num_heads // num_kv
    scale = head_dim ** -0.5 if scale is None else scale
    qg = q.reshape(B, S, K, G, head_dim)
    seq_ax = "kv_seq" if seq_sharded else None
    ck = constrain(cache["k"], "batch", seq_ax, "kv_heads", None)
    s = jnp.einsum("bqkgd,bckd->bkgqc", qg, ck,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap:
        s = cm.softcap(s, attn_softcap)
    kpos = ring_positions(pos[:, -1], C)                          # (B, C)
    ok = kpos[:, None, :] <= pos[:, :, None]                      # (B, S, C)
    s = jnp.where(ok[:, None, None], s, NEG_INF)
    s = constrain(s, "batch", "kv_heads", None, None, seq_ax)
    m = jnp.max(s, axis=-1, keepdims=True)
    pr = jnp.exp(s - m)
    l = jnp.sum(pr, axis=-1, keepdims=True)
    cv = constrain(cache["v"], "batch", seq_ax, "kv_heads", None)
    o = jnp.einsum("bkgqc,bckd->bqkgd", (pr / l).astype(cache["v"].dtype), cv,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, S, num_heads * head_dim).astype(x.dtype)
    return cm.dense(p["wo"], o), cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(b: Builder, *, d_model: int, num_heads: int, kv_lora: int,
             nope_dim: int = 128, rope_dim: int = 64, v_dim: int = 128) -> PyTree:
    return {
        "wq": cm.dense_init(b, d_model, num_heads * (nope_dim + rope_dim),
                            ("embed", "qkv")),
        "w_dkv": cm.dense_init(b, d_model, kv_lora + rope_dim, ("embed", None)),
        "kv_norm": {"scale": b.param((kv_lora,), (None,), init="zeros")},
        "w_uk": cm.dense_init(b, kv_lora, num_heads * nope_dim, (None, "qkv")),
        "w_uv": cm.dense_init(b, kv_lora, num_heads * v_dim, (None, "qkv")),
        "wo": cm.dense_init(b, num_heads * v_dim, d_model, ("qkv", "embed")),
    }


def mla_apply_full(p: PyTree, x: jax.Array, *, positions, num_heads: int,
                   kv_lora: int, nope_dim: int = 128, rope_dim: int = 64,
                   v_dim: int = 128, rope_theta: float = 1e4,
                   cache_capacity: int = 0) -> tuple[jax.Array, PyTree | None]:
    B, S, _ = x.shape
    H = num_heads
    q = cm.dense(p["wq"], x).reshape(B, S, H, nope_dim + rope_dim)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    q_rope = cm.rope(q_rope, positions, theta=rope_theta)
    ckr = cm.dense(p["w_dkv"], x)
    c_kv = cm.rmsnorm(p["kv_norm"], ckr[..., :kv_lora])
    k_rope = cm.rope(ckr[..., kv_lora:][:, :, None, :], positions,
                     theta=rope_theta)  # (B,S,1,rope_dim) shared head
    k_nope = cm.dense(p["w_uk"], c_kv).reshape(B, S, H, nope_dim)
    v = cm.dense(p["w_uv"], c_kv).reshape(B, S, H, v_dim)
    # combined head_dim attention: concat nope|rope with k_rope broadcast
    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    kc = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope_dim))],
                         axis=-1)
    scale = (nope_dim + rope_dim) ** -0.5
    qc = constrain(qc, "batch", "seq", "heads", None)
    kc = constrain(kc, "batch", "seq", "heads", None)
    v = constrain(v, "batch", "seq", "heads", None)
    o = flash_attention(qc, kc, v, causal=True, scale=scale)
    y = cm.dense(p["wo"], o.reshape(B, S, H * v_dim))
    cache = None
    if cache_capacity:
        cache = {"ckv": constrain(_ring_store(c_kv, cache_capacity),
                                  "batch", "kv_seq", None),
                 "krope": constrain(_ring_store(k_rope[:, :, 0],
                                                cache_capacity),
                                    "batch", "kv_seq", None)}
    return y, cache


def mla_apply_decode(p: PyTree, x: jax.Array, cache: PyTree, t: jax.Array, *,
                     num_heads: int, kv_lora: int, nope_dim: int = 128,
                     rope_dim: int = 64, v_dim: int = 128,
                     rope_theta: float = 1e4, seq_sharded: bool = False,
                     ) -> tuple[jax.Array, PyTree]:
    """Absorbed-matmul decode: attention runs in the compressed c-space.

    t: scalar or (B,) per-row positions (fused batched decode)."""
    B, S, _ = x.shape
    assert S == 1
    H = num_heads
    C = cache["ckv"].shape[1]
    per_row = jnp.ndim(t) == 1
    q = cm.dense(p["wq"], x).reshape(B, 1, H, nope_dim + rope_dim)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    pos = (jnp.asarray(t, jnp.int32)[:, None] if per_row
           else jnp.full((B, 1), t, jnp.int32))
    q_rope = cm.rope(q_rope, pos, theta=rope_theta)[:, 0]  # (B,H,rope)
    ckr = cm.dense(p["w_dkv"], x)
    c_new = cm.rmsnorm(p["kv_norm"], ckr[..., :kv_lora])
    k_rope_new = cm.rope(ckr[..., kv_lora:][:, :, None, :], pos,
                         theta=rope_theta)[:, 0, 0]  # (B,rope)
    slot = ring_slot(t, C)
    if per_row:  # row b writes its own ring slot t[b] % C
        rows = jnp.arange(B)
        cache = {
            "ckv": cache["ckv"].at[rows, slot].set(
                c_new[:, 0].astype(cache["ckv"].dtype)),
            "krope": cache["krope"].at[rows, slot].set(
                k_rope_new.astype(cache["krope"].dtype)),
        }
    else:
        cache = {
            "ckv": jax.lax.dynamic_update_slice_in_dim(
                cache["ckv"], c_new.astype(cache["ckv"].dtype), slot, axis=1),
            "krope": jax.lax.dynamic_update_slice_in_dim(
                cache["krope"],
                k_rope_new[:, None].astype(cache["krope"].dtype),
                slot, axis=1),
        }
    # absorb W_uk into q: q_c (B,H,r)
    w_uk = cm.kernel_dense(p["w_uk"]).astype(jnp.float32).reshape(
        kv_lora, H, nope_dim)
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32), w_uk)
    seq_ax = "kv_seq" if seq_sharded else None
    ckv = constrain(cache["ckv"], "batch", seq_ax, None)
    krope = constrain(cache["krope"], "batch", seq_ax, None)
    s = jnp.einsum("bhr,bcr->bhc", q_c.astype(jnp.bfloat16), ckv,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhr,bcr->bhc", q_rope.astype(jnp.bfloat16), krope,
                       preferred_element_type=jnp.float32)
    s = s * (nope_dim + rope_dim) ** -0.5
    kpos = ring_positions(t, C)                              # (C,) | (B,C)
    kb = kpos if kpos.ndim == 2 else kpos[None]
    tb = pos if per_row else jnp.asarray(t, jnp.int32)       # (B,1) | ()
    s = jnp.where((kb <= tb)[:, None, :], s, NEG_INF)
    s = constrain(s, "batch", "heads", seq_ax)
    p_attn = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhc,bcr->bhr", p_attn.astype(jnp.bfloat16), ckv,
                     preferred_element_type=jnp.float32)  # (B,H,r)
    w_uv = cm.kernel_dense(p["w_uv"]).astype(jnp.float32).reshape(
        kv_lora, H, v_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_c, w_uv)
    y = cm.dense(p["wo"], o.reshape(B, 1, H * v_dim).astype(jnp.bfloat16))
    return y, cache


def mla_apply_verify(p: PyTree, x: jax.Array, cache: PyTree, t: jax.Array, *,
                     num_heads: int, kv_lora: int, nope_dim: int = 128,
                     rope_dim: int = 64, v_dim: int = 128,
                     rope_theta: float = 1e4, seq_sharded: bool = False,
                     ) -> tuple[jax.Array, PyTree]:
    """Teacher-forced S-token absorbed-matmul decode (speculative verify).

    Same write-then-attend discipline as ``attn_apply_verify``: all S
    c-space rows land in the ring first, each query i masks kpos <= t + i.
    Caller guarantees max(t) + S <= capacity (no ring wrap)."""
    B, S, _ = x.shape
    H = num_heads
    C = cache["ckv"].shape[1]
    pos = jnp.asarray(t, jnp.int32)[:, None] + jnp.arange(S)      # (B, S)
    q = cm.dense(p["wq"], x).reshape(B, S, H, nope_dim + rope_dim)
    q_nope, q_rope = q[..., :nope_dim], q[..., nope_dim:]
    q_rope = cm.rope(q_rope, pos, theta=rope_theta)               # (B,S,H,r)
    ckr = cm.dense(p["w_dkv"], x)
    c_new = cm.rmsnorm(p["kv_norm"], ckr[..., :kv_lora])          # (B,S,kv)
    k_rope_new = cm.rope(ckr[..., kv_lora:][:, :, None, :], pos,
                         theta=rope_theta)[:, :, 0]               # (B,S,r)
    rows = jnp.arange(B)[:, None]
    slot = ring_slot(pos, C)
    cache = {
        "ckv": cache["ckv"].at[rows, slot].set(
            c_new.astype(cache["ckv"].dtype)),
        "krope": cache["krope"].at[rows, slot].set(
            k_rope_new.astype(cache["krope"].dtype)),
    }
    w_uk = cm.kernel_dense(p["w_uk"]).astype(jnp.float32).reshape(
        kv_lora, H, nope_dim)
    q_c = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32), w_uk)
    seq_ax = "kv_seq" if seq_sharded else None
    ckv = constrain(cache["ckv"], "batch", seq_ax, None)
    krope = constrain(cache["krope"], "batch", seq_ax, None)
    s = jnp.einsum("bshr,bcr->bshc", q_c.astype(jnp.bfloat16), ckv,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bshr,bcr->bshc", q_rope.astype(jnp.bfloat16), krope,
                       preferred_element_type=jnp.float32)
    s = s * (nope_dim + rope_dim) ** -0.5
    kpos = ring_positions(pos[:, -1], C)                          # (B, C)
    ok = kpos[:, None, :] <= pos[:, :, None]                      # (B, S, C)
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)
    s = constrain(s, "batch", None, "heads", seq_ax)
    p_attn = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bshc,bcr->bshr", p_attn.astype(jnp.bfloat16), ckv,
                     preferred_element_type=jnp.float32)
    w_uv = cm.kernel_dense(p["w_uv"]).astype(jnp.float32).reshape(
        kv_lora, H, v_dim)
    o = jnp.einsum("bshr,rhd->bshd", o_c, w_uv)
    y = cm.dense(p["wo"], o.reshape(B, S, H * v_dim).astype(jnp.bfloat16))
    return y, cache
