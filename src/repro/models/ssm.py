"""Mamba-2 (SSD) block: chunked-parallel training form + O(1) decode step.

Chunked SSD (Dao & Gu, arXiv:2405.21060): within a chunk the output is a
masked quadratic form (attention-like, cost S*L per token); across chunks a
short scan propagates the (heads, head_dim, state) SSM state.  This keeps the
largest intermediate at (B, n_chunks, L, L) instead of (B, S, heads, hd, ds).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.axes import constrain
from repro.models import common as cm
from repro.models.common import Builder

PyTree = Any


def mamba2_init(b: Builder, *, d_model: int, d_inner: int, d_state: int,
                head_dim: int = 64, conv_width: int = 4) -> PyTree:
    nh = d_inner // head_dim
    conv_ch = d_inner + 2 * d_state
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (ds), C (ds), dt (nh)]
        "in_proj": cm.dense_init(b, d_model, 2 * d_inner + 2 * d_state + nh,
                                 ("embed", "ssm")),
        "conv": {"kernel": b.param((conv_width, conv_ch), (None, "ssm"),
                                   scale=conv_width ** -0.5),
                 "bias": b.param((conv_ch,), ("ssm",), init="zeros")},
        "A_log": b.param((nh,), (None,), init="uniform", scale=1.0),
        "dt_bias": b.param((nh,), (None,), init="zeros"),
        "D": b.param((nh,), (None,), init="ones"),
        "norm": {"scale": b.param((d_inner,), ("ssm",), init="zeros")},
        "out_proj": cm.dense_init(b, d_inner, d_model, ("ssm", "embed")),
    }


def _split(p, x, d_inner, d_state, nh):
    zxbcdt = cm.dense(p["in_proj"], x)
    z = zxbcdt[..., :d_inner]
    xin = zxbcdt[..., d_inner:2 * d_inner]
    Bm = zxbcdt[..., 2 * d_inner:2 * d_inner + d_state]
    Cm = zxbcdt[..., 2 * d_inner + d_state:2 * d_inner + 2 * d_state]
    dt = zxbcdt[..., 2 * d_inner + 2 * d_state:]
    return z, xin, Bm, Cm, dt


def _conv_full(p, u):
    """Causal conv1d over sequence. u: (B, S, C)."""
    w = p["conv"]["kernel"].astype(u.dtype)  # (W, C)
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + u.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out + p["conv"]["bias"].astype(u.dtype))


def _gated_out(p, y, z, d_inner):
    y = cm.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return cm.dense(p["out_proj"], y)


def mamba2_apply_full(p: PyTree, x: jax.Array, *, d_inner: int, d_state: int,
                      head_dim: int = 64, chunk: int = 256,
                      return_state: bool = False,
                      ) -> tuple[jax.Array, PyTree | None]:
    B, S_real, _ = x.shape
    nh = d_inner // head_dim
    z, xin, Bm, Cm, dt = _split(p, x, d_inner, d_state, nh)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_out = _conv_full(p, conv_in)
    xin = conv_out[..., :d_inner]
    Bm = conv_out[..., d_inner:d_inner + d_state]
    Cm = conv_out[..., d_inner + d_state:]

    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (nh,) negative
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    dt = jnp.clip(dt, 1e-4, 10.0)

    # pad to a chunk multiple with dt=0 steps (a=1, zero input: state no-op)
    chunk = min(chunk, S_real)
    S = -(-S_real // chunk) * chunk
    if S != S_real:
        pad = ((0, 0), (0, S - S_real), (0, 0))
        xin, Bm, Cm = jnp.pad(xin, pad), jnp.pad(Bm, pad), jnp.pad(Cm, pad)
        dt = jnp.pad(dt, pad)  # dt=0 on padded steps
    nc = S // chunk
    xh = xin.reshape(B, nc, chunk, nh, head_dim).astype(jnp.float32)
    Bc = Bm.reshape(B, nc, chunk, d_state).astype(jnp.float32)
    Cc = Cm.reshape(B, nc, chunk, d_state).astype(jnp.float32)
    dtc = dt.reshape(B, nc, chunk, nh)

    loga = dtc * A  # (B,nc,L,nh) log decay per step
    cum = jnp.cumsum(loga, axis=2)  # l_t inclusive
    # intra-chunk: y[t] = sum_{i<=t} exp(l_t - l_i) dt_i (C_t.B_i) x_i
    G = jnp.einsum("bcln,bcsn->bcls", Cc, Bc)  # (B,nc,L,L)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # l_t - l_i (B,nc,L,L,nh)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    M = jnp.where(causal, jnp.exp(diff), 0.0) * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcls,bclsh,bcshp->bclhp", G, M, xh)

    # chunk states: S_c = sum_i exp(l_last - l_i) dt_i B_i x_i^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,L,nh)
    Sc = jnp.einsum("bcsn,bcsh,bcshp->bchpn", Bc, decay_to_end * dtc, xh)
    A_chunk = jnp.exp(cum[:, :, -1, :])  # (B,nc,nh) total chunk decay

    def comb(e1, e2):
        a1, s1 = e1
        a2, s2 = e2
        return a2 * a1, a2[..., None, None] * s1 + s2

    a_scan, s_scan = jax.lax.associative_scan(
        comb, (A_chunk.transpose(1, 0, 2), Sc.transpose(1, 0, 2, 3, 4)))
    # state BEFORE chunk c = scanned state of chunk c-1 (zero for c=0)
    H_prev = jnp.concatenate(
        [jnp.zeros_like(s_scan[:1]), s_scan[:-1]], axis=0).transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", Cc, jnp.exp(cum), H_prev)

    y = (y_intra + y_inter).reshape(B, S, nh, head_dim)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * \
        xin.reshape(B, S, nh, head_dim).astype(jnp.float32)
    y = y.reshape(B, S, d_inner)[:, :S_real].astype(x.dtype)
    out = _gated_out(p, y, z, d_inner)

    state = None
    if return_state:
        h_final = s_scan[-1]  # (B,nh,hd,ds); dt=0 padding is a state no-op
        W = p["conv"]["kernel"].shape[0]
        conv_cache = conv_in[:, S_real - (W - 1):S_real]
        state = {"h": h_final, "conv": conv_cache.astype(jnp.bfloat16)}
    return out, state


def mamba2_init_state(batch: int, *, d_inner: int, d_state: int,
                      head_dim: int = 64, conv_width: int = 4) -> PyTree:
    nh = d_inner // head_dim
    return {
        "h": jnp.zeros((batch, nh, head_dim, d_state), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_inner + 2 * d_state),
                          jnp.bfloat16),
    }


def mamba2_apply_decode(p: PyTree, x: jax.Array, state: PyTree, *,
                        d_inner: int, d_state: int, head_dim: int = 64,
                        ) -> tuple[jax.Array, PyTree]:
    """x: (B, 1, d_model). O(1) recurrent update."""
    B = x.shape[0]
    nh = d_inner // head_dim
    z, xin, Bm, Cm, dt = _split(p, x, d_inner, d_state, nh)
    u = jnp.concatenate([xin, Bm, Cm], axis=-1)[:, 0]  # (B, C)
    hist = jnp.concatenate([state["conv"].astype(u.dtype), u[:, None]], axis=1)
    w = p["conv"]["kernel"].astype(u.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + p["conv"]["bias"].astype(u.dtype)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[:, :d_inner].reshape(B, nh, head_dim).astype(jnp.float32)
    Bv = conv_out[:, d_inner:d_inner + d_state].astype(jnp.float32)
    Cv = conv_out[:, d_inner + d_state:].astype(jnp.float32)

    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    dtv = jnp.clip(dtv, 1e-4, 10.0)  # (B, nh)
    a = jnp.exp(dtv * A)  # (B, nh)
    h = state["h"] * a[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dtv, xin, Bv)
    y = jnp.einsum("bhpn,bn->bhp", h, Cv) + p["D"][None, :, None] * xin
    y = y.reshape(B, 1, d_inner).astype(x.dtype)
    out = _gated_out(p, y, z, d_inner)
    new_state = {"h": h, "conv": hist[:, 1:].astype(jnp.bfloat16)}
    return out, new_state
