"""Shared model-building utilities (pure JAX, no flax).

Parameters live in nested dicts of ``jnp`` arrays.  Every module defines its
structure once through a :class:`Builder`, which can run in three modes:

* ``init``  - draw real parameter values from a PRNG key,
* ``axes``  - emit the matching pytree of *logical axis name* tuples,

so parameter values and sharding metadata can never drift apart.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.sparse.formats import SparseTensor

PyTree = Any
COMPUTE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.float32

# shard_map was promoted out of experimental in jax 0.5.x; 0.4.x only has
# the old path.  Shared here so every call site (moe dispatch/combine,
# slstm scan) resolves the same symbol.
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map  # noqa: F401


class Builder:
    """Single-definition parameter structure builder."""

    def __init__(self, mode: str, key: jax.Array | None = None):
        assert mode in ("init", "axes")
        self.mode = mode
        self._key = key
        self._count = 0

    def _next_key(self) -> jax.Array:
        assert self._key is not None, "init mode requires a PRNG key"
        k = jax.random.fold_in(self._key, self._count)
        self._count += 1
        return k

    def child(self) -> "Builder":
        """Independent sub-builder (used for per-stage modules)."""
        if self.mode == "axes":
            return Builder("axes")
        return Builder("init", self._next_key())

    def param(
        self,
        shape: tuple[int, ...],
        axes: tuple[str | None, ...],
        *,
        init: str = "normal",
        scale: float | None = None,
        dtype=PARAM_DTYPE,
    ):
        assert len(shape) == len(axes), (shape, axes)
        if self.mode == "axes":
            # '|'-joined string leaf (tuples would be traversed as pytrees)
            return "|".join(a or "" for a in axes)
        if init == "zeros":
            return jnp.zeros(shape, dtype)
        if init == "ones":
            return jnp.ones(shape, dtype)
        if init == "normal":
            if scale is None:  # fan-in scaling
                fan_in = shape[0] if len(shape) > 1 else shape[-1]
                scale = fan_in ** -0.5
            return (scale * jax.random.truncated_normal(
                self._next_key(), -2.0, 2.0, shape, jnp.float32)).astype(dtype)
        if init == "uniform":
            s = scale if scale is not None else 1.0
            return (s * jax.random.uniform(self._next_key(), shape, jnp.float32, -1.0, 1.0)).astype(dtype)
        raise ValueError(init)


def dense_init(b: Builder, d_in: int, d_out: int, axes: tuple[str | None, str | None],
               *, scale: float | None = None) -> PyTree:
    return {"kernel": b.param((d_in, d_out), axes, scale=scale)}


def dense(params: PyTree, x: jax.Array) -> jax.Array:
    k = params["kernel"]
    if isinstance(k, SparseTensor):
        # 2:4-compressed kernel (sparse.apply.sparsify_params): route through
        # the compressed matmul.  The leaf's kernel_layout tag picks the
        # index path - packed 2-bit planes stream to the Pallas kernel as
        # stored (no host unpack), padded/int8 planes take the fallback.
        # No tape: sparse trees are serving-only.
        from repro.sparse import apply as sparse_apply
        return sparse_apply.sparse_dense(k, x)
    from repro.core import tape as _tape
    t = _tape.current_tape()
    if t is not None:
        t.record(k, x)
    return x @ k.astype(COMPUTE_DTYPE)


def expert_dense(params: PyTree, buf: jax.Array) -> jax.Array:
    """Expert-banked FFN matmul: MoE dispatch buffer (G, E, C, d_in) against
    an (E, d_in, d_out) kernel -> (G, E, C, d_out).

    The expert-bank sibling of :func:`dense`: compressed banks
    (``sparsify_params`` leaves the leading expert axis in the SparseTensor)
    route through the expert-grid ``nm_matmul_expert`` kernel; dense banks
    keep the einsum.  No tape here - ``moe_apply`` records the dispatch
    buffer itself, with routed-token counts.
    """
    k = params["kernel"]
    if isinstance(k, SparseTensor):
        from repro.sparse import apply as sparse_apply
        return sparse_apply.sparse_moe_dense(k, buf)
    return jnp.einsum("gecd,edf->gecf", buf, k.astype(COMPUTE_DTYPE))


def expert_dense_pair(p_up: PyTree, p_gate: PyTree, buf: jax.Array
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused up+gate expert-bank pair sharing the reduction dim.

    When both banks are compressed AND carry matching K-shard tags, the two
    expert-grid kernels run under one shard_map with a single deferred psum
    (one collective for the whole MoE projection group); otherwise falls
    back to two independent :func:`expert_dense` calls, preserving the
    dense-einsum and untagged-compressed paths bit-for-bit.
    """
    ku, kg = p_up["kernel"], p_gate["kernel"]
    if isinstance(ku, SparseTensor) and isinstance(kg, SparseTensor):
        from repro.kernels.shard import pair_k_sharded
        if pair_k_sharded(ku, kg):
            from repro.sparse import apply as sparse_apply
            return sparse_apply.sparse_moe_dense2(ku, kg, buf)
    return expert_dense(p_up, buf), expert_dense(p_gate, buf)


def kernel_dense(params: PyTree) -> jax.Array:
    """Dense view of a (possibly compressed) kernel param, for the few call
    sites that read weights directly (e.g. MLA absorbed-matmul decode)."""
    k = params["kernel"]
    return k.to_dense() if isinstance(k, SparseTensor) else k


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(b: Builder, dim: int) -> PyTree:
    return {"scale": b.param((dim,), ("embed_act",), init="zeros")}


def rmsnorm(params: PyTree, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) so zeros-init is identity
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(b: Builder, dim: int) -> PyTree:
    return {"scale": b.param((dim,), ("embed_act",), init="zeros"),
            "bias": b.param((dim,), ("embed_act",), init="zeros")}


def layernorm(params: PyTree, x: jax.Array, *, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + params["scale"]) + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Positional encodings / misc ops
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, *, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def sinusoidal_positions(num: int, dim: int) -> np.ndarray:
    pos = np.arange(num)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.zeros((num, dim), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return out


def embed_init(b: Builder, vocab: int, dim: int) -> PyTree:
    return {"table": b.param((vocab, dim), ("vocab", "embed"), scale=1.0)}


def embed_lookup(params: PyTree, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"].astype(COMPUTE_DTYPE), tokens, axis=0)


def unembed(params: PyTree, x: jax.Array) -> jax.Array:
    """Tied unembedding: x @ table.T -> logits (fp32)."""
    table = params["table"].astype(COMPUTE_DTYPE)
    return jnp.einsum("...d,vd->...v", x, table).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class ShapeDtype:
    shape: tuple[int, ...]
    dtype: Any

    def struct(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)
