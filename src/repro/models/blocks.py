"""Per-layer-kind block builders.

Every block kind exposes:
  init(b, cfg)                          -> params
  apply_full(cfg, p, x, ctx)            -> (x, aux, cache_entry|None)
  init_cache(cfg, batch, capacity)      -> cache entry pytree
  apply_decode(cfg, p, x, cache, t)     -> (x, new_cache)

Kinds: attn, local, moe, mla_dense, mla_moe, mamba, mamba_shared,
mlstm, slstm, enc, dec.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import common as cm
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.common import Builder
from repro.models.mlp import mlp_apply, mlp_init

PyTree = Any


@dataclasses.dataclass
class Ctx:
    """Per-call context for full (train/prefill) passes."""
    positions: jax.Array                 # (B, S)
    cache_capacity: int = 0              # 0 -> no cache output
    encoder_out: jax.Array | None = None  # whisper decoder cross-attn
    seq_sharded_kv: bool = False


def _norm_init(b: Builder, cfg: ModelConfig, dim: int | None = None) -> PyTree:
    dim = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return cm.layernorm_init(b, dim)
    return cm.rmsnorm_init(b, dim)


def _norm(cfg: ModelConfig, p: PyTree, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return cm.layernorm(p, x, eps=cfg.norm_eps)
    return cm.rmsnorm(p, x, eps=cfg.norm_eps)


def _attn_kwargs(cfg: ModelConfig, *, local: bool) -> dict:
    theta = cfg.rope_theta
    if local and cfg.local_rope_theta:
        theta = cfg.local_rope_theta
    return dict(
        num_heads=cfg.num_heads, num_kv=cfg.num_kv_heads,
        head_dim=cfg.head_dim, rope_theta=theta, use_rope=cfg.use_rope,
        window=cfg.sliding_window if local else 0,
        attn_softcap=cfg.attn_softcap,
        scale=cfg.attn_scale or None,
    )


# ---------------------------------------------------------------------------
# attention + (mlp | moe) blocks
# ---------------------------------------------------------------------------

def _tblock_init(b: Builder, cfg: ModelConfig, *, ffn: str) -> PyTree:
    p = {
        "ln1": _norm_init(b, cfg),
        "attn": attn.attn_init(b, d_model=cfg.d_model, num_heads=cfg.num_heads,
                               num_kv=cfg.num_kv_heads, head_dim=cfg.head_dim,
                               qk_norm=cfg.qk_norm),
        "ln2": _norm_init(b, cfg),
    }
    if ffn == "moe":
        p["moe"] = moe_mod.moe_init(
            b, d_model=cfg.d_model, d_ff=cfg.moe_d_ff or cfg.d_ff,
            num_experts=cfg.num_experts, num_shared=cfg.num_shared_experts,
            expert_sharded=cfg.num_experts % 16 == 0)
    else:
        p["mlp"] = mlp_init(b, cfg.d_model, cfg.d_ff)
    if cfg.sandwich_norm:
        p["post_ln1"] = _norm_init(b, cfg)
        p["post_ln2"] = _norm_init(b, cfg)
    return p


def _ffn_apply(cfg: ModelConfig, p: PyTree, x: jax.Array):
    if "moe" in p:
        y, aux = moe_mod.moe_apply(
            p["moe"], x, top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
            act=cfg.act, expert_sharded=cfg.num_experts % 16 == 0)
        return y, aux
    return mlp_apply(p["mlp"], x, act=cfg.act), jnp.zeros((), jnp.float32)


def _tblock_apply_full(cfg: ModelConfig, p: PyTree, x: jax.Array, ctx: Ctx, *,
                       local: bool, causal: bool = True):
    from repro.dist.axes import constrain
    kw = _attn_kwargs(cfg, local=local)
    a, cache = attn.attn_apply_full(
        p["attn"], _norm(cfg, p["ln1"], x), positions=ctx.positions,
        causal=causal, cache_capacity=ctx.cache_capacity, **kw)
    if cfg.sandwich_norm:
        a = _norm(cfg, p["post_ln1"], a)
    # Megatron SP: constrain block outputs back to the seq-sharded layout so
    # the TP partial-sum lowers to a reduce-scatter, not a full all-reduce.
    a = constrain(a, "batch", "act_seq", None)
    x = x + a
    f, aux = _ffn_apply(cfg, p, _norm(cfg, p["ln2"], x))
    if cfg.sandwich_norm:
        f = _norm(cfg, p["post_ln2"], f)
    f = constrain(f, "batch", "act_seq", None)
    return x + f, aux, cache


def _tblock_cache(cfg: ModelConfig, batch: int, capacity: int, *, local: bool):
    C = min(capacity, cfg.sliding_window) if (local and cfg.sliding_window) \
        else capacity
    return attn.make_kv_cache(batch, C, cfg.num_kv_heads, cfg.head_dim)


def _tblock_apply_decode(cfg: ModelConfig, p: PyTree, x, cache, t, *,
                         local: bool, seq_sharded: bool = False):
    kw = _attn_kwargs(cfg, local=local)
    a, cache = attn.attn_apply_decode(
        p["attn"], _norm(cfg, p["ln1"], x), cache, t,
        seq_sharded=seq_sharded, **kw)
    if cfg.sandwich_norm:
        a = _norm(cfg, p["post_ln1"], a)
    x = x + a
    f, _ = _ffn_apply(cfg, p, _norm(cfg, p["ln2"], x))
    if cfg.sandwich_norm:
        f = _norm(cfg, p["post_ln2"], f)
    return x + f, cache


def _tblock_apply_verify(cfg: ModelConfig, p: PyTree, x, cache, t, *,
                         seq_sharded: bool = False):
    kw = _attn_kwargs(cfg, local=False)
    assert not kw.pop("window"), "verify excludes windowed (ring-capped) kinds"
    a, cache = attn.attn_apply_verify(
        p["attn"], _norm(cfg, p["ln1"], x), cache, t,
        seq_sharded=seq_sharded, **kw)
    if cfg.sandwich_norm:
        a = _norm(cfg, p["post_ln1"], a)
    x = x + a
    f, _ = _ffn_apply(cfg, p, _norm(cfg, p["ln2"], x))
    if cfg.sandwich_norm:
        f = _norm(cfg, p["post_ln2"], f)
    return x + f, cache


# ---------------------------------------------------------------------------
# MLA blocks (deepseek)
# ---------------------------------------------------------------------------

def _mla_kwargs(cfg: ModelConfig) -> dict:
    return dict(num_heads=cfg.num_heads, kv_lora=cfg.kv_lora,
                nope_dim=cfg.qk_nope_dim, rope_dim=cfg.qk_rope_dim,
                v_dim=cfg.v_head_dim, rope_theta=cfg.rope_theta)


def _mla_block_init(b: Builder, cfg: ModelConfig, *, ffn: str) -> PyTree:
    p = {
        "ln1": _norm_init(b, cfg),
        "attn": attn.mla_init(b, d_model=cfg.d_model, num_heads=cfg.num_heads,
                              kv_lora=cfg.kv_lora, nope_dim=cfg.qk_nope_dim,
                              rope_dim=cfg.qk_rope_dim, v_dim=cfg.v_head_dim),
        "ln2": _norm_init(b, cfg),
    }
    if ffn == "moe":
        p["moe"] = moe_mod.moe_init(
            b, d_model=cfg.d_model, d_ff=cfg.moe_d_ff,
            num_experts=cfg.num_experts, num_shared=cfg.num_shared_experts,
            expert_sharded=cfg.num_experts % 16 == 0)
    else:
        p["mlp"] = mlp_init(b, cfg.d_model, cfg.d_ff)
    return p


def _mla_apply_full(cfg: ModelConfig, p: PyTree, x, ctx: Ctx):
    a, cache = attn.mla_apply_full(
        p["attn"], _norm(cfg, p["ln1"], x), positions=ctx.positions,
        cache_capacity=ctx.cache_capacity, **_mla_kwargs(cfg))
    x = x + a
    f, aux = _ffn_apply(cfg, p, _norm(cfg, p["ln2"], x))
    return x + f, aux, cache


def _mla_cache(cfg: ModelConfig, batch: int, capacity: int):
    return {"ckv": jnp.zeros((batch, capacity, cfg.kv_lora), jnp.bfloat16),
            "krope": jnp.zeros((batch, capacity, cfg.qk_rope_dim), jnp.bfloat16)}


def _mla_apply_decode(cfg: ModelConfig, p: PyTree, x, cache, t, *,
                      seq_sharded: bool = False):
    a, cache = attn.mla_apply_decode(
        p["attn"], _norm(cfg, p["ln1"], x), cache, t,
        seq_sharded=seq_sharded, **_mla_kwargs(cfg))
    x = x + a
    f, _ = _ffn_apply(cfg, p, _norm(cfg, p["ln2"], x))
    return x + f, cache


def _mla_apply_verify(cfg: ModelConfig, p: PyTree, x, cache, t, *,
                      seq_sharded: bool = False):
    a, cache = attn.mla_apply_verify(
        p["attn"], _norm(cfg, p["ln1"], x), cache, t,
        seq_sharded=seq_sharded, **_mla_kwargs(cfg))
    x = x + a
    f, _ = _ffn_apply(cfg, p, _norm(cfg, p["ln2"], x))
    return x + f, cache


# ---------------------------------------------------------------------------
# mamba blocks (+ zamba-style shared attention with per-invocation LoRA)
# ---------------------------------------------------------------------------

def _mamba_init(b: Builder, cfg: ModelConfig) -> PyTree:
    return {
        "ln": _norm_init(b, cfg),
        "mamba": ssm_mod.mamba2_init(b, d_model=cfg.d_model,
                                     d_inner=cfg.d_inner,
                                     d_state=cfg.ssm_state,
                                     head_dim=cfg.ssm_head_dim),
    }


def _lora_init(b: Builder, d_in: int, d_out: int, rank: int) -> PyTree:
    return {"a": b.param((d_in, rank), ("embed", "lora"), scale=d_in ** -0.5),
            "b": b.param((rank, d_out), ("lora", "qkv"), init="zeros")}


def _lora_apply(p: PyTree, x: jax.Array) -> jax.Array:
    return (x @ p["a"].astype(x.dtype)) @ p["b"].astype(x.dtype)


def shared_block_init(b: Builder, cfg: ModelConfig) -> PyTree:
    """The weight-shared attention+MLP block (one copy per model)."""
    return {
        "ln1": _norm_init(b, cfg),
        "attn": attn.attn_init(b, d_model=cfg.d_model, num_heads=cfg.num_heads,
                               num_kv=cfg.num_kv_heads, head_dim=cfg.head_dim),
        "ln2": _norm_init(b, cfg),
        "mlp": mlp_init(b, cfg.d_model, cfg.d_ff),
    }


def _mamba_shared_init(b: Builder, cfg: ModelConfig) -> PyTree:
    p = _mamba_init(b, cfg)
    r = cfg.lora_rank or 32
    H = cfg.num_heads * cfg.head_dim
    p["lora_q"] = _lora_init(b, cfg.d_model, H, r)
    p["lora_k"] = _lora_init(b, cfg.d_model, cfg.num_kv_heads * cfg.head_dim, r)
    p["lora_v"] = _lora_init(b, cfg.d_model, cfg.num_kv_heads * cfg.head_dim, r)
    return p


def _shared_attn_qkv_delta(p: PyTree, h: jax.Array):
    return (_lora_apply(p["lora_q"], h), _lora_apply(p["lora_k"], h),
            _lora_apply(p["lora_v"], h))


def _mamba_apply_full(cfg: ModelConfig, p: PyTree, x, ctx: Ctx, *,
                      shared: PyTree | None = None):
    y, state = ssm_mod.mamba2_apply_full(
        p["mamba"], _norm(cfg, p["ln"], x), d_inner=cfg.d_inner,
        d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk,
        return_state=ctx.cache_capacity > 0)
    x = x + y
    cache = {"mamba": state} if state is not None else None
    if shared is not None:
        h = _norm(cfg, shared["ln1"], x)
        B, S, _ = h.shape
        kw = _attn_kwargs(cfg, local=False)
        # LoRA deltas folded into q/k/v for this invocation
        dq, dk, dv = _shared_attn_qkv_delta(p, h)
        a, kvc = attn.attn_apply_full(
            shared["attn"], h, positions=ctx.positions,
            cache_capacity=ctx.cache_capacity,
            qkv_delta=(dq, dk, dv), **kw)
        x = x + a
        f = mlp_apply(shared["mlp"], _norm(cfg, shared["ln2"], x), act=cfg.act)
        x = x + f
        if cache is not None:
            cache["kv"] = kvc
    return x, jnp.zeros((), jnp.float32), cache


def _mamba_cache(cfg: ModelConfig, batch: int, capacity: int, *, shared: bool):
    c = {"mamba": ssm_mod.mamba2_init_state(
        batch, d_inner=cfg.d_inner, d_state=cfg.ssm_state,
        head_dim=cfg.ssm_head_dim)}
    if shared:
        c["kv"] = attn.make_kv_cache(batch, capacity, cfg.num_kv_heads,
                                     cfg.head_dim)
    return c


def _mamba_apply_decode(cfg: ModelConfig, p: PyTree, x, cache, t, *,
                        shared: PyTree | None = None,
                        seq_sharded: bool = False):
    y, st = ssm_mod.mamba2_apply_decode(
        p["mamba"], _norm(cfg, p["ln"], x), cache["mamba"],
        d_inner=cfg.d_inner, d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim)
    x = x + y
    new_cache = {"mamba": st}
    if shared is not None:
        h = _norm(cfg, shared["ln1"], x)
        kw = _attn_kwargs(cfg, local=False)
        dq, dk, dv = _shared_attn_qkv_delta(p, h)
        a, kvc = attn.attn_apply_decode(
            shared["attn"], h, cache["kv"], t, seq_sharded=seq_sharded,
            qkv_delta=(dq, dk, dv), **kw)
        x = x + a
        x = x + mlp_apply(shared["mlp"], _norm(cfg, shared["ln2"], x),
                          act=cfg.act)
        new_cache["kv"] = kvc
    return x, new_cache


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def _mlstm_init(b: Builder, cfg: ModelConfig) -> PyTree:
    return {"ln": _norm_init(b, cfg),
            "mlstm": xlstm_mod.mlstm_init(b, d_model=cfg.d_model,
                                          num_heads=cfg.lstm_heads,
                                          proj_factor=cfg.lstm_proj_factor)}


def _slstm_init(b: Builder, cfg: ModelConfig) -> PyTree:
    return {"ln": _norm_init(b, cfg),
            "slstm": xlstm_mod.slstm_init(b, d_model=cfg.d_model,
                                          num_heads=cfg.lstm_heads)}


# ---------------------------------------------------------------------------
# whisper encoder/decoder blocks
# ---------------------------------------------------------------------------

def _enc_init(b: Builder, cfg: ModelConfig) -> PyTree:
    return _tblock_init(b, cfg, ffn="mlp")


def _dec_init(b: Builder, cfg: ModelConfig) -> PyTree:
    p = _tblock_init(b, cfg, ffn="mlp")
    p["ln_cross"] = _norm_init(b, cfg)
    p["cross"] = attn.attn_init(b, d_model=cfg.d_model,
                                num_heads=cfg.num_heads,
                                num_kv=cfg.num_kv_heads,
                                head_dim=cfg.head_dim)
    return p


def _dec_apply_full(cfg: ModelConfig, p: PyTree, x, ctx: Ctx):
    kw = _attn_kwargs(cfg, local=False)
    a, cache = attn.attn_apply_full(
        p["attn"], _norm(cfg, p["ln1"], x), positions=ctx.positions,
        causal=True, cache_capacity=ctx.cache_capacity, **kw)
    x = x + a
    # cross attention over encoder output
    h = _norm(cfg, p["ln_cross"], x)
    enc = ctx.encoder_out
    B, Se, _ = enc.shape
    k = cm.dense(p["cross"]["wk"], enc).reshape(B, Se, cfg.num_kv_heads,
                                                cfg.head_dim)
    v = cm.dense(p["cross"]["wv"], enc).reshape(B, Se, cfg.num_kv_heads,
                                                cfg.head_dim)
    kwx = dict(kw)
    kwx["use_rope"] = False
    c, _ = attn.attn_apply_full(p["cross"], h, positions=ctx.positions,
                                causal=False, kv_override=(k, v), **kwx)
    x = x + c
    f = mlp_apply(p["mlp"], _norm(cfg, p["ln2"], x), act=cfg.act)
    if cache is not None:
        from repro.dist.axes import constrain
        cache = {"kv": cache,
                 "cross_k": constrain(k.astype(jnp.bfloat16), "batch",
                                      "kv_seq", "kv_heads", None),
                 "cross_v": constrain(v.astype(jnp.bfloat16), "batch",
                                      "kv_seq", "kv_heads", None)}
    return x + f, jnp.zeros((), jnp.float32), cache


def _dec_cache(cfg: ModelConfig, batch: int, capacity: int, enc_len: int):
    return {"kv": attn.make_kv_cache(batch, capacity, cfg.num_kv_heads,
                                     cfg.head_dim),
            "cross_k": jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                                  cfg.head_dim), jnp.bfloat16),
            "cross_v": jnp.zeros((batch, enc_len, cfg.num_kv_heads,
                                  cfg.head_dim), jnp.bfloat16)}


def _dec_apply_decode(cfg: ModelConfig, p: PyTree, x, cache, t, *,
                      seq_sharded: bool = False):
    kw = _attn_kwargs(cfg, local=False)
    a, kvc = attn.attn_apply_decode(p["attn"], _norm(cfg, p["ln1"], x),
                                    cache["kv"], t, seq_sharded=seq_sharded,
                                    **kw)
    x = x + a
    h = _norm(cfg, p["ln_cross"], x)
    B = x.shape[0]
    q = cm.dense(p["cross"]["wq"], h).reshape(B, cfg.num_heads, cfg.head_dim)
    Se = cache["cross_k"].shape[1]
    o = attn.decode_attend(q, cache["cross_k"], cache["cross_v"],
                           jnp.arange(Se), jnp.asarray(Se, jnp.int32),
                           seq_sharded=seq_sharded)
    c = cm.dense(p["cross"]["wo"], o.reshape(B, 1, cfg.num_heads * cfg.head_dim))
    x = x + c
    f = mlp_apply(p["mlp"], _norm(cfg, p["ln2"], x), act=cfg.act)
    new_cache = {"kv": kvc, "cross_k": cache["cross_k"],
                 "cross_v": cache["cross_v"]}
    return x + f, new_cache


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def block_init(kind: str, b: Builder, cfg: ModelConfig) -> PyTree:
    if kind in ("attn", "enc"):
        return _tblock_init(b, cfg, ffn="mlp")
    if kind == "local":
        return _tblock_init(b, cfg, ffn="mlp")
    if kind in ("moe", "moe_local"):
        return _tblock_init(b, cfg, ffn="moe")
    if kind == "mla_dense":
        return _mla_block_init(b, cfg, ffn="mlp")
    if kind == "mla_moe":
        return _mla_block_init(b, cfg, ffn="moe")
    if kind == "mamba":
        return _mamba_init(b, cfg)
    if kind == "mamba_shared":
        return _mamba_shared_init(b, cfg)
    if kind == "mlstm":
        return _mlstm_init(b, cfg)
    if kind == "slstm":
        return _slstm_init(b, cfg)
    if kind == "dec":
        return _dec_init(b, cfg)
    raise ValueError(kind)


def block_apply_full(kind: str, cfg: ModelConfig, p: PyTree, x: jax.Array,
                     ctx: Ctx, shared: PyTree | None = None):
    if kind == "attn":
        return _tblock_apply_full(cfg, p, x, ctx, local=False)
    if kind in ("local", "moe_local"):
        return _tblock_apply_full(cfg, p, x, ctx, local=True)
    if kind == "moe":
        return _tblock_apply_full(cfg, p, x, ctx, local=False)
    if kind in ("mla_dense", "mla_moe"):
        return _mla_apply_full(cfg, p, x, ctx)
    if kind == "mamba":
        return _mamba_apply_full(cfg, p, x, ctx)
    if kind == "mamba_shared":
        return _mamba_apply_full(cfg, p, x, ctx, shared=shared)
    if kind == "mlstm":
        y, st = xlstm_mod.mlstm_apply_full(
            p["mlstm"], _norm(cfg, p["ln"], x), num_heads=cfg.lstm_heads,
            return_state=ctx.cache_capacity > 0)
        return x + y, jnp.zeros((), jnp.float32), st
    if kind == "slstm":
        y, st = xlstm_mod.slstm_apply(
            p["slstm"], _norm(cfg, p["ln"], x), None, num_heads=cfg.lstm_heads,
            return_state=ctx.cache_capacity > 0)
        return x + y, jnp.zeros((), jnp.float32), st
    if kind == "enc":
        return _tblock_apply_full(cfg, p, x, ctx, local=False, causal=False)
    if kind == "dec":
        return _dec_apply_full(cfg, p, x, ctx)
    raise ValueError(kind)


def block_init_cache(kind: str, cfg: ModelConfig, batch: int, capacity: int,
                     enc_len: int = 0):
    if kind in ("attn", "moe"):
        return _tblock_cache(cfg, batch, capacity, local=False)
    if kind in ("local", "moe_local"):
        return _tblock_cache(cfg, batch, capacity, local=True)
    if kind in ("mla_dense", "mla_moe"):
        return _mla_cache(cfg, batch, capacity)
    if kind == "mamba":
        return _mamba_cache(cfg, batch, capacity, shared=False)
    if kind == "mamba_shared":
        return _mamba_cache(cfg, batch, capacity, shared=True)
    if kind == "mlstm":
        return xlstm_mod.mlstm_init_state(batch, d_inner=int(
            cfg.d_model * cfg.lstm_proj_factor), num_heads=cfg.lstm_heads)
    if kind == "slstm":
        return xlstm_mod.slstm_init_state(batch, d_model=cfg.d_model,
                                          num_heads=cfg.lstm_heads)
    if kind == "dec":
        return _dec_cache(cfg, batch, capacity, enc_len)
    raise ValueError(kind)


def block_apply_decode(kind: str, cfg: ModelConfig, p: PyTree, x: jax.Array,
                       cache: PyTree, t: jax.Array,
                       shared: PyTree | None = None,
                       seq_sharded: bool = False):
    if kind in ("attn", "moe"):
        return _tblock_apply_decode(cfg, p, x, cache, t, local=False,
                                    seq_sharded=seq_sharded)
    if kind in ("local", "moe_local"):
        return _tblock_apply_decode(cfg, p, x, cache, t, local=True,
                                    seq_sharded=seq_sharded)
    if kind in ("mla_dense", "mla_moe"):
        return _mla_apply_decode(cfg, p, x, cache, t, seq_sharded=seq_sharded)
    if kind == "mamba":
        return _mamba_apply_decode(cfg, p, x, cache, t)
    if kind == "mamba_shared":
        return _mamba_apply_decode(cfg, p, x, cache, t, shared=shared,
                                   seq_sharded=seq_sharded)
    if kind == "mlstm":
        y, st = xlstm_mod.mlstm_apply_decode(
            p["mlstm"], _norm(cfg, p["ln"], x), cache,
            num_heads=cfg.lstm_heads)
        return x + y, st
    if kind == "slstm":
        y, st = xlstm_mod.slstm_apply(
            p["slstm"], _norm(cfg, p["ln"], x), cache,
            num_heads=cfg.lstm_heads, return_state=True)
        return x + y, st
    if kind == "dec":
        return _dec_apply_decode(cfg, p, x, cache, t, seq_sharded=seq_sharded)
    raise ValueError(kind)


def block_apply_verify(kind: str, cfg: ModelConfig, p: PyTree, x: jax.Array,
                       cache: PyTree, t: jax.Array,
                       shared: PyTree | None = None,
                       seq_sharded: bool = False):
    """Teacher-forced S-token decode (speculative verify): one parallel
    pass over S fed tokens per row, write-then-attend against the slot's
    ring (see ``attention.attn_apply_verify``).  Only full-ring attention
    kinds support it - windowed rings can wrap mid-chunk and recurrent
    state cannot roll back (``serve.spec.SPEC_SAFE_KINDS``)."""
    if kind in ("attn", "moe"):
        return _tblock_apply_verify(cfg, p, x, cache, t,
                                    seq_sharded=seq_sharded)
    if kind in ("mla_dense", "mla_moe"):
        return _mla_apply_verify(cfg, p, x, cache, t, seq_sharded=seq_sharded)
    raise ValueError(f"kind {kind!r} has no parallel verify path "
                     "(spec decode gates on SPEC_SAFE_KINDS)")
