"""Feed-forward blocks: SwiGLU / GeLU MLPs."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.axes import constrain
from repro.models import common as cm
from repro.models.common import Builder

PyTree = Any


def mlp_init(b: Builder, d_model: int, d_ff: int, *, gated: bool = True) -> PyTree:
    p = {
        "up": cm.dense_init(b, d_model, d_ff, ("embed", "mlp")),
        "down": cm.dense_init(b, d_ff, d_model, ("mlp", "embed")),
    }
    if gated:
        p["gate"] = cm.dense_init(b, d_model, d_ff, ("embed", "mlp"))
    return p


def mlp_apply(p: PyTree, x: jax.Array, *, act: str = "silu") -> jax.Array:
    h = cm.dense(p["up"], x)
    if "gate" in p:
        g = cm.dense(p["gate"], x)
        g = _act(g, act)
        h = g * h
    else:
        h = _act(h, act)
    h = constrain(h, *(("batch",) + (None,) * (h.ndim - 2) + ("mlp",)))
    return cm.dense(p["down"], h)


def _act(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(act)
