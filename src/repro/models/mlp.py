"""Feed-forward blocks: SwiGLU / GeLU MLPs."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.dist.axes import constrain
from repro.models import common as cm
from repro.models.common import Builder

PyTree = Any


def mlp_init(b: Builder, d_model: int, d_ff: int, *, gated: bool = True) -> PyTree:
    p = {
        "up": cm.dense_init(b, d_model, d_ff, ("embed", "mlp")),
        "down": cm.dense_init(b, d_ff, d_model, ("mlp", "embed")),
    }
    if gated:
        p["gate"] = cm.dense_init(b, d_model, d_ff, ("embed", "mlp"))
    return p


def mlp_apply(p: PyTree, x: jax.Array, *, act: str = "silu") -> jax.Array:
    if "gate" in p and _both_sparse(p["up"], p["gate"]):
        # fused compressed pass: up and gate share the reduction dim.
        # sparse_dense2 picks the route at trace time - K-shard-tagged pairs
        # run two local kernels under one shard_map with a single deferred
        # psum for the projection group; untagged pairs keep the concat
        # fusion (CPU) or two plain kernel calls (TPU, where the pre-concat
        # would re-copy the weights every step).
        from repro.sparse.apply import sparse_dense2
        h, g = sparse_dense2(p["up"]["kernel"], p["gate"]["kernel"], x)
        h = _act(g, act) * h
    elif "gate" in p:
        h = cm.dense(p["up"], x)
        g = cm.dense(p["gate"], x)
        h = _act(g, act) * h
    else:
        h = _act(cm.dense(p["up"], x), act)
    h = constrain(h, *(("batch",) + (None,) * (h.ndim - 2) + ("mlp",)))
    return cm.dense(p["down"], h)


def _both_sparse(a: PyTree, b: PyTree) -> bool:
    from repro.sparse.formats import SparseTensor
    return (isinstance(a["kernel"], SparseTensor)
            and isinstance(b["kernel"], SparseTensor)
            and a["kernel"].idx_bits == b["kernel"].idx_bits)


def _act(x: jax.Array, act: str) -> jax.Array:
    if act == "silu":
        return jax.nn.silu(x)
    if act == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if act == "relu":
        return jax.nn.relu(x)
    raise ValueError(act)
