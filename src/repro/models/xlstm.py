"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM (arXiv:2405.04517).

mLSTM: matrix memory C (dk x dv) with exponential input gate and sigmoid-in-
log-space forget gate; chunkwise form keeps exact max-stabilization across
chunk boundaries.  sLSTM: scalar memory with true (nonlinear) recurrence on
h_{t-1} -> gates, computed with a lax.scan.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.common import Builder


PyTree = Any


def _pvary(x, axis_names):
    """Device-varying marker for replicated operands under shard_map.

    jax >= 0.6 requires an explicit ``pvary`` before mixing a replicated
    operand into device-varying compute; 0.4.x has no such primitive and
    its shard_map rep-checker handles replicated operands implicitly, so
    the identity is the correct (and only) fallback there.
    """
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axis_names) if fn is not None else x


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(b: Builder, *, d_model: int, num_heads: int,
               proj_factor: float = 2.0, conv_width: int = 4) -> PyTree:
    d_inner = int(d_model * proj_factor)
    return {
        "up": cm.dense_init(b, d_model, 2 * d_inner, ("embed", "ssm")),
        "conv": {"kernel": b.param((conv_width, d_inner), (None, "ssm"),
                                   scale=conv_width ** -0.5),
                 "bias": b.param((d_inner,), ("ssm",), init="zeros")},
        "wq": cm.dense_init(b, d_inner, d_inner, ("ssm", "qkv")),
        "wk": cm.dense_init(b, d_inner, d_inner, ("ssm", "qkv")),
        "wv": cm.dense_init(b, d_inner, d_inner, ("ssm", "qkv")),
        "w_if": cm.dense_init(b, d_inner, 2 * num_heads, ("ssm", None),
                              scale=0.01),
        "if_bias": b.param((2 * num_heads,), (None,), init="zeros"),
        "norm": {"scale": b.param((d_inner,), ("ssm",), init="zeros")},
        "down": cm.dense_init(b, d_inner, d_model, ("ssm", "embed")),
    }


def _mlstm_core_chunked(q, k, v, ig, fg, state, chunk: int):
    """q,k,v: (B,S,H,D); ig/fg raw gates: (B,S,H). state: (C,n,m) or None.
    Returns h (B,S,H,D), final state. Exact stabilized chunkwise form."""
    B, S, H, D = q.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    q = q.reshape(B, nc, chunk, H, D).astype(jnp.float32) * D ** -0.5
    k = k.reshape(B, nc, chunk, H, D).astype(jnp.float32)
    v = v.reshape(B, nc, chunk, H, D).astype(jnp.float32)
    ig = ig.reshape(B, nc, chunk, H).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(fg.reshape(B, nc, chunk, H).astype(jnp.float32))
    F = jnp.cumsum(logf, axis=2)  # inclusive cumulative log-forget

    if state is None:
        C0 = jnp.zeros((B, H, D, D), jnp.float32)
        n0 = jnp.zeros((B, H, D), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]

    def step(carry, xs):
        Cp, np_, mp = carry
        qc, kc, vc, igc, Fc, logfc = xs  # (B,chunk,...)
        # log weight of source i at target t: b[t,i] = F_t - F_i + ig_i
        bmat = Fc[:, :, None, :] - Fc[:, None, :, :] + igc[:, None, :, :]
        bmat = jnp.where(causal[None, :, :, None], bmat, -jnp.inf)
        a = Fc + mp[:, None, :]  # inter-chunk log weight (B,chunk,H)
        m_row = jnp.maximum(jnp.max(bmat, axis=2), a)  # (B,chunk,H)
        w = jnp.exp(bmat - m_row[:, :, None, :])  # (B,t,i,H)
        s_inter = jnp.exp(a - m_row)  # (B,chunk,H)
        qk = jnp.einsum("bthd,bihd->btih", qc, kc)
        num = jnp.einsum("btih,btih,bihd->bthd", qk, w, vc)
        num = num + s_inter[..., None] * jnp.einsum("bthd,bhde->bthe", qc, Cp)
        den = jnp.einsum("btih,btih->bth", qk, w)
        den = den + s_inter * jnp.einsum("bthd,bhd->bth", qc, np_)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_row))[..., None]
        # chunk-end state
        FL = Fc[:, -1]  # (B,H)
        g_end = FL[:, None, :] - Fc + igc  # (B,chunk,H) log weight to end
        m_new = jnp.maximum(FL + mp, jnp.max(g_end, axis=1))
        wg = jnp.exp(g_end - m_new[:, None, :])
        C_new = jnp.exp(FL + mp - m_new)[:, :, None, None] * Cp + \
            jnp.einsum("bih,bihd,bihe->bhde", wg, kc, vc)
        n_new = jnp.exp(FL + mp - m_new)[..., None] * np_ + \
            jnp.einsum("bih,bihd->bhd", wg, kc)
        return (C_new, n_new, m_new), h

    xs = (q.transpose(1, 0, 2, 3, 4), k.transpose(1, 0, 2, 3, 4),
          v.transpose(1, 0, 2, 3, 4), ig.transpose(1, 0, 2, 3),
          F.transpose(1, 0, 2, 3), logf.transpose(1, 0, 2, 3))
    (Cf, nf, mf), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    return h, (Cf, nf, mf)


def mlstm_core_step(q, k, v, ig, fg, state):
    """Single-token recurrent update. q,k,v: (B,H,D); gates (B,H)."""
    C, n, m = state
    D = q.shape[-1]
    qs = q.astype(jnp.float32) * D ** -0.5
    logf = jax.nn.log_sigmoid(fg.astype(jnp.float32))
    m_new = jnp.maximum(logf + m, ig.astype(jnp.float32))
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(logf + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * \
        jnp.einsum("bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    n = f_p[..., None] * n + i_p[..., None] * k.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qs, C)
    den = jnp.einsum("bhd,bhd->bh", qs, n)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h, (C, n, m_new)


def _mlstm_qkvg(p, x_mid, num_heads):
    B, S, d_inner = x_mid.shape
    D = d_inner // num_heads
    q = cm.dense(p["wq"], x_mid).reshape(B, S, num_heads, D)
    k = cm.dense(p["wk"], x_mid).reshape(B, S, num_heads, D)
    v = cm.dense(p["wv"], x_mid).reshape(B, S, num_heads, D)
    gates = cm.dense(p["w_if"], x_mid) + p["if_bias"].astype(cm.COMPUTE_DTYPE)
    ig, fg = gates[..., :num_heads], gates[..., num_heads:]
    return q, k, v, ig, fg


def _mlstm_out(p, h, z, B, S, d_inner):
    h = h.reshape(B, S, d_inner).astype(z.dtype)
    h = cm.rmsnorm(p["norm"], h)
    return cm.dense(p["down"], h * jax.nn.silu(z))


def mlstm_apply_full(p: PyTree, x: jax.Array, *, num_heads: int,
                     chunk: int = 256, return_state: bool = False,
                     ) -> tuple[jax.Array, PyTree | None]:
    B, S, _ = x.shape
    d_inner = p["conv"]["bias"].shape[0]
    up = cm.dense(p["up"], x)
    x_in, z = up[..., :d_inner], up[..., d_inner:]
    from repro.models.ssm import _conv_full
    x_mid = _conv_full(p, x_in)
    q, k, v, ig, fg = _mlstm_qkvg(p, x_mid, num_heads)
    # pad to chunk multiple: no-input (ig=-inf), no-forget (fg=+inf) steps
    ch = min(chunk, S)
    S_pad = -(-S // ch) * ch
    if S_pad != S:
        pq = ((0, 0), (0, S_pad - S), (0, 0), (0, 0))
        pg = ((0, 0), (0, S_pad - S), (0, 0))
        q, k, v = jnp.pad(q, pq), jnp.pad(k, pq), jnp.pad(v, pq)
        ig = jnp.pad(ig, pg, constant_values=-1e30)
        fg = jnp.pad(fg, pg, constant_values=30.0)
    h, state = _mlstm_core_chunked(q, k, v, ig, fg, None, ch)
    h = h[:, :S]
    out = _mlstm_out(p, h, z, B, S, d_inner)
    st = None
    if return_state:
        W = p["conv"]["kernel"].shape[0]
        st = {"C": state[0], "n": state[1], "m": state[2],
              "conv": x_in[:, S - (W - 1):].astype(jnp.bfloat16)}
    return out, st


def mlstm_init_state(batch: int, *, d_inner: int, num_heads: int,
                     conv_width: int = 4) -> PyTree:
    D = d_inner // num_heads
    return {
        "C": jnp.zeros((batch, num_heads, D, D), jnp.float32),
        "n": jnp.zeros((batch, num_heads, D), jnp.float32),
        "m": jnp.full((batch, num_heads), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_inner), jnp.bfloat16),
    }


def mlstm_apply_decode(p: PyTree, x: jax.Array, state: PyTree, *,
                       num_heads: int) -> tuple[jax.Array, PyTree]:
    B = x.shape[0]
    d_inner = p["conv"]["bias"].shape[0]
    up = cm.dense(p["up"], x)
    x_in, z = up[..., :d_inner], up[..., d_inner:]
    hist = jnp.concatenate([state["conv"].astype(x_in.dtype), x_in], axis=1)
    w = p["conv"]["kernel"].astype(x_in.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", hist, w) + p["conv"]["bias"].astype(x_in.dtype)
    x_mid = jax.nn.silu(conv_out)[:, None]
    q, k, v, ig, fg = _mlstm_qkvg(p, x_mid, num_heads)
    h, (C, n, m) = mlstm_core_step(q[:, 0], k[:, 0], v[:, 0], ig[:, 0],
                                   fg[:, 0], (state["C"], state["n"], state["m"]))
    out = _mlstm_out(p, h[:, None], z, B, 1, d_inner)
    return out, {"C": C, "n": n, "m": m, "conv": hist[:, 1:].astype(jnp.bfloat16)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(b: Builder, *, d_model: int, num_heads: int,
               ff_factor: float = 4.0 / 3.0) -> PyTree:
    hd = d_model // num_heads
    d_ff = int(d_model * ff_factor)
    return {
        # input projections for gates z,i,f,o
        "w_in": cm.dense_init(b, d_model, 4 * d_model, ("embed", "ssm")),
        # block-diagonal recurrent weights per head: (H, hd, 4*hd)
        "r": {"kernel": b.param((num_heads, hd, 4 * hd), (None, None, None),
                                scale=hd ** -0.5)},
        "gate_bias": b.param((4 * d_model,), (None,), init="zeros"),
        "norm": {"scale": b.param((d_model,), ("embed_act",), init="zeros")},
        "ff_up": cm.dense_init(b, d_model, 2 * d_ff, ("embed", "mlp")),
        "ff_down": cm.dense_init(b, d_ff, d_model, ("mlp", "embed")),
    }


def _slstm_step(carry, g_t, r, num_heads):
    c, n, m, h_prev = carry  # each (B, H, hd)
    B = g_t.shape[0]
    hd = c.shape[-1]
    rec = jnp.einsum("bhd,hde->bhe", h_prev, r)  # (B,H,4*hd)
    g = g_t.reshape(B, num_heads, 4, hd).transpose(0, 1, 3, 2)
    g = g + rec.reshape(B, num_heads, hd, 4)
    zt = jnp.tanh(g[..., 0])
    it = g[..., 1]
    ft = g[..., 2]
    ot = jax.nn.sigmoid(g[..., 3])
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * zt
    n_new = jnp.maximum(f_p * n + i_p, 1e-6)
    h = ot * c_new / n_new
    return (c_new, n_new, m_new, h), h


@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _slstm_scan(gates_in, state_tuple, r, num_heads, axis_names):
    """Sequential sLSTM scan with hand-written BPTT.

    Plain autodiff-of-scan under shard_map transposes the per-step `pvary`
    of the replicated recurrent weight R into a per-timestep psum of dR
    (4.7 MB x seq_len x layers - the xlstm train collective bottleneck).
    The custom VJP accumulates dR locally in the reverse scan's carry and
    psums ONCE over `axis_names` at the end.
    """
    out, _ = _slstm_fwd(gates_in, state_tuple, r, num_heads, axis_names)
    return out


def _slstm_fwd(gates_in, state_tuple, r, num_heads, axis_names):
    B, S, d4 = gates_in.shape
    d = d4 // 4
    rf = r.astype(jnp.float32)
    if axis_names:  # shard_map: make R device-varying ONCE so its per-step
        rf = _pvary(rf, axis_names)  # cotangents stay local
    gates_seq = gates_in.astype(jnp.float32).transpose(1, 0, 2)

    def step(carry, g_t):
        new_carry, h = _slstm_step(carry, g_t, rf, num_heads)
        return new_carry, (carry, h)  # save pre-step state for BPTT

    final, (saved_states, hs) = jax.lax.scan(step, state_tuple, gates_seq)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d)
    return (h, final), (gates_seq, saved_states, r)


def _slstm_bwd(num_heads, axis_names, res, cots):
    gates_seq, saved_states, r = res
    dh_out, dfinal = cots
    S, B, d4 = gates_seq.shape
    d = d4 // 4
    rf = r.astype(jnp.float32)
    if axis_names:
        rf = _pvary(rf, axis_names)
    dh_seq = dh_out.reshape(B, S, num_heads, d // num_heads) \
        .transpose(1, 0, 2, 3).astype(jnp.float32)
    dR0 = jnp.zeros(r.shape, jnp.float32)
    if axis_names:
        dR0 = _pvary(dR0, axis_names)

    def back(carry, xs):
        dstate, dR = carry
        g_t, st_prev, dh_t = xs
        _, vjp_fn = jax.vjp(
            lambda st, g, rr: _slstm_step(st, g, rr, num_heads),
            st_prev, g_t, rf)
        dc, dn, dm, dh = dstate
        dst_prev, dg, dr = vjp_fn(((dc, dn, dm, dh + dh_t),
                                   jnp.zeros_like(dh_t)))
        # h cotangent of this step's OUTPUT was already folded in; the
        # scan output h equals the carry h, so route dh via the carry.
        return (dst_prev, dR + dr), dg

    (dstate0, dR), dg_seq = jax.lax.scan(
        back, (dfinal, dR0), (gates_seq, saved_states, dh_seq), reverse=True)
    if axis_names:
        dR = jax.lax.psum(dR, axis_names)
    dgates = dg_seq.transpose(1, 0, 2).astype(jnp.float32)
    return dgates, dstate0, dR.astype(r.dtype)


_slstm_scan.defvjp(_slstm_fwd, _slstm_bwd)


def slstm_core(p: PyTree, gates_in: jax.Array, state: PyTree, *,
               num_heads: int):
    """Dispatch the sequential scan, under shard_map when rules are active
    (batch-local recurrence; ONE dR psum at the end via the custom VJP)."""
    from repro.dist.axes import current_rules
    init = (state["c"], state["n"], state["m"], state["h"])
    rules = current_rules()
    B = gates_in.shape[0]
    axis_names: tuple = ()
    wrap = None
    if rules is not None:
        batch_axes = rules.rules.get("batch") or ()
        if isinstance(batch_axes, str):
            batch_axes = (batch_axes,)
        batch_axes = tuple(a for a in batch_axes
                           if a in rules.mesh.axis_names)
        dp = 1
        for a in batch_axes:
            dp *= rules.mesh.shape[a]
        if batch_axes and B % dp == 0 and B >= dp:
            axis_names = batch_axes
            wrap = rules.mesh

    def core_fn(g, st, r):
        return _slstm_scan(g, st, r, num_heads, axis_names)

    fn = core_fn
    if wrap is not None:
        from jax.sharding import PartitionSpec as P
        bsp = P(axis_names, None, None)
        fn = cm.shard_map(core_fn, mesh=wrap,
                           in_specs=(bsp, (bsp,) * 4, P(None, None, None)),
                           out_specs=(bsp, (bsp,) * 4))
    h, (c, n, m, h_last) = fn(gates_in.astype(jnp.float32), init,
                              p["r"]["kernel"])
    return h, {"c": c, "n": n, "m": m, "h": h_last}


def slstm_init_state(batch: int, *, d_model: int, num_heads: int) -> PyTree:
    hd = d_model // num_heads
    z = jnp.zeros((batch, num_heads, hd), jnp.float32)
    return {"c": z, "n": z + 1e-6, "m": z - 1e30, "h": z}


def slstm_apply(p: PyTree, x: jax.Array, state: PyTree | None, *,
                num_heads: int, return_state: bool = False,
                ) -> tuple[jax.Array, PyTree | None]:
    B, S, d = x.shape
    if state is None:
        state = slstm_init_state(B, d_model=d, num_heads=num_heads)
    gates_in = cm.dense(p["w_in"], x) + p["gate_bias"].astype(cm.COMPUTE_DTYPE)
    h, new_state = slstm_core(p, gates_in, state, num_heads=num_heads)
    h = cm.rmsnorm(p["norm"], h.astype(x.dtype))
    ff = cm.dense(p["ff_up"], h)
    d_ff = ff.shape[-1] // 2
    h = cm.dense(p["ff_down"], jax.nn.gelu(ff[..., :d_ff], approximate=True)
                 * ff[..., d_ff:])
    return h, (new_state if return_state else None)
