"""Deterministic synthetic corpus (C4 stand-in for the offline container).

Token process per position (seeded, reproducible, split-disjoint):
  p=0.55: deterministic bigram successor  succ(t) = (a*t + c) mod V
  p=0.20: copy of the token 8 positions back (induction structure)
  p=0.25: zipfian unigram draw
A competent model reaches low PPL by learning succ and the copy head, while
corrupted/pruned models degrade measurably - exactly what the paper's PPL
tables need at toy scale.

Batches are a pure function of (seed, split, index) so any host can compute
its shard and a restart resumes from a cursor with no replay.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

SPLITS = {"train": 0, "calib": 1, "valid": 2}


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    p_succ: float = 0.55
    p_copy: float = 0.20


def _succ_params(vocab: int, seed: int) -> tuple[int, int]:
    rng = np.random.default_rng(seed + 7)
    a = int(rng.integers(2, vocab - 1)) | 1   # odd -> full cycle for pow2 V
    c = int(rng.integers(1, vocab - 1))
    return a, c


def sample_tokens(cfg: CorpusConfig, split: str, index: int,
                  batch: int, seq: int) -> np.ndarray:
    rng = np.random.default_rng(
        (cfg.seed * 1_000_003 + SPLITS[split] * 7919 + index) % (2 ** 63))
    a, c = _succ_params(cfg.vocab_size, cfg.seed)
    V = cfg.vocab_size
    # zipf over a shuffled id map so frequent ids are spread over the vocab
    ranks = (rng.zipf(cfg.zipf_a, size=(batch, seq)) - 1) % V
    perm = np.random.default_rng(cfg.seed + 13).permutation(V)
    zipf_draws = perm[ranks]
    u = rng.random((batch, seq))
    toks = np.empty((batch, seq), np.int64)
    toks[:, 0] = zipf_draws[:, 0]
    for t in range(1, seq):
        succ = (a * toks[:, t - 1] + c) % V
        copy = toks[:, max(t - 8, 0)]
        toks[:, t] = np.where(
            u[:, t] < cfg.p_succ, succ,
            np.where(u[:, t] < cfg.p_succ + cfg.p_copy, copy,
                     zipf_draws[:, t]))
    return toks.astype(np.int32)


def _stub_embeds(tokens: np.ndarray, dim: int, seed: int) -> np.ndarray:
    """Deterministic frame/patch embedding stub derived from token ids."""
    rng = np.random.default_rng(seed + 29)
    table = rng.standard_normal((257, dim)).astype(np.float32) * 0.5
    return table[tokens % 257]


def batches_for(model_cfg, *, n: int, batch: int, seq: int, split: str,
                seed: int = 0, start: int = 0) -> list[dict]:
    """Model-family-aware batches (adds stub frames/patches as needed)."""
    ccfg = CorpusConfig(vocab_size=model_cfg.vocab_size, seed=seed)
    out = []
    for i in range(start, start + n):
        toks = sample_tokens(ccfg, split, i, batch, seq)
        b = {"tokens": toks}
        if model_cfg.family == "audio":
            b["frames"] = _stub_embeds(toks, model_cfg.d_model, seed)
        if model_cfg.family == "vlm":
            img = sample_tokens(ccfg, split, i + 100_000, batch,
                                model_cfg.num_image_tokens)
            b["patches"] = _stub_embeds(img, model_cfg.vit_dim, seed)
        out.append(b)
    return out


@dataclasses.dataclass
class DataCursor:
    """Checkpointable loader state: (split, next_index)."""
    split: str = "train"
    index: int = 0


class ShardedLoader:
    """Per-host loader: host h of H reads batch rows [h*b/H, (h+1)*b/H)."""

    def __init__(self, model_cfg, *, global_batch: int, seq: int,
                 split: str = "train", seed: int = 0, host_id: int = 0,
                 num_hosts: int = 1, cursor: DataCursor | None = None):
        assert global_batch % num_hosts == 0
        self.model_cfg = model_cfg
        self.global_batch = global_batch
        self.seq = seq
        self.seed = seed
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.cursor = cursor or DataCursor(split=split)

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        i = self.cursor.index
        self.cursor.index += 1
        full = batches_for(self.model_cfg, n=1, batch=self.global_batch,
                           seq=self.seq, split=self.cursor.split,
                           seed=self.seed, start=i)[0]
        per = self.global_batch // self.num_hosts
        lo = self.host_id * per
        return {k: v[lo:lo + per] for k, v in full.items()}
