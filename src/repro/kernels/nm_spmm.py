"""2:4 structured-sparse matmul Pallas kernel (TPU adaptation of the paper's
NVIDIA-sparse-tensor-core speedup, Table 8).

TPU MXUs have no sparse mode, so the win is HBM *bandwidth*: decode-shape
GEMMs are memory-bound (arithmetic intensity ~ batch << 240 flops/byte), and
a 2:4 weight stored compressed moves ~9/16 of the dense bf16 bytes
(values K/2*N*2B + 2-bit packed indices K/8*N*1B vs dense K*N*2B; int8
indices give the weaker 3/4 fallback).  The kernel streams compressed tiles
HBM->VMEM, expands them to dense in-register on the VPU (a masked broadcast
- no gather), and feeds the MXU a normal dense matmul.

Layout: W (K, N) pruned 2:4 along K (the reduction dim).  Compressed:
  vals (K/2, N)  bf16   - the two surviving values per group of 4
and one of two index layouts, named by the tags in ``sparse.formats``:
  idx  (K/2, N)  int8   - LAYOUT_INT8: in-group positions (0..3), ascending
  idx  (K/8, N)  uint8  - LAYOUT_PACKED2: 4 positions per byte, bits 2j..2j+1
                          hold the position of compressed row 4r+j

With LAYOUT_PACKED2 the packed bytes are what streams HBM->VMEM; the 2-bit
unpack is a bitwise shift/mask on the VPU *after* the copy, so the index
plane costs K/8*N bytes of bandwidth instead of K/2*N.  The int8 path is
kept as a fallback (byte-padded planes, legacy callers).

Block tiling: (bm x bk) @ (bk x bn) with compressed operand tiles
(bk/2 x bn) vals and (bk/2 x bn | bk/8 x bn) idx; K is the innermost
(arbitrary) grid dim accumulating into an f32 VMEM scratch, flushed to the
output on the last K step.

MoE expert banks (E, K, N) pruned 2:4 along K use ``nm_matmul_expert``: the
same compressed tiles gain a leading expert axis and the grid a leading
(parallel) expert dimension, so per-expert GEMMs over the dispatch buffer
stream each expert's 9/16 bytes without a masked-dense fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across JAX versions (TPUCompilerParams <= 0.4.x)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# The index-plane layout tags the kernel dispatches on.  Single source of
# truth; ``sparse.formats`` re-exports them for the storage side.
LAYOUT_INT8 = "int8"
LAYOUT_PACKED2 = "packed2"


def unpack_idx2(packed: jax.Array) -> jax.Array:
    """(..., rows, n) uint8 packed codes -> (..., rows*4, n) int8 positions.

    The single definition of the 2-bit layout: byte row r carries compressed
    rows 4r..4r+3 in bit pairs 2j..2j+1.  Used both as the in-kernel VMEM
    unpack (2-D tile after the HBM->VMEM copy; shift/mask runs on the VPU in
    int32 lanes, Mosaic's native integer width, then narrows to int8 for the
    expand compare) and, via ``sparse.formats``, as the host/storage unpack.
    """
    *lead, rows, n = packed.shape
    p = packed.astype(jnp.int32)
    codes = [(p >> (2 * j)) & 0x3 for j in range(4)]
    out = jnp.stack(codes, axis=-2)                # (..., rows, 4, n)
    return out.reshape(*lead, rows * 4, n).astype(jnp.int8)


def _expand_tile(vals, idx):
    """(bk/2, bn) compressed -> (bk, bn) dense, in-register.

    Group g occupies dense rows 4g..4g+3; compressed rows 2g, 2g+1 carry
    (value, position).  dense[4g + r, n] = sum_j vals[2g+j, n] * (idx==r).
    """
    half, bn = vals.shape
    g = half // 2
    v = vals.reshape(g, 2, bn)
    p = idx.reshape(g, 2, bn)
    r = jax.lax.broadcasted_iota(jnp.int8, (g, 4, bn), 1)  # in-group row
    dense = jnp.zeros((g, 4, bn), vals.dtype)
    for j in range(2):
        hit = p[:, j:j + 1, :] == r
        dense = dense + jnp.where(hit, v[:, j:j + 1, :], 0)
    return dense.reshape(g * 4, bn)


def _nm_matmul_kernel(x_ref, vals_ref, idx_ref, o_ref, acc_ref, *, nk,
                      packed):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = unpack_idx2(idx_ref[...]) if packed else idx_ref[...]
    dense_w = _expand_tile(vals_ref[...], idx)
    acc_ref[...] += jnp.dot(x_ref[...], dense_w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _infer_layout(K: int, idx_shape: tuple[int, ...]) -> str:
    if idx_shape[-2] * 2 == K:
        return LAYOUT_INT8
    if idx_shape[-2] * 8 == K:
        return LAYOUT_PACKED2
    raise ValueError(f"index plane {idx_shape} matches no layout for K={K}")


def infer_layout(K: int, idx_shape: tuple[int, ...]) -> str:
    """Index-plane layout from shapes alone (K/2 rows -> int8, K/8 ->
    packed2).

    Works on *shard-local* shapes too: under ``shard_map`` each device holds
    (K_loc/2, N) vals and (K_loc/2 | K_loc/8, N) idx slices of the same
    layout, and the row ratio is sharding-invariant, so the per-device
    kernel call infers the layout from its local operands with no global
    metadata.
    """
    return _infer_layout(K, idx_shape)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "layout", "interpret",
                                    "out_dtype"))
def nm_matmul(x: jax.Array, vals: jax.Array, idx: jax.Array, *,
              bm: int = 128, bk: int = 512, bn: int = 256,
              layout: str | None = None,
              interpret: bool = False, out_dtype=None) -> jax.Array:
    """x: (M, K) @ 2:4-compressed W (K, N) -> (M, N) in x.dtype.

    layout: LAYOUT_INT8 (idx (K/2, N) int8) or LAYOUT_PACKED2 (idx (K/8, N)
    uint8, consumed packed - no host-side unpack); None infers from shapes.

    out_dtype: output dtype override (default x.dtype).  The tensor-parallel
    wrappers pass float32 so K-partial results leave the kernel as the raw
    f32 accumulator and the cross-device psum adds full-precision partials
    before the single cast back to the activation dtype.
    """
    M, K = x.shape
    halfK, N = vals.shape
    assert halfK * 2 == K, (x.shape, vals.shape)
    layout = _infer_layout(K, idx.shape) if layout is None else layout
    packed = layout == LAYOUT_PACKED2
    if packed:
        assert K % 8 == 0 and idx.shape == (K // 8, N), (idx.shape, K, N)
    else:
        assert layout == LAYOUT_INT8 and idx.shape == (halfK, N), \
            (layout, idx.shape)
    bm = min(bm, M)
    bk = min(bk, K)
    bn = min(bn, N)
    idx_rows = 8 if packed else 2
    # int8 tiles need whole 2:4 groups (bk % 4); packed tiles additionally
    # need whole index bytes (bk % 8)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 \
        and bk % (8 if packed else 4) == 0
    nk = K // bk
    return pl.pallas_call(
        functools.partial(_nm_matmul_kernel, nk=nk, packed=packed),
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk // 2, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bk // idx_rows, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype or x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, vals, idx)


# ---------------------------------------------------------------------------
# Expert-banked variant (MoE)
# ---------------------------------------------------------------------------

def _nm_matmul_expert_kernel(x_ref, vals_ref, idx_ref, o_ref, acc_ref, *, nk,
                             packed):
    """Same tile math as ``_nm_matmul_kernel``; the grid grew a leading
    expert dim so every ref carries a size-1 expert block (sliced off with
    [0]).  One (bm x bn) f32 accumulator per (e, m, n) program."""
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = unpack_idx2(idx_ref[0]) if packed else idx_ref[0]
    dense_w = _expand_tile(vals_ref[0], idx)
    acc_ref[...] += jnp.dot(x_ref[0], dense_w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == nk - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bk", "bn", "layout", "interpret",
                                    "out_dtype"))
def nm_matmul_expert(x: jax.Array, vals: jax.Array, idx: jax.Array, *,
                     bm: int = 128, bk: int = 512, bn: int = 256,
                     layout: str | None = None,
                     interpret: bool = False, out_dtype=None) -> jax.Array:
    """Per-expert batch x: (E, M, K) @ 2:4-compressed bank (E, K, N)
    -> (E, M, N) in x.dtype.

    The compressed operands carry a leading expert axis - vals (E, K/2, N),
    idx (E, K/2, N) int8 | (E, K/8, N) uint8 - and the grid grows a leading
    (parallel) expert dimension, so each program streams one expert's
    compressed tiles HBM->VMEM and runs the same VMEM shift/mask unpack +
    in-register expand as the 2-D kernel.  MoE dispatch buffers (G, E, C, d)
    reshape to (E, G*C, d) and route through here (see
    ``sparse.apply.sparse_moe_dense``).
    """
    E, M, K = x.shape
    Ev, halfK, N = vals.shape
    assert Ev == E and halfK * 2 == K, (x.shape, vals.shape)
    layout = _infer_layout(K, idx.shape) if layout is None else layout
    packed = layout == LAYOUT_PACKED2
    if packed:
        assert K % 8 == 0 and idx.shape == (E, K // 8, N), (idx.shape, K, N)
    else:
        assert layout == LAYOUT_INT8 and idx.shape == (E, halfK, N), \
            (layout, idx.shape)
    bm = min(bm, M)
    bk = min(bk, K)
    bn = min(bn, N)
    idx_rows = 8 if packed else 2
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 \
        and bk % (8 if packed else 4) == 0
    nk = K // bk
    return pl.pallas_call(
        functools.partial(_nm_matmul_expert_kernel, nk=nk, packed=packed),
        grid=(E, M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda e, m, n, k: (e, m, k)),
            pl.BlockSpec((1, bk // 2, bn), lambda e, m, n, k: (e, k, n)),
            pl.BlockSpec((1, bk // idx_rows, bn),
                         lambda e, m, n, k: (e, k, n)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda e, m, n, k: (e, m, n)),
        out_shape=jax.ShapeDtypeStruct((E, M, N), out_dtype or x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(x, vals, idx)
