"""2:4 structured-sparse matmul Pallas kernel (TPU adaptation of the paper's
NVIDIA-sparse-tensor-core speedup, Table 8).

TPU MXUs have no sparse mode, so the win is HBM *bandwidth*: decode-shape
GEMMs are memory-bound (arithmetic intensity ~ batch << 240 flops/byte), and
a 2:4 weight stored compressed moves ~9/16 of the dense bf16 bytes
(values K/2*N*2B + 8-bit indices K/2*N*1B vs dense K*N*2B; 2-bit packed
indices push that to ~9/32).  The kernel streams compressed tiles HBM->VMEM,
expands them to dense in-register on the VPU (a masked broadcast - no
gather), and feeds the MXU a normal dense matmul.

Layout: W (K, N) pruned 2:4 along K (the reduction dim).  Compressed:
  vals (K/2, N)  bf16   - the two surviving values per group of 4
  idx  (K/2, N)  int8   - their in-group positions (0..3), ascending

Block tiling: (bm x bk) @ (bk x bn) with compressed operand tiles
(bk/2 x bn); K is the innermost (arbitrary) grid dim accumulating into an
f32 VMEM scratch, flushed to the output on the last K step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across JAX versions (TPUCompilerParams <= 0.4.x)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _expand_tile(vals, idx):
    """(bk/2, bn) compressed -> (bk, bn) dense, in-register.

    Group g occupies dense rows 4g..4g+3; compressed rows 2g, 2g+1 carry
    (value, position).  dense[4g + r, n] = sum_j vals[2g+j, n] * (idx==r).
    """
    half, bn = vals.shape
    g = half // 2
    v = vals.reshape(g, 2, bn)
    p = idx.reshape(g, 2, bn)
    r = jax.lax.broadcasted_iota(jnp.int8, (g, 4, bn), 1)  # in-group row
    dense = jnp.zeros((g, 4, bn), vals.dtype)
    for j in range(2):
        hit = p[:, j:j + 1, :] == r
        dense = dense + jnp.where(hit, v[:, j:j + 1, :], 0)
    return dense.reshape(g * 4, bn)


def _nm_matmul_kernel(x_ref, vals_ref, idx_ref, o_ref, acc_ref, *, nk):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dense_w = _expand_tile(vals_ref[...], idx_ref[...])
    acc_ref[...] += jnp.dot(x_ref[...], dense_w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret"))
def nm_matmul(x: jax.Array, vals: jax.Array, idx: jax.Array, *,
              bm: int = 128, bk: int = 512, bn: int = 256,
              interpret: bool = False) -> jax.Array:
    """x: (M, K) @ 2:4-compressed W (K, N) -> (M, N) in x.dtype."""
    M, K = x.shape
    halfK, N = vals.shape
    assert halfK * 2 == K and idx.shape == (halfK, N), (x.shape, vals.shape)
    bm = min(bm, M)
    bk = min(bk, K)
    bn = min(bn, N)
    assert M % bm == 0 and K % bk == 0 and N % bn == 0 and bk % 4 == 0
    nk = K // bk
    return pl.pallas_call(
        functools.partial(_nm_matmul_kernel, nk=nk),
        grid=(M // bm, N // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),
            pl.BlockSpec((bk // 2, bn), lambda m, n, k: (k, n)),
            pl.BlockSpec((bk // 2, bn), lambda m, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, vals, idx)
