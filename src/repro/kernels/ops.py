"""Public jit'd wrappers over the Pallas kernels (shape padding, tree-level
application, CPU-interpret fallbacks).

On a real TPU these dispatch to the compiled kernels; on CPU they run in
interpret mode (bit-accurate against ref.py, validated in tests).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_decode import (flash_decode, flash_decode_partial,
                                        flash_decode_partial_ref,
                                        flash_decode_ref)
from repro.kernels.nm_prox import nm_mask24, prox24
from repro.kernels.nm_spmm import nm_matmul

PyTree = Any
_ON_TPU = jax.default_backend() == "tpu"


def _interp() -> bool:
    return not _ON_TPU


# --- 2:4 compressed weights ------------------------------------------------

def compress_leaf(w: jax.Array) -> dict:
    """Dense 2:4-pruned (d_in, d_out) kernel -> compressed {vals, idx}."""
    vals, idx = ref.compress_24(w)
    return {"vals": vals.astype(jnp.bfloat16), "idx": idx}


def compress_params_24(params: PyTree, masks: PyTree) -> PyTree:
    """Compress every 2-D masked kernel; other leaves pass through."""
    def leaf(w, m):
        if m is None or w.ndim != 2 or w.shape[0] % 4:
            return w
        return compress_leaf(w * m.astype(w.dtype))

    return jax.tree.map(leaf, params, masks, is_leaf=lambda x: x is None)


def sparse_dense(x: jax.Array, packed: dict, *, bm: int = 128,
                 bk: int = 512, bn: int = 256) -> jax.Array:
    """x @ W for a compressed 2:4 weight (kernel on TPU, oracle on CPU)."""
    if _interp():
        return ref.nm_matmul_ref(x, packed["vals"], packed["idx"])
    K2, N = packed["vals"].shape
    return nm_matmul(x, packed["vals"], packed["idx"], bm=min(bm, x.shape[0]),
                     bk=min(bk, 2 * K2), bn=min(bn, N))


# --- fused mirror-descent elementwise pass ----------------------------------

def fused_mirror_leaf(w, a, gamma, v, *, metric: str, v_lr: float,
                      lam: float, rowsum=None, colsum=None):
    from repro.kernels.saliency_fuse import saliency_fused_step
    if _interp():
        rs = None if rowsum is None else rowsum[:, None]
        cs = None if colsum is None else colsum[None, :]
        if metric == "magnitude":
            return ref.saliency_step_ref(w, jnp.ones(w.shape[:-1]), gamma, v,
                                         v_lr=v_lr, lam=lam)
        return ref.saliency_step_ref(w, a, gamma, v, v_lr=v_lr, lam=lam,
                                     rowsum=rs, colsum=cs)
    return saliency_fused_step(w, a, gamma, v, metric=metric, v_lr=v_lr,
                               lam=lam, rowsum=rowsum, colsum=colsum)


# --- decode attention --------------------------------------------------------

def decode_attention(q, k, v, bias, *, scale=None):
    """(B,K,G,D) x (B,C,K,D) -> (B,K,G,Dv); kernel on TPU, oracle on CPU."""
    if _interp():
        return flash_decode_ref(q, k, v, bias, scale=scale)
    return flash_decode(q, k, v, bias, scale=scale)


def decode_attention_partial(q, k, v, bias, *, scale=None):
    """Un-normalized decode attention over a capacity shard: float32
    (acc, m, l) partials for the cross-shard pmax/psum combine in
    ``kernels.shard.decode_attend_sharded``."""
    if _interp():
        return flash_decode_partial_ref(q, k, v, bias, scale=scale)
    return flash_decode_partial(q, k, v, bias, scale=scale)


def prox24_op(w: jax.Array, lam: float) -> jax.Array:
    if _interp():
        from repro.core.prox import prox_nm24
        return prox_nm24(w, lam)
    return prox24(w, lam=lam)


def nm_mask24_op(s: jax.Array) -> jax.Array:
    if _interp():
        return ref.nm_mask_ref(s)
    return nm_mask24(s)
