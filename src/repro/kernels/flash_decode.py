"""Split-KV decode attention Pallas kernel (flash-decoding style).

One query token vs a long KV cache is pure HBM streaming: arithmetic
intensity ~ 2 flops/byte, far below the v5e ridge (~240).  The kernel tiles
the KV capacity dim, keeps a running (m, l, acc) softmax state in VMEM
scratch, and writes the normalized output on the final chunk - one pass over
KV, no (C,)-sized logits materialized in HBM.

Masking comes in as an additive bias vector (0 / -inf per slot), computed
once outside from ring positions - so the same kernel serves dense, ring
(sliding-window) and sequence-sharded caches (the partial (m, l, acc)
combine across shards is decode_attend's psum path).

Grid: (B, K_heads, C/bc), last dim arbitrary (sequential accumulation).
Real-TPU note: G (=H/K) and D tiles should be padded to (8, 128) lanes; the
oracle-validated interpret path accepts any shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across JAX versions (TPUCompilerParams <= 0.4.x)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, nc, scale):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (bc, D)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (bc, Dv)
    s = (q @ k.T) * scale + bias_ref[0]            # (G, bc)
    m_prev = m_ref[...]                            # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(pl.program_id(2) == nc - 1)
    def _flush():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def flash_decode(q, k, v, bias, *, scale=None, bc: int = 512,
                 interpret: bool = False):
    """q: (B, K, G, D); k/v: (B, C, K, D/Dv); bias: (B, C) additive mask.

    Returns (B, K, G, Dv).
    """
    B, K, G, D = q.shape
    C = k.shape[1]
    Dv = v.shape[-1]
    bc = min(bc, C)
    assert C % bc == 0, (C, bc)
    scale = D ** -0.5 if scale is None else scale
    nc = C // bc
    return pl.pallas_call(
        functools.partial(_decode_kernel, nc=nc, scale=scale),
        grid=(B, K, nc),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, bc, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, bc, 1, Dv), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, bc), lambda b, h, c: (b, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, h, c: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, Dv), q.dtype),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, Dv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, bias)


def flash_decode_ref(q, k, v, bias, *, scale=None):
    """Materialized oracle."""
    B, K, G, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    s = jnp.einsum("bkgd,bckd->bkgc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = s + bias[:, None, None, :]
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgc,bckd->bkgd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# Partial (un-normalized) variant for capacity-sharded caches
# ---------------------------------------------------------------------------

def _decode_partial_kernel(q_ref, k_ref, v_ref, bias_ref, acc_o, m_o, l_o,
                           m_ref, l_ref, acc_ref, *, nc, scale):
    """Same streaming state as ``_decode_kernel`` but the flush emits the raw
    (acc, m, l) instead of acc/l - the caller combines partials across
    capacity shards (pmax on m, psum on rescaled l/acc) before normalizing
    once.  An all-masked shard flushes m = -1e30, whose cross-shard
    correction exp(m - m_global) zeroes its partial exactly."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)            # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)         # (bc, D)
    v = v_ref[0, :, 0].astype(jnp.float32)         # (bc, Dv)
    s = (q @ k.T) * scale + bias_ref[0]            # (G, bc)
    m_prev = m_ref[...]                            # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + p @ v
    m_ref[...] = m_new

    @pl.when(pl.program_id(2) == nc - 1)
    def _flush():
        acc_o[0, 0] = acc_ref[...]
        m_o[0, 0] = m_ref[...]
        l_o[0, 0] = l_ref[...]


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def flash_decode_partial(q, k, v, bias, *, scale=None, bc: int = 512,
                         interpret: bool = False):
    """Un-normalized flash decode over (a shard of) the KV capacity.

    Same operands as :func:`flash_decode`; returns float32
    ``(acc (B, K, G, Dv), m (B, K, G, 1), l (B, K, G, 1))`` with
    ``acc = sum_c exp(s_c - m) v_c`` and ``l = sum_c exp(s_c - m)`` - the
    running softmax state, flushed raw so shard partials combine exactly
    like the kernel's own chunk accumulation, just across devices.
    """
    B, K, G, D = q.shape
    C = k.shape[1]
    Dv = v.shape[-1]
    bc = min(bc, C)
    assert C % bc == 0, (C, bc)
    scale = D ** -0.5 if scale is None else scale
    nc = C // bc
    return pl.pallas_call(
        functools.partial(_decode_partial_kernel, nc=nc, scale=scale),
        grid=(B, K, nc),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, bc, 1, D), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, bc, 1, Dv), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, bc), lambda b, h, c: (b, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, Dv), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((B, K, G, Dv), jnp.float32),
                   jax.ShapeDtypeStruct((B, K, G, 1), jnp.float32),
                   jax.ShapeDtypeStruct((B, K, G, 1), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, Dv), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, bias)


def flash_decode_partial_ref(q, k, v, bias, *, scale=None):
    """Materialized (acc, m, l) oracle for the partial kernel."""
    B, K, G, D = q.shape
    scale = D ** -0.5 if scale is None else scale
    s = jnp.einsum("bkgd,bckd->bkgc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = s + bias[:, None, None, :]
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bkgc,bckd->bkgd", p, v.astype(jnp.float32))
    return acc, m, l
