"""Group-of-4 kernels: the R_{2:4} proximal operator and 2:4 mask extraction.

Both are local to contiguous groups of 4 along the K (reduction) dim -
perfect VPU work with zero cross-lane traffic.  Tiles are (bk x bn) with
bk % 4 == 0; groups are processed as a (bk/4, 4, bn) view in-register.

prox: damped Jacobi fixed point on u_i = max(0, |w_i| - lam * e2_i(u_others))
      (Kuebler et al. 2501.18015), signs restored - runs every search step in
      N:M mode, so it shares the fused-pass motivation of saliency_fuse.
mask: top-2 |s| per group -> bool mask, deterministic tie-break by position.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across JAX versions (TPUCompilerParams <= 0.4.x)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _prox_kernel(w_ref, o_ref, *, lam, iters, damping):
    w = w_ref[...].astype(jnp.float32)
    bk, bn = w.shape
    g = w.reshape(bk // 4, 4, bn)
    absw = jnp.abs(g)
    u = absw
    for _ in range(iters):
        u0, u1, u2, u3 = u[:, 0], u[:, 1], u[:, 2], u[:, 3]
        e0 = u1 * u2 + u2 * u3 + u3 * u1
        e1 = u0 * u2 + u2 * u3 + u3 * u0
        e2 = u0 * u1 + u1 * u3 + u3 * u0
        e3 = u0 * u1 + u1 * u2 + u2 * u0
        grad = jnp.stack([e0, e1, e2, e3], axis=1)
        u = damping * jnp.maximum(absw - lam * grad, 0.0) + \
            (1 - damping) * u
    out = jnp.sign(g) * u
    o_ref[...] = out.reshape(bk, bn).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("lam", "iters", "damping", "bk",
                                             "bn", "interpret"))
def prox24(w: jax.Array, *, lam: float, iters: int = 12,
           damping: float = 0.7, bk: int = 256, bn: int = 512,
           interpret: bool = False) -> jax.Array:
    K, N = w.shape
    bk = min(bk, K)
    bn = min(bn, N)
    assert K % bk == 0 and N % bn == 0 and bk % 4 == 0
    return pl.pallas_call(
        functools.partial(_prox_kernel, lam=lam, iters=iters,
                          damping=damping),
        grid=(K // bk, N // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), w.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(w)


def _mask_kernel(s_ref, o_ref):
    s = jnp.abs(s_ref[...].astype(jnp.float32))
    bk, bn = s.shape
    g = s.reshape(bk // 4, 4, bn)
    # rank of element i = #{j: g_j > g_i, or g_j == g_i with j earlier}
    gi = g[:, :, None, :]   # axis 1 = i
    gj = g[:, None, :, :]   # axis 2 = j
    pos = jnp.arange(4)
    j_earlier = pos[None, None, :, None] < pos[None, :, None, None]
    beats = (gj > gi) | ((gj == gi) & j_earlier)
    rank = jnp.sum(beats, axis=2)
    mask = rank < 2
    o_ref[...] = mask.reshape(bk, bn)


@functools.partial(jax.jit, static_argnames=("bk", "bn", "interpret"))
def nm_mask24(s: jax.Array, *, bk: int = 256, bn: int = 512,
              interpret: bool = False) -> jax.Array:
    """Top-2-of-4 keep-mask along K. s: (K, N) scores -> bool (K, N)."""
    K, N = s.shape
    bk = min(bk, K)
    bn = min(bn, N)
    assert K % bk == 0 and N % bn == 0 and bk % 4 == 0
    return pl.pallas_call(
        _mask_kernel,
        grid=(K // bk, N // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((K, N), jnp.bool_),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(s)
