"""Fused UniPruning inner loop: local metric + dual update + Gamma prox.

The search stage touches every prunable parameter every step with a pure
elementwise chain (score -> V update -> soft-threshold).  Unfused, XLA
materializes S and reads/writes each operand separately: ~5 reads + 3 writes
of W-sized tensors per step.  This kernel does it in one HBM pass:
reads W, Gamma, V (+ per-row stats), writes V', Gamma'.

Metric selection is static:
  wanda:      S = |W| * a[:, None]
  ria/stoch:  S = (|W|/rowsum + |W|/colsum) * sqrt(a)[:, None]
  magnitude:  S = |W|

a / rowsum enter as (K, 1) blocks, colsum as (1, N) - all VMEM-resident per
tile; the tile shape (bk x bn) is VPU-lane aligned (multiples of 8 x 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed across JAX versions (TPUCompilerParams <= 0.4.x)
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _fuse_kernel(w_ref, a_ref, row_ref, col_ref, g_ref, v_ref,
                 vout_ref, gout_ref, *, v_lr, lam, metric):
    w = jnp.abs(w_ref[...].astype(jnp.float32))
    if metric == "wanda":
        s = w * a_ref[...].astype(jnp.float32)
    elif metric == "magnitude":
        s = w
    else:  # ria / stochria
        a = jnp.sqrt(jnp.maximum(a_ref[...].astype(jnp.float32), 1e-12))
        s = (w / (row_ref[...].astype(jnp.float32) + 1e-12)
             + w / (col_ref[...].astype(jnp.float32) + 1e-12)) * a
    v_new = v_ref[...].astype(jnp.float32) - \
        v_lr * (g_ref[...].astype(jnp.float32) - s)
    vout_ref[...] = v_new
    gout_ref[...] = jnp.sign(v_new) * jnp.maximum(jnp.abs(v_new) - lam, 0.0)


@functools.partial(jax.jit, static_argnames=("metric", "v_lr", "lam", "bk",
                                             "bn", "interpret"))
def saliency_fused_step(w, a, gamma, v, *, metric: str = "wanda",
                        v_lr: float = 0.1, lam: float = 1e-3,
                        rowsum=None, colsum=None, bk: int = 256,
                        bn: int = 512, interpret: bool = False):
    """Returns (V', Gamma'). w: (K, N); a: (K,); rowsum: (K,); colsum: (N,)."""
    K, N = w.shape
    bk = min(bk, K)
    bn = min(bn, N)
    assert K % bk == 0 and N % bn == 0
    a2 = a.reshape(K, 1).astype(jnp.float32)
    row2 = (rowsum if rowsum is not None
            else jnp.ones((K,), jnp.float32)).reshape(K, 1)
    col2 = (colsum if colsum is not None
            else jnp.ones((N,), jnp.float32)).reshape(1, N)
    grid = (K // bk, N // bn)
    return pl.pallas_call(
        functools.partial(_fuse_kernel, v_lr=v_lr, lam=lam, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),   # w
            pl.BlockSpec((bk, 1), lambda i, j: (i, 0)),    # a
            pl.BlockSpec((bk, 1), lambda i, j: (i, 0)),    # rowsum
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),    # colsum
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),   # gamma
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),   # v
        ],
        out_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((K, N), jnp.float32),
                   jax.ShapeDtypeStruct((K, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(w, a2, row2, col2, gamma, v)
