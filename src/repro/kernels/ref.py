"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --- nm_spmm ---------------------------------------------------------------

def compress_24(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Dense (K, N) (assumed or forced 2:4 along K) -> (vals, idx).

    Keeps the top-2 |w| per contiguous group of 4 along K, positions
    ascending.  Exact inverse of decompress_24 for genuinely 2:4 inputs.
    """
    K, N = w.shape
    assert K % 4 == 0
    g = w.reshape(K // 4, 4, N)
    order = jnp.argsort(-jnp.abs(g), axis=1)[:, :2]        # (K/4, 2, N)
    idx = jnp.sort(order, axis=1).astype(jnp.int8)
    vals = jnp.take_along_axis(g, idx.astype(jnp.int32), axis=1)
    return vals.reshape(K // 2, N).astype(w.dtype), idx.reshape(K // 2, N)


def decompress_24(vals: jax.Array, idx: jax.Array) -> jax.Array:
    halfK, N = vals.shape
    g = halfK // 2
    v = vals.reshape(g, 2, N)
    p = idx.reshape(g, 2, N).astype(jnp.int32)
    r = jnp.arange(4)[None, :, None]
    dense = jnp.zeros((g, 4, N), vals.dtype)
    for j in range(2):
        dense = dense + jnp.where(p[:, j:j + 1] == r, v[:, j:j + 1], 0)
    return dense.reshape(g * 4, N)


def nm_matmul_ref(x: jax.Array, vals: jax.Array, idx: jax.Array) -> jax.Array:
    w = decompress_24(vals, idx)
    return (x @ w.astype(x.dtype)).astype(x.dtype)


# --- saliency_fuse ---------------------------------------------------------

def saliency_step_ref(w, a, gamma, v, *, v_lr: float, lam: float,
                      rowsum=None, colsum=None):
    """One fused local-metric + dual + prox step (fp32 math).

    S = |w| * a[:, None]                          (wanda; a = ||X_j||_2)
    or, when rowsum/colsum given (RIA family):
    S = (|w|/rowsum + |w|/colsum) * sqrt(a)[:, None]
    V' = v - v_lr * (gamma - S);  Gamma' = soft(V', lam).
    """
    wf = jnp.abs(w.astype(jnp.float32))
    af = a.astype(jnp.float32)
    if rowsum is None:
        s = wf * af[:, None]
    else:
        s = (wf / (rowsum.astype(jnp.float32) + 1e-12)
             + wf / (colsum.astype(jnp.float32) + 1e-12)) * \
            jnp.sqrt(jnp.maximum(af, 1e-12))[:, None]
    v_new = v.astype(jnp.float32) - v_lr * (gamma.astype(jnp.float32) - s)
    gamma_new = jnp.sign(v_new) * jnp.maximum(jnp.abs(v_new) - lam, 0.0)
    return v_new, gamma_new


# --- nm_prox / nm mask -----------------------------------------------------

def nm_mask_ref(s: jax.Array, n: int = 2, m: int = 4) -> jax.Array:
    """Top-n per contiguous group of m along axis 0 (ties -> lower index).

    Rank-based: element i is kept iff fewer than n elements beat it, where
    "beats" = strictly greater, or equal with a lower position.
    """
    K, N = s.shape
    g = jnp.abs(s.astype(jnp.float32)).reshape(K // m, m, N)
    gi = g[:, :, None, :]
    gj = g[:, None, :, :]
    pos = jnp.arange(m)
    j_earlier = pos[None, None, :, None] < pos[None, :, None, None]
    rank = jnp.sum((gj > gi) | ((gj == gi) & j_earlier), axis=2)
    return (rank < n).reshape(K, N)


def prox24_ref(w: jax.Array, lam: float, *, iters: int = 12,
               damping: float = 0.7) -> jax.Array:
    """Mirror of core.prox.prox_nm24 for 2-D inputs (oracle shared there)."""
    from repro.core.prox import prox_nm24
    return prox_nm24(w, lam, iters=iters, damping=damping)
