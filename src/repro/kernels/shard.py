"""Tensor-parallel sparse execution: shard_map wrappers with explicit
K-partial accumulation.

GSPMD never K-shards the compressed kernels: ``vals`` (K/2, N) and ``idx``
(K/2 | K/8, N) are two pytree leaves whose reduction dims the partitioner
cannot connect through a Pallas call, so PR 2's component-wise sharding
specs executed replicated-or-N-sharded.  These wrappers make the contraction
explicit: each device runs the Pallas kernel on its local (K_loc/2, N_loc)
vals and (K_loc/8, N_loc) packed-idx shards producing a *float32 partial*,
and a single ``jax.lax.psum`` over the K mesh axes combines partials before
the one cast back to the activation dtype.

The psum is *deferred across projection groups*: the fused gate/up pair and
the MoE up/gate expert banks each run two local kernels and then ONE
variadic ``psum((h, g), axes)`` - one collective per projection group, not
per kernel.  Sites are labeled (mlp / attn / moe / attn_kv) and every
wrapper increments ``dist.psum`` / ``dist.psum_bytes`` at trace time (once
per compiled trace - the static per-decode-step collective count the bench
asserts on) and records ``dist.collective_ms`` on eager calls.

``decode_attend_sharded`` is the KV-cache sibling: capacity-sharded caches
run a local flash partial (TPU) or an exact-mimic masked softmax (CPU
interpret parity), then pmax/psum combine - a sharded fleet member never
falls back to replicated weights or a replicated cache.

``REPRO_FORCE_REPLICATED=1`` disables every K-sharded path (tags are not
stamped, caches stay per-GSPMD) - the escape hatch when a mesh/collective
bug needs bisecting.
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.dist.axes import current_rules
from repro.models import common as cm

FORCE_REPLICATED_ENV = "REPRO_FORCE_REPLICATED"


def replicated_forced() -> bool:
    """Env escape hatch: force the replicated/GSPMD fallback everywhere."""
    return os.environ.get(FORCE_REPLICATED_ENV, "") not in ("", "0")


def _ax_tuple(entry) -> tuple[str, ...]:
    """Spec entry (None | name | tuple of names) -> tuple of mesh axes."""
    if entry is None:
        return ()
    return (entry,) if isinstance(entry, str) else tuple(entry)


def axes_size(mesh, entry) -> int:
    n = 1
    for a in _ax_tuple(entry):
        n *= mesh.shape[a]
    return n


def k_sharded(st) -> bool:
    """Does this leaf's tag route through the shard-mapped kernels here?

    True when the leaf carries a non-None K entry AND rules are installed
    (the tag is stamped from the same rules the engine traces under, so the
    mesh axes are guaranteed present).
    """
    if replicated_forced():
        return False
    if getattr(st, "shard", None) is None or st.k_shard is None:
        return False
    return current_rules() is not None


def pair_k_sharded(st_a, st_b) -> bool:
    """Can a gate/up pair share one deferred psum? (same K mesh axes)"""
    return (k_sharded(st_a) and k_sharded(st_b)
            and st_a.shard[-2] == st_b.shard[-2]
            and st_a.vals.shape[-2] == st_b.vals.shape[-2])


def _eager(*arrays) -> bool:
    return not any(isinstance(a, jax.core.Tracer) for a in arrays)


def _count(site: str, payload_bytes: int, n_psum: int = 1) -> None:
    """Collective accounting.  Under jit this runs at trace time, so the
    counters advance once per compiled trace: the value IS the static
    per-step collective count (and per-device payload bytes)."""
    obs.inc("dist.psum", n_psum, site=site)
    obs.inc("dist.psum_bytes", payload_bytes, site=site)


def _timed(site: str, eager: bool, fn, *args):
    if eager and obs.enabled():
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        obs.observe("dist.collective_ms", (time.perf_counter() - t0) * 1e3,
                    site=site)
        return out
    return fn(*args)


def _local_nm(x, vals, idx, expert: bool = False):
    """One device's kernel call on shard-local operands -> f32 partial.

    Layout is inferred from the *local* shapes (the vals/idx row ratio is
    sharding-invariant, see ``nm_spmm.infer_layout``); block selection sees
    local dims too, so a K_loc smaller than the global tile caps cleanly.
    """
    from repro.kernels.nm_spmm import (infer_layout, nm_matmul,
                                       nm_matmul_expert)
    from repro.sparse.apply import _run_nm
    layout = infer_layout(2 * vals.shape[-2], idx.shape)
    return _run_nm(x, vals, idx, layout,
                   kernel=nm_matmul_expert if expert else nm_matmul,
                   out_dtype=jnp.float32)


# ---------------------------------------------------------------------------
# 2-D kernels (MLP / attention projections)
# ---------------------------------------------------------------------------

def nm_dense_sharded(st, x2: jax.Array, *, site: str) -> jax.Array:
    """x2 (M, K) @ K-sharded compressed (K, N) -> (M, N); one psum."""
    rules = current_rules()
    mesh = rules.mesh
    k_e, n_e = st.shard[-2], st.shard[-1]
    k_axes = _ax_tuple(k_e)
    out_dt = x2.dtype
    M = x2.shape[0]
    n_loc = st.shape[-1] // axes_size(mesh, n_e)
    _count(site, M * n_loc * 4)
    idx_plane = st.idx if st.kernel_layout == "packed2" else st.unpacked_idx()

    def local(xl, vl, il):
        # the site: scope lands in the psum eqn's name_stack, so the jaxpr
        # auditor attributes collectives per site without running anything
        with jax.named_scope(f"site:{site}"):
            y = _local_nm(xl, vl, il)
            return jax.lax.psum(y, k_axes).astype(out_dt)

    f = cm.shard_map(local, mesh=mesh,
                     in_specs=(P(None, k_e), P(k_e, n_e), P(k_e, n_e)),
                     out_specs=P(None, n_e), check_rep=False)
    return _timed(site, _eager(x2), f, x2, st.vals.astype(out_dt), idx_plane)


def nm_dense2_sharded(st_a, st_b, x2: jax.Array, *, site: str
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused pair sharing K (gated-MLP up+gate): two local kernels, ONE
    deferred variadic psum over the pair -> one collective for the group."""
    rules = current_rules()
    mesh = rules.mesh
    k_e = st_a.shard[-2]
    n_a, n_b = st_a.shard[-1], st_b.shard[-1]
    k_axes = _ax_tuple(k_e)
    out_dt = x2.dtype
    M = x2.shape[0]
    payload = (M * (st_a.shape[-1] // axes_size(mesh, n_a))
               + M * (st_b.shape[-1] // axes_size(mesh, n_b))) * 4
    _count(site, payload)
    ia = st_a.idx if st_a.kernel_layout == "packed2" else st_a.unpacked_idx()
    ib = st_b.idx if st_b.kernel_layout == "packed2" else st_b.unpacked_idx()

    def local(xl, va, ila, vb, ilb):
        with jax.named_scope(f"site:{site}"):
            ya = _local_nm(xl, va, ila)
            yb = _local_nm(xl, vb, ilb)
            ya, yb = jax.lax.psum((ya, yb), k_axes)
            return ya.astype(out_dt), yb.astype(out_dt)

    f = cm.shard_map(local, mesh=mesh,
                     in_specs=(P(None, k_e), P(k_e, n_a), P(k_e, n_a),
                               P(k_e, n_b), P(k_e, n_b)),
                     out_specs=(P(None, n_a), P(None, n_b)), check_rep=False)
    return _timed(site, _eager(x2), f, x2, st_a.vals.astype(out_dt), ia,
                  st_b.vals.astype(out_dt), ib)


# ---------------------------------------------------------------------------
# Expert banks (MoE)
# ---------------------------------------------------------------------------

def nm_moe_sharded(st, x3: jax.Array, *, site: str = "moe") -> jax.Array:
    """x3 (E, M, K) @ K-sharded expert bank (E, K, N) -> (E, M, N).

    The expert grid rides inside ONE shard_map: every expert's partial comes
    out of a single ``nm_matmul_expert`` call and one psum combines the
    whole bank - not one collective per expert.
    """
    rules = current_rules()
    mesh = rules.mesh
    e_e, k_e, n_e = st.shard[-3], st.shard[-2], st.shard[-1]
    k_axes = _ax_tuple(k_e)
    out_dt = x3.dtype
    E, M, _ = x3.shape
    e_loc = E // axes_size(mesh, e_e)
    n_loc = st.shape[-1] // axes_size(mesh, n_e)
    _count(site, e_loc * M * n_loc * 4)
    idx_plane = st.idx if st.kernel_layout == "packed2" else st.unpacked_idx()

    def local(xl, vl, il):
        with jax.named_scope(f"site:{site}"):
            y = _local_nm(xl, vl, il, expert=True)
            return jax.lax.psum(y, k_axes).astype(out_dt)

    f = cm.shard_map(local, mesh=mesh,
                     in_specs=(P(e_e, None, k_e), P(e_e, k_e, n_e),
                               P(e_e, k_e, n_e)),
                     out_specs=P(e_e, None, n_e), check_rep=False)
    return _timed(site, _eager(x3), f, x3, st.vals.astype(out_dt), idx_plane)


def nm_moe2_sharded(st_up, st_gate, x3: jax.Array, *, site: str = "moe"
                    ) -> tuple[jax.Array, jax.Array]:
    """Fused up+gate expert banks: two local expert-grid kernels, one
    deferred variadic psum across the pair AND the expert grid."""
    rules = current_rules()
    mesh = rules.mesh
    e_e, k_e = st_up.shard[-3], st_up.shard[-2]
    n_u, n_g = st_up.shard[-1], st_gate.shard[-1]
    k_axes = _ax_tuple(k_e)
    out_dt = x3.dtype
    E, M, _ = x3.shape
    e_loc = E // axes_size(mesh, e_e)
    payload = (e_loc * M * (st_up.shape[-1] // axes_size(mesh, n_u))
               + e_loc * M * (st_gate.shape[-1] // axes_size(mesh, n_g))) * 4
    _count(site, payload)
    iu = (st_up.idx if st_up.kernel_layout == "packed2"
          else st_up.unpacked_idx())
    ig = (st_gate.idx if st_gate.kernel_layout == "packed2"
          else st_gate.unpacked_idx())

    def local(xl, vu, ilu, vg, ilg):
        with jax.named_scope(f"site:{site}"):
            h = _local_nm(xl, vu, ilu, expert=True)
            g = _local_nm(xl, vg, ilg, expert=True)
            h, g = jax.lax.psum((h, g), k_axes)
            return h.astype(out_dt), g.astype(out_dt)

    f = cm.shard_map(local, mesh=mesh,
                     in_specs=(P(e_e, None, k_e), P(e_e, k_e, n_u),
                               P(e_e, k_e, n_u), P(e_e, k_e, n_g),
                               P(e_e, k_e, n_g)),
                     out_specs=(P(e_e, None, n_u), P(e_e, None, n_g)),
                     check_rep=False)
    return _timed(site, _eager(x3), f, x3, st_up.vals.astype(out_dt), iu,
                  st_gate.vals.astype(out_dt), ig)


# ---------------------------------------------------------------------------
# Decode attention over a capacity-sharded KV cache
# ---------------------------------------------------------------------------

def kv_shard_axes(B: int, C: int) -> tuple[str, ...]:
    """Mesh axes of the decode-KV capacity dim, () when the sharded path is
    off.  Mirrors ``dist.sharding.cache_sharding``'s B > 1 layout (capacity
    over "model") so the shard_map in_specs match how the engine placed the
    caches - no resharding on entry.
    """
    rules = current_rules()
    if rules is None or replicated_forced():
        return ()
    mesh = rules.mesh
    if "model" not in mesh.axis_names:
        return ()
    m = mesh.shape["model"]
    if m <= 1 or B <= 1 or C % m:
        return ()
    return ("model",)


def decode_attend_sharded(qg: jax.Array, cache_k: jax.Array,
                          cache_v: jax.Array, ok: jax.Array, *,
                          axes: tuple[str, ...], scale: float) -> jax.Array:
    """Partial-softmax decode attention over capacity-sharded KV.

    qg (B, K, G, D) replicated; cache_k/v (B, C, K, D) capacity-sharded over
    ``axes``; ok (B, C) valid-slot mask (position + window, precomputed by
    the caller so both paths mask identically).

    CPU (interpret) path mimics the replicated einsum element-for-element:
    local scores, global max via pmax, exp/sum, the same
    ``(p / l).astype(v.dtype)`` cast the oracle makes *before* the PV
    einsum, then a psum of the f32 PV partials - token parity with the
    replicated engine.  TPU path runs the flash partial kernel per shard
    and combines (l, acc) with ONE variadic psum after an m-pmax.
    """
    from repro.kernels import ops
    rules = current_rules()
    mesh = rules.mesh
    B, Kh, G, _ = qg.shape
    Dv = cache_v.shape[-1]
    NEG = -1e30  # attention.NEG_INF: both paths mask with the same constant

    if ops._interp():
        # exact-mimic combine: 1 pmax + 2 psums
        _count("attn_kv", B * Kh * G * (1 + Dv) * 4, n_psum=2)

        def local(q, ck, cv, okl):
            with jax.named_scope("site:attn_kv"):
                s = jnp.einsum("bkgd,bckd->bkgc", q, ck,
                               preferred_element_type=jnp.float32) * scale
                s = jnp.where(okl[:, None, None, :], s, NEG)
                m = jax.lax.pmax(jnp.max(s, axis=-1, keepdims=True), axes)
                p = jnp.exp(s - m)
                l = jax.lax.psum(jnp.sum(p, axis=-1, keepdims=True), axes)
                w = (p / l).astype(cv.dtype)
                o = jnp.einsum("bkgc,bckd->bkgd", w, cv,
                               preferred_element_type=jnp.float32)
                return jax.lax.psum(o, axes).astype(qg.dtype)
    else:
        # flash partial + 1 pmax + 1 variadic psum over (l, acc)
        _count("attn_kv", B * Kh * G * (1 + Dv) * 4, n_psum=1)

        def local(q, ck, cv, okl):
            with jax.named_scope("site:attn_kv"):
                bias = jnp.where(okl, 0.0, NEG).astype(jnp.float32)
                acc, m, l = ops.decode_attention_partial(q, ck, cv, bias,
                                                         scale=scale)
                mg = jax.lax.pmax(m, axes)
                corr = jnp.exp(m - mg)
                l, acc = jax.lax.psum((l * corr, acc * corr), axes)
                return (acc / jnp.maximum(l, 1e-30)).astype(qg.dtype)

    ax = axes[0] if len(axes) == 1 else axes
    f = cm.shard_map(local, mesh=mesh,
                     in_specs=(P(None, None, None, None),
                               P(None, ax, None, None),
                               P(None, ax, None, None), P(None, ax)),
                     out_specs=P(None, None, None, None), check_rep=False)
    return _timed("attn_kv", _eager(qg), f, qg, cache_k, cache_v, ok)
