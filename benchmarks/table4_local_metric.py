"""Paper Table 4: UniPruning under different local metrics x sparsity.

One MaskBank artifact per metric; the three budgets are one-shot
re-thresholds of each bank - no inline stats/search runs here."""
from __future__ import annotations

from benchmarks.common import evaluate, fmt_row, get_bank, get_trained
from repro.configs.base import PruneConfig
from repro.core import masks as masks_mod
from repro.data.synthetic import batches_for

SPARSITIES = [0.5, 0.6, 0.7]
METRICS = ["magnitude", "wanda", "ria", "stochria"]


def run(out_rows: list) -> None:
    print("\n=== Table 4: local-metric ablation (llama-tiny) ===")
    print(fmt_row(["metric"] + [f"ppl@{int(s*100)}%" for s in SPARSITIES]))
    cfg, params = get_trained("llama-tiny")
    calib = batches_for(cfg, n=10, batch=8, seq=128, split="calib")
    for m in METRICS:
        pcfg = PruneConfig(local_metric=m, steps=60)
        # the stochria search IS table1/fig2/oneshot's bank: share it
        tag = "unstructured" if m == "stochria" else f"metric-{m}"
        bank = get_bank("llama-tiny", cfg, params, pcfg, calib, tag=tag)
        ppls = [evaluate(cfg, masks_mod.apply_masks(
            params, bank.masks_at(sparsity=s)))["ppl"] for s in SPARSITIES]
        print(fmt_row([m] + [f"{p:.2f}" for p in ppls]))
        out_rows.append({"table": 4, "metric": m,
                         **{f"ppl{int(s*100)}": p
                            for s, p in zip(SPARSITIES, ppls)}})
