"""Paper section 4.1: one search -> masks at arbitrary sparsity levels.

The search is the shared table1 MaskBank artifact; the five budgets here
are pure re-thresholds of that persisted state."""
from __future__ import annotations

import time

from benchmarks.common import evaluate, fmt_row, get_bank, get_trained
from benchmarks.table1_unstructured import PCFG
from repro.core import masks as masks_mod
from repro.data.synthetic import batches_for

LEVELS = [0.4, 0.5, 0.6, 0.7, 0.8]


def run(out_rows: list) -> None:
    print("\n=== One-shot multi-sparsity export (llama-tiny) ===")
    cfg, params = get_trained("llama-tiny")
    calib = batches_for(cfg, n=10, batch=8, seq=128, split="calib")
    t0 = time.time()
    bank = get_bank("llama-tiny", cfg, params, PCFG, calib,
                    tag="unstructured")
    t_cal = time.time() - t0
    t0 = time.time()
    grid = bank.masks_grid(LEVELS)
    t_export = time.time() - t0
    print(fmt_row(["sparsity", "ppl", "acc"]))
    for s in LEVELS:
        r = evaluate(cfg, masks_mod.apply_masks(params, grid[s]))
        print(fmt_row([f"{int(s*100)}%", f"{r['ppl']:.2f}",
                       f"{r['acc']:.3f}"]))
        out_rows.append({"table": "oneshot", "sparsity": s, **r})
    print(f"calibrate-or-load {t_cal:.0f}s + {len(LEVELS)} exports "
          f"{t_export:.1f}s - exports are sort-only re-thresholds of the "
          "persisted bank (paper's one-shot claim)")
