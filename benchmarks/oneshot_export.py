"""Paper section 4.1: one search -> masks at arbitrary sparsity levels."""
from __future__ import annotations

import time

from benchmarks.common import evaluate, fmt_row, get_trained
from repro.configs.base import PruneConfig
from repro.core import calibrate
from repro.data.synthetic import batches_for

LEVELS = [0.4, 0.5, 0.6, 0.7, 0.8]


def run(out_rows: list) -> None:
    print("\n=== One-shot multi-sparsity export (llama-tiny) ===")
    cfg, params = get_trained("llama-tiny")
    calib = batches_for(cfg, n=10, batch=8, seq=128, split="calib")
    pcfg = PruneConfig(local_metric="stochria", steps=60)
    t0 = time.time()
    pruned, state, _ = calibrate.unipruning_prune(cfg, pcfg, params, calib,
                                                  sparsities=LEVELS)
    t_total = time.time() - t0
    print(fmt_row(["sparsity", "ppl", "acc"]))
    for s in LEVELS:
        r = evaluate(cfg, pruned[s])
        print(fmt_row([f"{int(s*100)}%", f"{r['ppl']:.2f}",
                       f"{r['acc']:.3f}"]))
        out_rows.append({"table": "oneshot", "sparsity": s, **r})
    print(f"single search ({pcfg.steps} steps) + {len(LEVELS)} exports: "
          f"{t_total:.0f}s - exports are sort-only (paper's one-shot claim)")
