"""Sparsity-fleet bench: ONE bank artifact -> N budgets behind one router.

Exercises the full §4.3 serving story end-to-end on the smoke config:
calibrate once through ``launch.calibrate`` (which persists the mask
bank), then ``SparsityFleet.from_artifact``
materializes dense (0.0), unstructured-0.5 (masked-dense), and 2:4
(compressed kernels) members that serve concurrently.  Tracked per PR as
``results/bench/BENCH_fleet.json`` and gated by ``benchmarks/run.py
--smoke``:

* per-budget tok/s + compressed weight-byte ratio (2:4 at the packed bound
  9/16, every member <= dense 1.0),
* the NxN token-agreement matrix across members (diagonal == 1.0),
* the 0.0-budget member token-identical to a plain dense ``ServeEngine``,
* the bank thresholded exactly once per non-dense budget (memoization).

CPU numbers are functional (interpret-mode kernel); the byte ratio is the
TPU bandwidth story.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.table8_inference import write_serve_json

BUDGETS = ["0.0", "0.5", "2:4"]


def fleet_bench(out_rows: list, *, arch: str = "llama3.2-1b",
                steps: int = 6) -> dict:
    from repro.configs.base import PruneConfig, get_smoke_config
    from repro.data.synthetic import batches_for
    from repro.launch import calibrate as launch_cal
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    from repro.serve.fleet import SparsityFleet

    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    calib = batches_for(cfg, n=2, batch=2, seq=16, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=2)
    with tempfile.TemporaryDirectory() as td:
        bank_dir = td + "/bank"
        launch_cal.calibrate_to_bank(bank_dir, cfg=cfg, pcfg=pcfg,
                                     params=params, calib=calib, arch=arch,
                                     smoke=True)
        fleet = SparsityFleet.from_artifact(bank_dir, params, BUDGETS,
                                            slots=6, capacity=32)

    prompts = [np.array([5, 6, 7, 8]), np.array([9, 10, 11]),
               np.array([1, 2]), np.array([12, 13, 14, 15, 16])]
    # tagged traffic: every member serves every prompt -> NxN agreement
    matrix, outs = fleet.agreement_matrix(prompts, steps)
    # weighted A/B traffic: deterministic split + live agreement vs densest
    ab = {"0.0": 1, "0.5": 1, "2:4": 2}
    ab_rids = [fleet.submit(p, steps, ab=ab) for p in prompts * 2]
    t0 = time.perf_counter()
    ab_res = fleet.run()
    ab_dt = time.perf_counter() - t0
    assert set(ab_rids) <= set(ab_res), "A/B requests lost by the router"
    report = fleet.report()

    # oracle: the 0.0 member must be token-identical to a plain dense engine
    eng = ServeEngine(cfg, params, slots=2, capacity=32)
    rids = [eng.submit(p, steps) for p in prompts]
    res = eng.run()
    dense_parity = [res[r] for r in rids] == outs["0.0"]

    result = {
        "arch": arch, "backend": jax.default_backend(),
        "decode_steps": steps, "budgets": list(fleet.engines),
        "reference": report["reference"],
        "per_budget": report["budgets"],
        "token_agreement": matrix,
        "ab_weights": ab, "ab_requests": len(ab_rids),
        "ab_seconds": ab_dt,
        "mask_thresholds_computed": len(fleet.bank._mask_cache),
        "dense_member_matches_plain_engine": dense_parity,
    }
    print(f"\n=== fleet bench ({arch} smoke, {jax.default_backend()}) ===")
    print(f"one bank -> {len(fleet.engines)} budgets "
          f"({result['mask_thresholds_computed']} threshold passes), "
          f"reference {report['reference']}")
    for name, r in report["budgets"].items():
        print(f"  {name:>6}: {r['requests']} reqs, "
              f"{(r['tok_s'] or 0):8.1f} tok/s, "
              f"byte ratio {r['weight_bytes_ratio']:.4f}, "
              f"shared dense leaves {r['shared_dense_leaves']}")
    print(f"dense member == plain dense engine: {dense_parity}")
    out_rows.append({"table": "fleet", **result})
    return result


def run(out_rows: list) -> None:
    fleet_bench(out_rows)


if __name__ == "__main__":
    rows: list = []
    res = fleet_bench(rows)
    print("wrote", write_serve_json(res, name="BENCH_fleet.json"))
