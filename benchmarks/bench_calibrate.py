"""Calibration-pipeline bench: the mesh-native stats + search refactor.

Tracked per PR as ``results/bench/BENCH_calibrate.json`` and gated by
``benchmarks/run.py --smoke``:

* stats-pass throughput (calibration tok/s) for the jitted sharded pass vs
  the eager tape oracle, plus the parity flag between the two (the shared
  ``calibrate.stats_parity`` criterion the test suite enforces),
* mirror-descent search steps/s, eager one-dispatch-per-step vs the
  ``lax.scan``-chunked jitted path with donated state buffers - measured
  MARGINALLY (time difference between a long and a short run of the same
  compiled program shape) so jit compile time cancels out of the metric,
* the search's resident memory: live device bytes after the scanned search
  and the SearchState's own three-fp32-trees footprint (the budget the
  sharded state distributes at mesh scale).

CPU numbers are functional; the scanned-vs-eager ratio and the state-bytes
footprint are the trajectory tracked across PRs.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.table8_inference import write_serve_json


def _live_bytes() -> int:
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in jax.live_arrays())


def calibrate_bench(out_rows: list, *, arch: str = "llama3.2-1b",
                    steps: int = 8) -> dict:
    import dataclasses

    from repro.configs.base import PruneConfig, get_smoke_config
    from repro.core import calibrate, mirror
    from repro.core.prunable import prunable_map
    from repro.data.synthetic import batches_for
    from repro.models import model as M

    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    calib = batches_for(cfg, n=4, batch=2, seq=32, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=steps,
                       stats_batches=4)
    tokens = sum(int(np.asarray(b["tokens"]).size) for b in calib)

    def timed_stats(impl):
        calibrate.collect_stats(cfg, params, calib, pcfg=pcfg,
                                impl=impl)  # warm the jit cache
        t0 = time.perf_counter()
        stats = calibrate.collect_stats(cfg, params, calib, pcfg=pcfg,
                                        impl=impl)
        jax.block_until_ready([x for x in jax.tree.leaves(
            stats, is_leaf=lambda x: x is None) if x is not None])
        return stats, time.perf_counter() - t0

    jit_stats, t_jit = timed_stats("jit")
    tape_stats, t_tape = timed_stats("tape")
    worst_fro, parity, n_leaves = calibrate.stats_parity(
        tape_stats, jit_stats, prunable_map(params))

    def timed_search(n_steps, chunk):
        p = dataclasses.replace(pcfg, steps=n_steps)
        t0 = time.perf_counter()
        state, _ = calibrate.run_search(cfg, p, params, calib, jit_stats,
                                        scan_chunk=chunk)
        jax.block_until_ready(state.step)
        return time.perf_counter() - t0

    # marginal steps/s: run_search builds fresh jits per call, so a single
    # timing is dominated by trace+compile.  Timing N and 2N steps of the
    # SAME program shape (eager: per-step program; scanned: a fixed
    # `steps`-long scan chunk) and differencing cancels the compile cost,
    # leaving pure dispatch/execute throughput.
    t_eager = timed_search(2 * steps, 0) - timed_search(steps, 0)
    t_scan = timed_search(2 * steps, steps) - timed_search(steps, steps)
    t_eager, t_scan = max(t_eager, 1e-9), max(t_scan, 1e-9)

    # resident footprint, not an in-flight peak: live arrays after the
    # search plus the SearchState's own three-fp32-trees budget (what
    # search_state_sharding distributes on a real mesh)
    state_bytes = sum(
        int(np.prod(x.shape)) * x.dtype.itemsize
        for x in jax.tree.leaves(
            mirror.init_search(params, jax.random.key(17)),
            is_leaf=lambda x: x is None)
        if x is not None and hasattr(x, "shape"))
    live_after = _live_bytes()

    result = {
        "arch": arch, "backend": jax.default_backend(),
        "calib_tokens": tokens, "stats_batches": pcfg.stats_batches,
        "stats_tok_s_jit": tokens / max(t_jit, 1e-9),
        "stats_tok_s_tape": tokens / max(t_tape, 1e-9),
        "stats_parity_worst_rel_fro": worst_fro,
        "stats_parity_leaves": n_leaves,
        "tape_parity": parity,
        "search_steps": steps,
        "search_steps_s_eager": steps / t_eager,
        "search_steps_s_scanned": steps / t_scan,
        "scanned_vs_eager": t_eager / t_scan,
        "search_state_bytes": int(state_bytes),
        "live_bytes_after_search": int(live_after),
    }
    print(f"\n=== calibrate bench ({arch} smoke, "
          f"{jax.default_backend()}) ===")
    print(f"stats: jit {result['stats_tok_s_jit']:.0f} tok/s vs tape "
          f"{result['stats_tok_s_tape']:.0f} tok/s; parity "
          f"{parity} (worst rel fro {worst_fro:.2e} over "
          f"{n_leaves} prunable leaves)")
    print(f"search: scanned {result['search_steps_s_scanned']:.2f} steps/s "
          f"vs eager {result['search_steps_s_eager']:.2f} steps/s "
          f"({result['scanned_vs_eager']:.2f}x, marginal); search state "
          f"{state_bytes / 1e6:.1f} MB, live after "
          f"{live_after / 1e6:.1f} MB")
    out_rows.append({"table": "calibrate", **result})
    return result


def run(out_rows: list) -> None:
    calibrate_bench(out_rows)


if __name__ == "__main__":
    rows: list = []
    res = calibrate_bench(rows)
    print("wrote", write_serve_json(res, name="BENCH_calibrate.json"))
