"""Per-computation attribution of the roofline terms: which while bodies /
fusions account for the bytes, flops and collectives after trip-count
multiplication.  Used by the EXPERIMENTS.md perf iterations to localize the
dominant term.

  PYTHONPATH=src python -m benchmarks.hlo_debug results/dryrun/<tag>.hlo.gz
"""
from __future__ import annotations

import sys

from repro.launch.hlo_analysis import (_analyze_comp, _parse_computations,
                                       analyze_file)


def main(path: str, top: int = 14) -> None:
    import gzip
    op = gzip.open if path.endswith(".gz") else open
    text = op(path, "rt").read()
    raw, entry = _parse_computations(text)
    comps = {name: _analyze_comp(lines) for name, lines in raw.items()}

    rows = []

    def visit(name, mult, parent_mult, in_fusion, depth=0):
        st = comps.get(name)
        if st is None or depth > 64:
            return
        if not in_fusion:
            rows.append((mult * st.bytes_out + parent_mult * st.dus_bytes,
                         mult * st.dot_flops,
                         mult * st.coll_bytes, mult, name))
        for kind, callee, cond in st.calls:
            if kind == "while":
                trip = comps[cond].trip_hint if cond in comps else 1
                visit(callee, mult * trip, mult, in_fusion, depth + 1)
            elif kind == "fusion":
                visit(callee, mult, parent_mult, True, depth + 1)
            else:
                visit(callee, mult, parent_mult, in_fusion, depth + 1)

    visit(entry, 1.0, 1.0, False)
    rows.sort(reverse=True)
    print(f"{'bytes':>12s} {'dotflops':>12s} {'coll':>12s} {'mult':>8s} name")
    for b, f, c, m, n in rows[:top]:
        print(f"{b:12.3e} {f:12.3e} {c:12.3e} {m:8.0f} {n[:70]}")
    s = analyze_file(path)
    print(f"\nTOTAL bytes {s.bytes_out:.3e} dotflops {s.dot_flops:.3e} "
          f"coll {s.coll_bytes:.3e} whiles {s.n_while} "
          f"trips {sorted(set(s.trip_counts))[:12]}")


if __name__ == "__main__":
    main(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 14)
