"""Self-speculative decoding bench: sparse member drafts, dense verifies.

Exercises the fleet's speculative path end-to-end on the smoke config:
calibrate once into a mask bank, build a two-member fleet (dense 0.0
verifier + unstructured-0.5 draft), and serve identical traffic three
ways - dense-only pinned, draft-only pinned, and spec-routed - through
the SAME engines and jit caches.  Tracked per PR as
``results/bench/BENCH_spec.json`` and gated by ``benchmarks/run.py
--smoke``:

* spec tok/s >= 1.2x the dense-only baseline (the perf claim),
* the spec stream BIT-IDENTICAL to the dense member decoding alone
  (greedy speculative decoding is lossless),
* acceptance rate / accepted-tokens-per-round from the fleet report.

Config notes: the draft is the 0.5 masked-dense member, not 2:4 - on CPU
the interpret-mode packed kernel makes the compressed member ~3x slower
than dense, which buries the speculation win under kernel overhead; on
TPU the compressed draft is the bandwidth story.  k is pinned high
(k=k_max=64 = the whole generation): smoke-weight streams echo heavily so
acceptance saturates, and one wide round per request amortizes the
per-dispatch host overhead that CPU decode timing is dominated by.  ONE
slot per member: speculation's classic win is low-batch latency, where
each dense decode dispatch moves a single row and host overhead is the
bottleneck; at high batch the draft scan and the dense loop cost the
same compute and the margin washes out.  Engines and jitted entry points
are built ONCE and reused across warmup and timed runs - fresh EngineFns
per run would time jit compilation, not decoding.
"""
from __future__ import annotations

import tempfile
import time

import jax
import numpy as np

from benchmarks.table8_inference import write_serve_json

BUDGETS = ["0.0", "0.5"]
SPEC = "draft:0.5,k:64,k_max:64"
SLOTS, CAPACITY, GEN = 2, 128, 64  # 1 slot per member (low-batch latency)


def spec_bench(out_rows: list, *, arch: str = "llama3.2-1b") -> dict:
    from repro.configs.base import PruneConfig, get_smoke_config
    from repro.data.synthetic import batches_for
    from repro.launch import calibrate as launch_cal
    from repro.models import model as M
    from repro.serve.fleet import SparsityFleet

    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    calib = batches_for(cfg, n=2, batch=2, seq=16, split="calib")
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=2)
    with tempfile.TemporaryDirectory() as td:
        bank_dir = td + "/bank"
        launch_cal.calibrate_to_bank(bank_dir, cfg=cfg, pcfg=pcfg,
                                     params=params, calib=calib, arch=arch,
                                     smoke=True)
        fleet = SparsityFleet.from_artifact(bank_dir, params, BUDGETS,
                                            slots=SLOTS, capacity=CAPACITY,
                                            spec=SPEC)

    prompts = [np.arange(1, 5 + i, dtype=np.int32) % 97 + 1
               for i in range(4)]

    def timed(route: dict) -> tuple[list[list[int]], float]:
        rids = [fleet.submit(p, GEN, **route) for p in prompts]
        t0 = time.perf_counter()
        res = fleet.run()
        return [res[r] for r in rids], time.perf_counter() - t0

    # warm every jit bucket (prefill, decode, draft_64, verify_64) OUTSIDE
    # the timed region; two spec passes make sure late-compiled buckets
    # (anything adaptive k visits) are hot too
    for route in ({"budget": "0.0"}, {"budget": "0.5"}, {"spec": True},
                  {"spec": True}):
        timed(route)

    # interleave the three modes inside each rep and take per-mode medians:
    # paired sampling cancels slow machine periods that min-of-n timing
    # hands to whichever mode got lucky
    reps = 5
    outs: dict[str, list[list[int]]] = {}
    times: dict[str, list[float]] = {"dense": [], "draft": [], "spec": []}
    for _ in range(reps):
        for mode, route in (("dense", {"budget": "0.0"}),
                            ("draft", {"budget": "0.5"}),
                            ("spec", {"spec": True})):
            o, dt = timed(route)
            assert outs.setdefault(mode, o) == o, \
                f"non-deterministic {mode} stream under timing"
            times[mode].append(dt)

    n_tok = sum(len(o) for o in outs["dense"])
    dense_tok_s = n_tok / float(np.median(times["dense"]))
    draft_tok_s = n_tok / float(np.median(times["draft"]))
    spec_tok_s = n_tok / float(np.median(times["spec"]))
    # speedups from per-rep PAIRED ratios (each rep's modes ran back to
    # back under the same machine conditions), not ratios of medians
    vs_dense = float(np.median([d / s for d, s
                                in zip(times["dense"], times["spec"])]))
    vs_draft = float(np.median([d / s for d, s
                                in zip(times["draft"], times["spec"])]))
    lossless = outs["spec"] == outs["dense"]

    report = fleet.report()
    spec_rep = report["spec"]
    result = {
        "arch": arch, "backend": jax.default_backend(),
        "spec": SPEC, "budgets": list(fleet.engines),
        "slots": SLOTS, "capacity": CAPACITY, "gen_tokens": GEN,
        "requests": len(prompts), "tokens_per_mode": n_tok,
        "spec_tok_s": spec_tok_s,
        "dense_tok_s": dense_tok_s,
        "draft_tok_s": draft_tok_s,
        "speedup_vs_dense": vs_dense,
        "speedup_vs_draft": vs_draft,
        "lossless_vs_dense": lossless,
        "accept_rate": spec_rep["accept_rate"],
        "accepted_tokens_per_round": spec_rep["accepted_tokens_per_round"],
        "rollbacks": spec_rep["rollbacks"],
        "spec_rounds": spec_rep["rounds"],
        "k_final": spec_rep["k"],
    }
    print(f"\n=== spec bench ({arch} smoke, {jax.default_backend()}) ===")
    print(f"spec {spec_tok_s:8.1f} tok/s  dense {dense_tok_s:8.1f}  "
          f"draft {draft_tok_s:8.1f}")
    print(f"speedup vs dense {result['speedup_vs_dense']:.2f}x  "
          f"vs draft {result['speedup_vs_draft']:.2f}x  "
          f"lossless={lossless}")
    print(f"accept_rate {spec_rep['accept_rate']:.3f}  "
          f"accepted/round {spec_rep['accepted_tokens_per_round']:.2f}  "
          f"rollbacks {spec_rep['rollbacks']}  k_final {spec_rep['k']}")
    out_rows.append({"table": "spec", **result})
    return result


def run(out_rows: list) -> None:
    spec_bench(out_rows)


if __name__ == "__main__":
    rows: list = []
    res = spec_bench(rows)
    print("wrote", write_serve_json(res, name="BENCH_spec.json"))
