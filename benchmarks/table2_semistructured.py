"""Paper Table 2: 2:4 semi-structured pruning PPL across methods.

Baselines take top-2-of-4 on their local metric; UniPruning adds the
R_{2:4} prox on W during search (Algorithm 1 N:M branch) and exports the
2:4 mask from Gamma.  Calibration state (stats + Gamma/V) comes from the
per-family N:M MaskBank artifact - no inline stats/search runs here."""
from __future__ import annotations

import jax

from benchmarks.common import FAMILIES, evaluate, fmt_row, get_bank, \
    get_trained
from repro.configs.base import PruneConfig
from repro.core import calibrate, masks as masks_mod
from repro.data.synthetic import batches_for

METHODS = ["magnitude", "wanda", "ria"]
PCFG = PruneConfig(local_metric="wanda", mode="nm", steps=60)


def run(out_rows: list) -> None:
    print("\n=== Table 2: 2:4 semi-structured PPL ===")
    print(fmt_row(["model", "method", "ppl", "acc"]))
    for fam in FAMILIES:
        cfg, params = get_trained(fam)
        dense = evaluate(cfg, params)
        print(fmt_row([fam, "dense", f"{dense['ppl']:.2f}",
                       f"{dense['acc']:.3f}"]))
        calib = batches_for(cfg, n=10, batch=8, seq=128, split="calib")
        bank = get_bank(fam, cfg, params, PCFG, calib, tag="nm")
        for m in METHODS:
            mask = calibrate.baseline_masks(m, params, bank.stats, 0.5,
                                            mode="nm",
                                            key=jax.random.key(5))
            r = evaluate(cfg, masks_mod.apply_masks(params, mask))
            print(fmt_row([fam, m, f"{r['ppl']:.2f}", f"{r['acc']:.3f}"]))
            out_rows.append({"table": 2, "model": fam, "method": m, **r})
        pruned = masks_mod.apply_masks(params, bank.masks_at())
        r = evaluate(cfg, pruned)
        print(fmt_row([fam, "unipruning", f"{r['ppl']:.2f}",
                       f"{r['acc']:.3f}"]))
        out_rows.append({"table": 2, "model": fam, "method": "unipruning",
                         **r})
