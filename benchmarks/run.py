"""Benchmark driver: one module per paper table/figure.

Prints each table and a final ``name,us_per_call,derived`` CSV summary;
writes structured results to results/bench/results.json.

``--smoke`` runs only the serve-path benches (CI gate): the dense-FFN bench
must produce ``results/bench/BENCH_serve.json`` with a compressed
weight-byte ratio at or under the 2-bit-packed bound of 9/16, token parity
vs masked-dense, and fused-vs-vmapped engine token parity; the MoE bench
must produce ``results/bench/BENCH_serve_moe.json`` with every expert bank
kernel-native packed (zero masked-dense fallbacks), the same 9/16 bound,
and the same token parities; the fleet bench must produce
``results/bench/BENCH_fleet.json`` with one mask bank serving >= 3 budgets
(thresholded once per non-dense budget), every member's weight-byte ratio
<= dense (the 2:4 member at the 9/16 bound), and the 0.0-budget member
token-identical to a plain dense engine; the calibrate bench must produce
``results/bench/BENCH_calibrate.json`` with the jitted sharded stats pass
matching the eager tape oracle (parity flag) and live scanned-vs-eager
search steps/s; the obs bench must produce
``results/bench/BENCH_obs.json`` with flight-recorder decode overhead
<= 3%, identical jitted dispatch counts with telemetry on and off,
per-budget fleet decode p50/p95, and per-chunk search series in the JSONL
trace under results/bench/obs_trace; the tensor-parallel bench must produce
``results/bench/BENCH_tp.json`` (from a forced-4-device child process) with
the K-sharded engine token-identical to the replicated oracle on (1,4) and
(2,2) meshes, a static per-decode-trace collective count, and the fused
up/gate pair costing ONE deferred psum; the spec bench must produce
``results/bench/BENCH_spec.json`` with the self-speculative fleet path
(sparse member drafts, dense member verifies in one batched pass) at
>= 1.2x dense-only tok/s, the spec stream bit-identical to the dense
member alone, and multi-token accepted runs - and exits non-zero
otherwise.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time


def smoke() -> None:
    from benchmarks import table8_inference

    rows: list[dict] = []
    result = table8_inference.serve_bench(rows)
    path = table8_inference.write_serve_json(result)
    assert path.exists(), path
    ratio = result["weight_bytes_ratio"]
    assert ratio is not None and ratio <= 9 / 16 + 1e-9, (
        f"compressed weight-byte ratio {ratio} exceeds the 2-bit-packed "
        "bound 9/16")
    assert result["tokens_match_masked_dense"], \
        "compressed decode diverged from masked-dense"
    assert result["engine_tokens_match_fused_vs_vmap"], \
        "fused engine decode diverged from the vmapped scan"

    moe = table8_inference.serve_bench_moe(rows)
    moe_path = table8_inference.write_serve_json(
        moe, name="BENCH_serve_moe.json")
    assert moe_path.exists(), moe_path
    moe_ratio = moe["weight_bytes_ratio"]
    assert moe_ratio is not None and moe_ratio <= 9 / 16 + 1e-9, (
        f"MoE compressed weight-byte ratio {moe_ratio} exceeds the "
        "2-bit-packed bound 9/16")
    assert moe["expert_leaves"] and moe["expert_kernel_native"], \
        "MoE expert banks are not executing kernel-native packed"
    assert moe["fallback_leaves"] == 0, (
        f"{moe['fallback_leaves']} pruned leaves fell back to masked-dense")
    assert moe["tokens_match_masked_dense"], \
        "MoE compressed decode diverged from masked-dense"
    assert moe["engine_tokens_match_fused_vs_vmap"], \
        "MoE fused engine decode diverged from the vmapped scan"
    from benchmarks import bench_fleet

    fleet = bench_fleet.fleet_bench(rows)
    fleet_path = table8_inference.write_serve_json(
        fleet, name="BENCH_fleet.json")
    assert fleet_path.exists(), fleet_path
    assert len(fleet["budgets"]) >= 3, fleet["budgets"]
    assert fleet["dense_member_matches_plain_engine"], (
        "the 0.0-budget fleet member diverged from a plain dense engine")
    non_dense = [b for b in fleet["budgets"] if ":" in b or float(b) > 0]
    assert fleet["mask_thresholds_computed"] == len(non_dense), (
        f"bank thresholded {fleet['mask_thresholds_computed']}x for "
        f"{len(non_dense)} non-dense budgets: memoization broken")
    for name, r in fleet["per_budget"].items():
        bound = 9 / 16 if ":" in name else 1.0
        assert r["weight_bytes_ratio"] <= bound + 1e-9, (
            f"fleet budget {name} weight-byte ratio "
            f"{r['weight_bytes_ratio']} exceeds {bound}")
        row = fleet["token_agreement"][name]
        assert set(row) == set(fleet["budgets"]), (
            f"agreement matrix row {name} missing members: {sorted(row)}")
        assert all(0.0 <= v <= 1.0 for v in row.values()), row

    from benchmarks import bench_calibrate

    cal = bench_calibrate.calibrate_bench(rows)
    cal_path = table8_inference.write_serve_json(
        cal, name="BENCH_calibrate.json")
    assert cal_path.exists(), cal_path
    assert cal["tape_parity"], (
        f"jitted sharded stats diverged from the tape oracle: worst "
        f"relative Frobenius error {cal['stats_parity_worst_rel_fro']:.3e} "
        f"over {cal['stats_parity_leaves']} prunable leaves")
    assert cal["stats_parity_leaves"] > 0, "stats parity checked no leaves"
    assert cal["search_steps_s_scanned"] > 0 and \
        cal["search_steps_s_eager"] > 0, cal
    from benchmarks import bench_obs

    ob = bench_obs.obs_bench(rows)
    ob_path = table8_inference.write_serve_json(ob, name="BENCH_obs.json")
    assert ob_path.exists(), ob_path
    assert ob["overhead_pct"] <= 3.0, (
        f"flight-recorder decode overhead {ob['overhead_pct']:.2f}% "
        "exceeds the 3% budget")
    assert ob["dispatch_counts_identical"], (
        f"telemetry changed the jitted dispatch count: "
        f"{ob['dispatches_per_run']}")
    for name, p in ob["fleet_decode_ms"].items():
        assert p["p50"] is not None and p["p95"] is not None, (
            f"fleet budget {name} missing decode p50/p95 with the "
            "recorder enabled")
    assert ob["trace_search_chunks"] >= 1 and ob["trace_series_ok"], (
        "run_search emitted no per-chunk loss/sparsity/mask-churn series "
        "into the JSONL trace")
    assert ob["trace_span_events"] >= 1, "no span events in the trace"

    from benchmarks import bench_tp

    tp = bench_tp.tp_bench(rows)
    tp_path = table8_inference.write_serve_json(tp, name="BENCH_tp.json")
    assert tp_path.exists(), tp_path
    assert tp["parity"], (
        "K-sharded decode diverged from the replicated oracle: "
        f"{ {n: m['tokens_match_replicated'] for n, m in tp['meshes'].items()} }")
    assert tp["collectives_static"], (
        "psum counters advanced on a same-shape decode: the collective "
        "count is not static per trace")
    psums22 = tp["meshes"]["2x2"]["decode_psums_per_trace"]
    assert psums22["mlp"] == 2, (
        f"mlp site costs {psums22['mlp']} psums per decode trace on (2, 2); "
        "the fused up/gate pair must share ONE deferred psum (2 = pair + "
        "down, 3 = deferral regressed)")
    assert psums22["attn"] == 4 and psums22["attn_kv"] >= 1, psums22

    from benchmarks import bench_spec

    sp = bench_spec.spec_bench(rows)
    sp_path = table8_inference.write_serve_json(sp, name="BENCH_spec.json")
    assert sp_path.exists(), sp_path
    assert sp["lossless_vs_dense"], (
        "speculative stream diverged from the dense member decoding alone "
        "- greedy self-speculation must be lossless")
    assert sp["speedup_vs_dense"] >= 1.2, (
        f"speculative decode at {sp['speedup_vs_dense']:.2f}x dense-only "
        "tok/s, below the 1.2x gate")
    assert sp["accept_rate"] is not None and 0.0 <= sp["accept_rate"] <= 1.0
    assert sp["accepted_tokens_per_round"] > 1.0, (
        f"{sp['accepted_tokens_per_round']:.2f} accepted tokens/round: "
        "speculation is not committing multi-token runs")

    print(f"smoke ok: wrote {path} (ratio {ratio:.4f}), {moe_path} "
          f"(ratio {moe_ratio:.4f}, {moe['expert_leaves']} expert banks "
          f"kernel-native), {fleet_path} "
          f"({len(fleet['budgets'])} budgets from one bank), {cal_path} "
          f"(scanned search {cal['scanned_vs_eager']:.2f}x eager, stats "
          f"parity ok), {ob_path} ({ob['overhead_pct']:.2f}% telemetry "
          f"overhead), {tp_path} "
          f"({tp['devices']}-device K-sharded decode, parity ok) and "
          f"{sp_path} (spec {sp['speedup_vs_dense']:.2f}x dense tok/s, "
          f"lossless)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="serve bench only + BENCH_serve.json assertions")
    if ap.parse_args().smoke:
        smoke()
        return
    from benchmarks import (bench_calibrate, bench_fleet, bench_obs,
                            bench_spec, bench_tp, fig2_high_sparsity,
                            oneshot_export, table1_unstructured,
                            table2_semistructured, table4_local_metric,
                            table5_mirror_ablation, table8_inference)

    rows: list[dict] = []
    timings: list[tuple[str, float]] = []
    for mod in [table1_unstructured, table2_semistructured,
                table4_local_metric, table5_mirror_ablation,
                fig2_high_sparsity, table8_inference, bench_fleet,
                bench_calibrate, bench_obs, bench_tp, bench_spec,
                oneshot_export]:
        name = mod.__name__.split(".")[-1]
        t0 = time.time()
        mod.run(rows)
        timings.append((name, time.time() - t0))

    out = pathlib.Path(__file__).resolve().parent.parent / "results" / "bench"
    out.mkdir(parents=True, exist_ok=True)
    (out / "results.json").write_text(json.dumps(rows, indent=1))
    serve_rows = [r for r in rows if r.get("table") == "serve"]
    if serve_rows:  # sparse-serving trajectory, tracked per PR
        table8_inference.write_serve_json(serve_rows[0])
    moe_rows = [r for r in rows if r.get("table") == "serve_moe"]
    if moe_rows:
        table8_inference.write_serve_json(moe_rows[0],
                                          name="BENCH_serve_moe.json")
    fleet_rows = [r for r in rows if r.get("table") == "fleet"]
    if fleet_rows:
        table8_inference.write_serve_json(fleet_rows[0],
                                          name="BENCH_fleet.json")
    cal_rows = [r for r in rows if r.get("table") == "calibrate"]
    if cal_rows:
        table8_inference.write_serve_json(cal_rows[0],
                                          name="BENCH_calibrate.json")
    obs_rows = [r for r in rows if r.get("table") == "obs"]
    if obs_rows:
        table8_inference.write_serve_json(obs_rows[0],
                                          name="BENCH_obs.json")
    tp_rows = [r for r in rows if r.get("table") == "tp"]
    if tp_rows:
        table8_inference.write_serve_json(tp_rows[0], name="BENCH_tp.json")
    spec_rows = [r for r in rows if r.get("table") == "spec"]
    if spec_rows:
        table8_inference.write_serve_json(spec_rows[0],
                                          name="BENCH_spec.json")

    print("\nname,us_per_call,derived")
    for name, dt in timings:
        derived = ""
        if name == "table8_inference":
            e2e = [r for r in rows
                   if r.get("module") == "end-to-end"]
            derived = f"proj_speedup={e2e[0]['proj_speedup']:.2f}x" if e2e \
                else ""
        if name == "table1_unstructured":
            uni = [r["ppl"] for r in rows
                   if r.get("table") == 1 and r["method"] == "unipruning"]
            derived = f"uni_mean_ppl={sum(uni)/len(uni):.2f}" if uni else ""
        print(f"{name},{dt*1e6:.0f},{derived}")


if __name__ == "__main__":
    main()
