"""Paper Table 8: inference efficiency from 2:4 sparsity, TPU-adapted.

On GPUs the paper measures sparse-tensor-core speedups (1.27-1.34x).  The
TPU adaptation is bandwidth: decode GEMMs are memory-bound, so the win is
the weight-byte ratio dense/compressed.  We report, per decode-shape GEMM of
a Qwen2.5-7B-like layer:
  * HBM bytes dense vs 2:4-compressed (+2-bit packed variant),
  * projected memory-bound speedup  min(ratio, ridge-limited),
  * wall-clock of the XLA-compiled dense matmul vs the compressed kernel's
    pure-jnp reference on CPU (functional sanity, not a TPU timing),
  * interpret-mode correctness of the Pallas kernel on these exact shapes.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_row
from repro.kernels import ref as kref
from repro.kernels.nm_spmm import nm_matmul

# Qwen2.5-7B-ish decode GEMMs (batch 8, one token) - the paper's modules
LAYERS = {
    "attn qkv":  (8, 3584, 3584 + 2 * 512),
    "attn out":  (8, 3584, 3584),
    "mlp gate/up": (8, 3584, 2 * 18944),
    "mlp down":  (8, 18944, 3584),
}
HBM_GBPS = 819.0
PEAK_FLOPS = 197e12


def run(out_rows: list) -> None:
    print("\n=== Table 8: 2:4 inference efficiency (TPU bandwidth model) ===")
    print(fmt_row(["module", "dense_MB", "nm_MB", "ratio", "proj_speedup",
                   "kernel_ok"], [12, 10, 10, 8, 12, 9]))
    tot_d = tot_c = 0.0
    for name, (M, K, N) in LAYERS.items():
        dense_b = K * N * 2                      # bf16 weights
        comp_b = (K // 2) * N * 2 + (K // 2) * N // 4  # vals + 2-bit idx
        act_b = (M * K + M * N) * 2
        t_dense = (dense_b + act_b) / (HBM_GBPS * 1e9)
        t_comp = (comp_b + act_b) / (HBM_GBPS * 1e9)
        t_flops = 2 * M * K * N / PEAK_FLOPS
        speed = (max(t_dense, t_flops)) / max(t_comp, t_flops)
        # correctness on the exact (padded) shape
        Kp, Np = K + (-K % 512), N + (-N % 256)
        w = jax.random.normal(jax.random.key(0), (Kp, Np), jnp.float32)
        vals, idx = kref.compress_24(w)
        x = 0.1 * jax.random.normal(jax.random.key(1), (8, Kp), jnp.float32)
        y = nm_matmul(x, vals, idx, bm=8, bk=512, bn=256, interpret=True)
        yr = kref.nm_matmul_ref(x, vals, idx)
        ok = bool(np.max(np.abs(np.asarray(y - yr))) /
                  (np.max(np.abs(np.asarray(yr))) + 1e-9) < 1e-4)
        tot_d += t_dense
        tot_c += t_comp
        print(fmt_row([name, f"{dense_b/1e6:.1f}", f"{comp_b/1e6:.1f}",
                       f"{dense_b/comp_b:.2f}", f"{speed:.2f}x", str(ok)],
                      [12, 10, 10, 8, 12, 9]))
        out_rows.append({"table": 8, "module": name,
                         "byte_ratio": dense_b / comp_b,
                         "proj_speedup": speed, "kernel_ok": ok})
    e2e = tot_d / tot_c
    print(f"end-to-end projected (GEMM-only) speedup: {e2e:.2f}x "
          f"(paper reports 1.27x e2e on H200)")
    out_rows.append({"table": 8, "module": "end-to-end", "proj_speedup": e2e})

    # wall-clock sanity: dense XLA vs decompress+matmul (CPU, not TPU)
    K, N, M = 2048, 2048, 8
    w = jax.random.normal(jax.random.key(0), (K, N), jnp.float32)
    vals, idx = kref.compress_24(w)
    x = jax.random.normal(jax.random.key(1), (M, K), jnp.float32)
    f_dense = jax.jit(lambda x, w: x @ w)
    f_comp = jax.jit(kref.nm_matmul_ref)
    f_dense(x, w).block_until_ready()
    f_comp(x, vals, idx).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f_dense(x, w).block_until_ready()
    td = (time.perf_counter() - t0) / 20
    t0 = time.perf_counter()
    for _ in range(20):
        f_comp(x, vals, idx).block_until_ready()
    tc = (time.perf_counter() - t0) / 20
    print(f"cpu wall (functional only): dense {td*1e6:.0f}us vs "
          f"compressed-ref {tc*1e6:.0f}us")
    out_rows.append({"table": 8, "module": "cpu_wall",
                     "dense_us": td * 1e6, "comp_us": tc * 1e6})
    serve_bench(out_rows)
    serve_bench_moe(out_rows)


def serve_bench(out_rows: list, *, arch: str = "llama3.2-1b",
                steps: int = 8) -> dict:
    """End-to-end serve-path bench: dense vs bank-style 2:4-compressed decode
    through the real model (tok/s + weight-byte ratio), tracked per PR as
    BENCH_serve.json.  Compressed decode runs twice - kernel-native 2-bit
    packed indices vs the int8 fallback plane - and the continuous-batching
    engine runs its fused single-invocation decode vs the legacy vmapped
    per-slot scan.  CPU numbers are functional (interpret-mode kernel), the
    byte ratio is the TPU bandwidth story."""
    from repro.configs.base import get_smoke_config
    from repro.core import masks as masks_mod, metrics as metrics_mod
    from repro.core.prunable import prunable_map
    from repro.data.synthetic import batches_for
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    from repro.sparse import apply as apply_mod

    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    masks = masks_mod.nm_masks(scores)
    sparse = apply_mod.sparsify_params(params, masks, axes=M.param_axes(cfg),
                                       idx_bits=2, dtype=jnp.bfloat16)
    sparse8 = apply_mod.sparsify_params(params, masks, axes=M.param_axes(cfg),
                                        idx_bits=8, dtype=jnp.bfloat16)
    rep = apply_mod.compressed_report(sparse)

    B, P = 4, 32
    batch = {k: jnp.asarray(v) for k, v in
             batches_for(cfg, n=1, batch=B, seq=P, split="valid")[0].items()}
    capacity = P + steps + 1

    def decode_toks_per_s(p):
        prefill = jax.jit(lambda pp, b: M.prefill(cfg, pp, b,
                                                  cache_capacity=capacity))
        decode = jax.jit(lambda pp, tok, c, t: M.decode_step(cfg, pp, tok,
                                                             c, t))
        logits, caches = prefill(p, batch)
        toks = jnp.argmax(logits, axis=-1)
        toks_hist = [np.asarray(toks)]
        decode(p, toks, caches, jnp.asarray(P, jnp.int32))  # compile
        t0 = time.perf_counter()
        for i in range(steps):
            logits, caches = decode(p, toks, caches,
                                    jnp.asarray(P + i, jnp.int32))
            toks = jnp.argmax(logits, axis=-1)
            toks_hist.append(np.asarray(toks))
        jax.block_until_ready(logits)
        return B * steps / (time.perf_counter() - t0), np.stack(toks_hist, 1)

    def engine_toks_per_s(decode_mode):
        eng = ServeEngine(cfg, sparse, slots=B, capacity=capacity,
                          decode_mode=decode_mode)
        prompt = np.arange(1, P) % cfg.vocab_size
        # warm-up run compiles prefill + decode; the timed run measures
        # steady-state decode, not trace speed
        for _ in range(B):
            eng.submit(prompt, steps)
        eng.run()
        rids = [eng.submit(prompt, steps) for _ in range(B)]
        t0 = time.perf_counter()
        res = eng.run()
        dt = time.perf_counter() - t0
        toks = [res[r] for r in rids]
        return B * steps / dt, toks

    dense_tps, dense_toks = decode_toks_per_s(params)
    masked_tps, masked_toks = decode_toks_per_s(
        masks_mod.apply_masks(params, masks))
    sparse_tps, sparse_toks = decode_toks_per_s(sparse)
    int8_tps, int8_toks = decode_toks_per_s(sparse8)
    fused_tps, fused_toks = engine_toks_per_s("fused")
    vmap_tps, vmap_toks = engine_toks_per_s("vmap")
    tokens_match = bool((sparse_toks == masked_toks).all())
    result = {
        "arch": arch, "backend": jax.default_backend(), "decode_steps": steps,
        "batch": B, "prompt_len": P,
        "dense_tok_s": dense_tps, "masked_tok_s": masked_tps,
        "compressed_tok_s": sparse_tps,          # 2-bit packed, kernel-native
        "compressed_int8_tok_s": int8_tps,       # int8 index fallback plane
        "engine_fused_tok_s": fused_tps,         # one decode call per step
        "engine_vmap_tok_s": vmap_tps,           # legacy per-slot vmapped
        "compressed_weight_bytes": rep["bytes_compressed"],
        "dense_weight_bytes_bf16": rep["bytes_dense_bf16"],
        "weight_bytes_ratio": rep["ratio"],
        "compressed_kernels": len(rep["layers"]),
        "kernel_native_packed": rep["kernel_native_packed"],
        "tokens_match_masked_dense": tokens_match,
        "tokens_match_packed_vs_int8": bool((sparse_toks == int8_toks).all()),
        "engine_tokens_match_fused_vs_vmap": fused_toks == vmap_toks,
    }
    print(f"\n=== serve bench ({arch} smoke, {jax.default_backend()}) ===")
    print(f"decode tok/s: dense {dense_tps:.1f}, masked {masked_tps:.1f}, "
          f"2:4 packed-2bit {sparse_tps:.1f}, 2:4 int8-idx {int8_tps:.1f} "
          f"(interpret-mode kernel on non-TPU backends)")
    print(f"engine decode tok/s: fused {fused_tps:.1f} vs vmapped "
          f"{vmap_tps:.1f} (tokens match: "
          f"{result['engine_tokens_match_fused_vs_vmap']})")
    print(f"pruned-layer weight bytes: {rep['bytes_compressed']} vs "
          f"{rep['bytes_dense_bf16']} dense bf16 "
          f"(ratio {rep['ratio']:.4f}, {rep['kernel_native_packed']} "
          f"kernel-native packed planes); tokens match masked-dense: "
          f"{tokens_match}")
    out_rows.append({"table": "serve", **result})
    return result


def serve_bench_moe(out_rows: list, *, arch: str = "mixtral-8x22b",
                    steps: int = 6) -> dict:
    """MoE serve bench: expert banks executing through the expert-grid
    kernel (no masked-dense fallback), tracked as BENCH_serve_moe.json.

    Asserts the three properties the smoke gate cares about: every expert
    bank compresses kernel-native (``kernel_layout == "packed2"``, zero
    fallback leaves in the masks-aware report), the headline weight-byte
    ratio stays at the 2-bit-packed bound 9/16, and the fused continuous-
    batching engine decodes token-identically to the masked-dense oracle
    and to the legacy vmapped scan - with unequal prompt lengths, so slots
    admit mid-batch."""
    from repro.configs.base import get_smoke_config
    from repro.core import masks as masks_mod, metrics as metrics_mod
    from repro.core.prunable import prunable_map
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    from repro.sparse import apply as apply_mod

    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    masks = masks_mod.nm_masks(scores)
    sparse = apply_mod.sparsify_params(params, masks, axes=M.param_axes(cfg),
                                       idx_bits=2, dtype=jnp.bfloat16)
    masked = masks_mod.apply_masks(params, masks)
    rep = apply_mod.compressed_report(sparse, masks)
    expert = [l for l in rep["layers"] if "['moe']" in l["path"]]

    prompts = [np.array([5, 6, 7, 8]), np.array([9, 10, 11]),
               np.array([1, 2]), np.array([12, 13, 14, 15, 16])]

    def engine_run(p, decode_mode):
        eng = ServeEngine(cfg, p, slots=2, capacity=32,
                          decode_mode=decode_mode)
        rids = [eng.submit(pr_, steps) for pr_ in prompts]
        t0 = time.perf_counter()
        res = eng.run()
        dt = time.perf_counter() - t0
        return [res[r] for r in rids], len(prompts) * steps / dt

    sparse_toks, sparse_tps = engine_run(sparse, "fused")
    vmap_toks, _ = engine_run(sparse, "vmap")
    masked_toks, masked_tps = engine_run(masked, "fused")
    result = {
        "arch": arch, "backend": jax.default_backend(),
        "decode_steps": steps, "requests": len(prompts),
        "compressed_tok_s": sparse_tps, "masked_tok_s": masked_tps,
        "compressed_weight_bytes": rep["bytes_compressed"],
        "dense_weight_bytes_bf16": rep["bytes_dense_bf16"],
        "weight_bytes_ratio": rep["ratio"],
        "fallback_leaves": rep["fallback_leaves"],
        "expert_leaves": len(expert),
        "expert_kernel_native": all(
            l["kernel_layout"] == "packed2" for l in expert),
        "tokens_match_masked_dense": sparse_toks == masked_toks,
        "engine_tokens_match_fused_vs_vmap": sparse_toks == vmap_toks,
    }
    print(f"\n=== MoE serve bench ({arch} smoke, {jax.default_backend()}) "
          f"===")
    print(f"decode tok/s: 2:4-compressed {sparse_tps:.1f} vs masked-dense "
          f"{masked_tps:.1f} (interpret-mode kernel on non-TPU backends)")
    print(f"{len(expert)} expert banks compressed "
          f"(kernel-native packed: {result['expert_kernel_native']}, "
          f"fallback leaves: {rep['fallback_leaves']}); weight bytes "
          f"{rep['bytes_compressed']} vs {rep['bytes_dense_bf16']} dense "
          f"bf16 (ratio {rep['ratio']:.4f}); tokens match masked-dense: "
          f"{result['tokens_match_masked_dense']}")
    out_rows.append({"table": "serve_moe", **result})
    return result


def write_serve_json(result: dict, path=None, *,
                     name: str = "BENCH_serve.json") -> pathlib.Path:
    from benchmarks.common import attach_obs_summary
    out = (pathlib.Path(path) if path else
           pathlib.Path(__file__).resolve().parent.parent / "results" /
           "bench" / name)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(attach_obs_summary(result), indent=1))
    return out


if __name__ == "__main__":
    rows: list = []
    res = serve_bench(rows)
    print("wrote", write_serve_json(res))
    res_moe = serve_bench_moe(rows)
    print("wrote", write_serve_json(res_moe, name="BENCH_serve_moe.json"))
