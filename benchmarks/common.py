"""Shared benchmark harness: cached tiny 'model families' + eval metrics.

The paper evaluates pretrained LLM families on WikiText PPL + zero-shot
accuracy.  At container scale we train tiny instances of three families on
the synthetic corpus (cached under results/bench_models) and report:
  ppl  - held-out perplexity (the paper's PPL columns)
  acc  - next-token top-1 accuracy (zero-shot-accuracy stand-in)
  ind  - accuracy on copy-rule positions (induction; 'reasoning' stand-in)
"""
from __future__ import annotations

import pathlib
import pickle

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import batches_for
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.optim.losses import lm_loss

CACHE = pathlib.Path(__file__).resolve().parent.parent / "results" / \
    "bench_models"
BANKS = CACHE.parent / "bench_banks"

FAMILIES: dict[str, ModelConfig] = {
    "llama-tiny": ModelConfig(
        name="llama-tiny", family="dense", d_model=128, num_layers=4,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512),
    "gemma-tiny": ModelConfig(
        name="gemma-tiny", family="dense", d_model=128, num_layers=4,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
        pattern=("local", "attn"), sliding_window=16, attn_softcap=50.0,
        final_softcap=30.0, sandwich_norm=True, scale_embed=True,
        act="gelu"),
    "moe-tiny": ModelConfig(
        name="moe-tiny", family="moe", d_model=128, num_layers=4,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, moe_d_ff=256,
        vocab_size=512, pattern=("moe",), num_experts=4, top_k=2),
}


def get_trained(name: str, *, steps: int = 300, lr: float = 1.5e-3):
    cfg = FAMILIES[name]
    CACHE.mkdir(parents=True, exist_ok=True)
    f = CACHE / f"{name}.pkl"
    if f.exists():
        params = jax.tree.map(jnp.asarray, pickle.load(open(f, "rb")))
        return cfg, params
    params = M.init_params(cfg, jax.random.key(0))
    train = batches_for(cfg, n=50, batch=16, seq=128, split="train")
    ocfg = opt.AdamWConfig(lr=lr, warmup_steps=steps // 10,
                           total_steps=steps)
    ostate = opt.adamw_init(params)

    @jax.jit
    def step(params, ostate, batch):
        (l, m), g = jax.value_and_grad(
            lambda p, b: lm_loss(cfg, p, b), has_aux=True)(params, batch)
        params, ostate, _ = opt.adamw_update(ocfg, g, ostate, params)
        return params, ostate, l

    for i in range(steps):
        params, ostate, loss = step(params, ostate, train[i % len(train)])
    pickle.dump(jax.tree.map(np.asarray, params), open(f, "wb"))
    return cfg, params


def get_bank(name: str, cfg: ModelConfig, params, pcfg, calib, *, tag: str):
    """One calibration per (model, PruneConfig), shared across tables.

    Routes through ``launch.calibrate.ensure_bank``: the MaskBank artifact
    under results/bench_banks is reused whenever the PruneConfig and the
    weights fingerprint match, so every benchmark module consumes the SAME
    artifact instead of re-running stats/search inline - the paper's
    calibrate-once claim, exercised across the whole table suite.
    """
    from repro.launch import calibrate as launch_cal
    return launch_cal.ensure_bank(
        str(BANKS / f"{name}-{tag}"), cfg=cfg, pcfg=pcfg, params=params,
        calib=calib, arch=name, smoke=False)


def evaluate(cfg: ModelConfig, params, *, n_batches: int = 3) -> dict:
    valid = batches_for(cfg, n=n_batches, batch=12, seq=128, split="valid")
    from repro.data.synthetic import _succ_params
    a, b = _succ_params(cfg.vocab_size, 0)
    tot_nll = tot = 0.0
    hit = cnt = ind_hit = ind_cnt = 0

    @jax.jit
    def fwd(p, batch):
        logits, _, _ = M.forward(cfg, p, batch)
        return logits

    for bt in valid:
        batch = {k: jnp.asarray(v) for k, v in bt.items()}
        logits = fwd(params, batch)
        toks = np.asarray(batch["tokens"])
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -np.asarray(jnp.take_along_axis(
            lp, jnp.asarray(toks[:, 1:])[..., None], axis=-1))[..., 0]
        tot_nll += nll.sum()
        tot += nll.size
        pred = np.asarray(jnp.argmax(logits[:, :-1], -1))
        tgt = toks[:, 1:]
        hit += (pred == tgt).sum()
        cnt += tgt.size
        is_ind = tgt == (a * toks[:, :-1] + b) % cfg.vocab_size
        ind_hit += ((pred == tgt) & is_ind).sum()
        ind_cnt += is_ind.sum()
    import math
    return {"ppl": math.exp(min(tot_nll / tot, 30.0)),
            "acc": hit / cnt, "ind": ind_hit / max(ind_cnt, 1)}


def fmt_row(cols, widths=None):
    widths = widths or [14] * len(cols)
    return "  ".join(str(c)[:w].ljust(w) for c, w in zip(cols, widths))


def attach_obs_summary(result: dict) -> dict:
    """Merge the live flight-recorder snapshot into a BENCH_* result dict.

    No-op (and no key) while the recorder is disabled, so artifacts from
    uninstrumented runs are byte-identical to pre-obs ones.  Called by
    ``table8_inference.write_serve_json`` on every BENCH_*.json it writes.
    """
    from repro import obs
    if obs.enabled():
        result["obs"] = obs.summary()
    return result
