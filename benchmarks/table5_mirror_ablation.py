"""Paper Table 5: the necessity of mirror descent.

Compares full UniPruning against the direct Eq. 8 objective (no saliency
variable / no mirror descent; L2 instead of the non-differentiable L1),
across (lambda, rho) configurations."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from benchmarks.common import evaluate, fmt_row, get_bank, get_trained
from repro.configs.base import PruneConfig
from repro.core import masks as masks_mod, metrics as metrics_mod
from repro.core.mirror import no_mirror_step
from repro.core.prunable import prunable_map
from repro.data.synthetic import batches_for
from repro.optim.losses import lm_loss

SPARSITIES = [0.5, 0.6]


def no_mirror_prune(cfg, params, calib, stats, *, rho, l2, steps=60):
    pcfg = PruneConfig(local_metric="stochria", rho=rho, steps=steps)
    prunable = prunable_map(params)
    loss_fn = partial(lm_loss, cfg)
    W = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    rng = jax.random.key(11)
    step = jax.jit(lambda W, b, s: no_mirror_step(
        pcfg, loss_fn, W, b, stats, prunable, rng, s, l2=l2))
    for n in range(steps):
        W, loss = step(W, calib[n % len(calib)], jnp.asarray(n))
    # Eq. 8 has no saliency variable: masks come from RAW S(W_final) -
    # the Gamma-side machinery (normalized anchor + dual integration) is
    # exactly what this ablation removes.
    S = metrics_mod.metric_tree("stochria", W, stats, prunable,
                                key=rng, norm="none")
    return {sp: masks_mod.apply_masks(
        params, masks_mod.unstructured_masks(S, sp, scope="global"))
        for sp in SPARSITIES}


def run(out_rows: list) -> None:
    print("\n=== Table 5: mirror-descent ablation (llama-tiny) ===")
    print(fmt_row(["variant", "ppl@50%", "ppl@60%"]))
    cfg, params = get_trained("llama-tiny")
    calib = batches_for(cfg, n=10, batch=8, seq=128, split="calib")
    # the shared unstructured bank supplies both the UniPruning row and the
    # activation stats the Eq. 8 ablation loop consumes
    pcfg = PruneConfig(local_metric="stochria", steps=60)
    bank = get_bank("llama-tiny", cfg, params, pcfg, calib,
                    tag="unstructured")
    stats = bank.stats
    ppls = [evaluate(cfg, masks_mod.apply_masks(
        params, bank.masks_at(sparsity=s)))["ppl"] for s in SPARSITIES]
    print(fmt_row(["unipruning"] + [f"{p:.2f}" for p in ppls]))
    out_rows.append({"table": 5, "variant": "unipruning",
                     "ppl50": ppls[0], "ppl60": ppls[1]})

    for l2, rho in [(0.01, 1e-5), (0.01, 0.0), (0.0, 1e-5), (0.0, 0.0)]:
        pm = no_mirror_prune(cfg, params, calib, stats, rho=rho, l2=l2)
        ppls = [evaluate(cfg, pm[s])["ppl"] for s in SPARSITIES]
        name = f"eq8 L2:{l2} r:{rho}"
        print(fmt_row([name] + [f"{p:.2f}" for p in ppls]))
        out_rows.append({"table": 5, "variant": name, "ppl50": ppls[0],
                         "ppl60": ppls[1]})
