"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod mesh:
  compute term    = HLO_dot_FLOPs / peak_FLOPs          (per device, s)
  memory term     = 2 * HLO_bytes / HBM_bw              (write + read)
  collective term = collective_bytes / link_bw
with HLO quantities from the while-trip-aware analyzer
(repro/launch/hlo_analysis.py; cost_analysis() counts scan bodies once and
is unusable directly).  Also reports MODEL_FLOPS (6*N_active*D for train,
2*N_active*tokens for serve) and the useful-compute ratio
MODEL_FLOPS / (devices * HLO_FLOPs), which exposes remat/redundancy waste.

  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
      [--multi-pod] [--write results/roofline.json]

``--nm-shard`` prints the shard-local analysis of the K-sharded 2:4 kernel
(kernels/shard.py): per-device arithmetic intensity, bytes moved, and the
explicit psum payload against LINK_BW - the decision surface for when
K-partial accumulation beats a replicated kernel.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12        # v5e bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # ICI per link

from repro.configs.base import ARCH_IDS, SHAPE_CELLS, get_config


def _param_counts(arch: str) -> tuple[float, float]:
    """(N_total, N_active) from the real config's param shapes."""
    from repro.models import model as M
    cfg = get_config(arch)
    shapes = M.param_shapes(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    total = active = 0.0
    for kp, s in flat:
        n = 1
        for d in s.shape:
            n *= d
        path = jax.tree_util.keystr(kp)
        total += n
        if "['moe']" in path and len(s.shape) == 4 and "shared" not in path:
            # stacked expert kernels (L, E, d, f): only top_k/E active
            active += n * cfg.top_k / max(cfg.num_experts, 1)
        else:
            active += n
    return total, active


def model_flops(arch: str, cell_name: str) -> float:
    cell = SHAPE_CELLS[cell_name]
    n_total, n_active = _param_counts(arch)
    if cell.kind == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    return 2.0 * n_active * cell.global_batch  # one decoded token


def analyze_cell(dirpath: pathlib.Path, arch: str, cell: str,
                 multi_pod: bool) -> dict | None:
    tag = f"{arch}__{cell}__{'multipod' if multi_pod else 'pod'}"
    jf = dirpath / f"{tag}.json"
    if not jf.exists():
        return None
    rec = json.loads(jf.read_text())
    if rec.get("skipped"):
        return {"arch": arch, "cell": cell, "skipped": rec["skipped"]}
    if rec.get("error"):
        return {"arch": arch, "cell": cell, "error": rec["error"]}
    from repro.launch.hlo_analysis import analyze_file
    s = analyze_file(dirpath / f"{tag}.hlo.gz")
    n_dev = rec["devices"]
    t_c = s.dot_flops / PEAK_FLOPS
    t_m = 2.0 * s.bytes_out / HBM_BW
    t_x = s.coll_bytes / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    mf = model_flops(arch, cell)
    ratio = mf / max(n_dev * s.dot_flops, 1e-30)
    return {
        "arch": arch, "cell": cell, "devices": n_dev,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom[1],
        "hlo_flops_per_dev": s.dot_flops,
        "hlo_bytes_per_dev": s.bytes_out,
        "coll_bytes_per_dev": s.coll_bytes,
        "coll_by_op": s.coll_by_op,
        "model_flops_global": mf,
        "useful_ratio": ratio,
        "hbm_per_dev_gb": rec.get("per_device_hbm_bytes", 0) / 1e9,
        "fits_16gb": rec.get("fits_16gb"),
        "compile_s": rec.get("compile_s"),
        "roofline_fraction": t_c / max(t_c, t_m, t_x),
        "note": _note(dom[1], ratio, s),
    }


def _note(dom: str, ratio: float, s) -> str:
    if dom == "compute":
        if ratio < 0.5:
            return ("compute-bound but only {:.0%} useful - cut remat "
                    "recompute or redundant (replicated) matmuls".format(ratio))
        return "compute-bound; gains need better MXU shapes or less remat"
    if dom == "memory":
        return ("memory-bound; fuse elementwise chains / shrink saved "
                "activations (bytes dominate flops)")
    ag = s.coll_by_op.get("all-gather", 0)
    ar = s.coll_by_op.get("all-reduce", 0)
    which = "all-gather (FSDP weight gathers)" if ag >= ar else \
        "all-reduce (grad sync)"
    return f"collective-bound, dominated by {which}; overlap or re-shard"


def nm_shard_roofline(M: int, K: int, N: int, *, devices: int = 1,
                      idx_bits: int = 2, act_bytes: int = 2) -> dict:
    """Shard-local roofline of one K-sharded 2:4 kernel call.

    Each device holds a (K/d, N) slice of the compressed kernel - vals
    (K/(2d), N) bf16 plus the index plane (K/(8d), N) packed-2-bit or
    (K/(2d), N) int8 - streams its x slice (M, K/d), and produces an f32
    partial (M, N) that ONE psum over the K axis combines (payload
    M*N*4 bytes per device, counted by the ``dist.psum_bytes`` site
    counters at trace time).  FLOPs count the kept weights only
    (2 * M * K/2 * N multiply-adds, split d ways); a replicated kernel is
    the devices=1 row with zero collective time.
    """
    k_loc = K / devices
    flops = 2.0 * M * (K / 2) * N / devices        # kept-weight MACs
    vals_b = (k_loc / 2) * N * 2                   # bf16 vals slice
    idx_b = (k_loc / 8) * N if idx_bits == 2 else (k_loc / 2) * N
    x_b = M * k_loc * act_bytes
    out_b = M * N * 4                              # f32 partial write
    bytes_moved = vals_b + idx_b + x_b + out_b
    psum_b = 0.0 if devices == 1 else M * N * 4    # per-device psum payload
    t_c = flops / PEAK_FLOPS
    t_m = bytes_moved / HBM_BW
    t_x = psum_b / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    return {
        "M": M, "K": K, "N": N, "devices": devices, "idx_bits": idx_bits,
        "flops_per_dev": flops, "bytes_per_dev": bytes_moved,
        "arith_intensity": flops / bytes_moved,
        "psum_bytes_per_dev": psum_b,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "t_total_s": max(t_c, t_m) + t_x, "dominant": dom[1],
    }


def nm_shard_table(arch: str = "llama3.2-1b", M: int = 8,
                   device_counts=(1, 4, 8)) -> list[dict]:
    """K-sharded kernel roofline over one decode step's projection shapes.

    Decode is tiny-M (M = batch of slots), so the compressed weight bytes
    dominate ``bytes_per_dev`` and K-sharding divides exactly the dominant
    term while the psum payload (M*N*4) stays M-small - the table shows the
    memory-time win per device count next to the collective time it buys.
    """
    cfg = get_config(arch)
    h = cfg.num_heads * cfg.head_dim
    kv = cfg.num_kv_heads * cfg.head_dim
    shapes = [("wq", cfg.d_model, h), ("wk", cfg.d_model, kv),
              ("wv", cfg.d_model, kv), ("wo", h, cfg.d_model),
              ("up+gate", cfg.d_model, 2 * cfg.d_ff),
              ("down", cfg.d_ff, cfg.d_model)]
    rows = []
    for name, K, N in shapes:
        for d in device_counts:
            r = nm_shard_roofline(M, K, N, devices=d)
            r["proj"] = name
            rows.append(r)
    return rows


def _print_nm_shard(M: int) -> None:
    rows = nm_shard_table(M=M)
    print(f"K-sharded 2:4 kernel, shard-local roofline (decode M={M}):")
    print(f"{'proj':10s} {'KxN':>12s} {'dev':>4s} {'AI':>7s} "
          f"{'MB/dev':>8s} {'psum KB':>8s} {'t_mem':>9s} {'t_coll':>9s} "
          f"{'dom':>6s}")
    for r in rows:
        print(f"{r['proj']:10s} {r['K']:>5d}x{r['N']:<6d} "
              f"{r['devices']:>4d} {r['arith_intensity']:7.2f} "
              f"{r['bytes_per_dev'] / 1e6:8.3f} "
              f"{r['psum_bytes_per_dev'] / 1e3:8.2f} "
              f"{r['t_memory_s']:9.2e} {r['t_collective_s']:9.2e} "
              f"{r['dominant'][:6]:>6s}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--write", default="results/roofline.json")
    ap.add_argument("--nm-shard", action="store_true",
                    help="shard-local roofline of the K-sharded 2:4 kernel")
    ap.add_argument("--decode-batch", type=int, default=8,
                    help="decode batch M for --nm-shard")
    args = ap.parse_args()
    if args.nm_shard:
        _print_nm_shard(args.decode_batch)
        if args.write:
            p = pathlib.Path(args.write)
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(json.dumps(nm_shard_table(M=args.decode_batch),
                                    indent=1))
            print("wrote", args.write)
        return
    d = pathlib.Path(args.dir)
    rows = []
    for arch in ARCH_IDS:
        for cell in SHAPE_CELLS:
            r = analyze_cell(d, arch, cell, args.multi_pod)
            if r is not None:
                rows.append(r)
    hdr = (f"{'arch':22s} {'cell':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dom':>6s} {'useful':>7s} {'HBM GB':>7s}")
    print(hdr)
    for r in rows:
        if r.get("skipped"):
            print(f"{r['arch']:22s} {r['cell']:12s} SKIP ({r['skipped'][:48]})")
            continue
        if r.get("error"):
            print(f"{r['arch']:22s} {r['cell']:12s} ERROR")
            continue
        print(f"{r['arch']:22s} {r['cell']:12s} "
              f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
              f"{r['t_collective_s']:9.2e} {r['dominant'][:6]:>6s} "
              f"{r['useful_ratio']:7.2f} {r['hbm_per_dev_gb']:7.2f}")
    if args.write:
        pathlib.Path(args.write).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(args.write).write_text(json.dumps(rows, indent=1))
        print("wrote", args.write)


if __name__ == "__main__":
    main()
