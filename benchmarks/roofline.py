"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) on the single-pod mesh:
  compute term    = HLO_dot_FLOPs / peak_FLOPs          (per device, s)
  memory term     = 2 * HLO_bytes / HBM_bw              (write + read)
  collective term = collective_bytes / link_bw
with HLO quantities from the while-trip-aware analyzer
(repro/launch/hlo_analysis.py; cost_analysis() counts scan bodies once and
is unusable directly).  Also reports MODEL_FLOPS (6*N_active*D for train,
2*N_active*tokens for serve) and the useful-compute ratio
MODEL_FLOPS / (devices * HLO_FLOPs), which exposes remat/redundancy waste.

  PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
      [--multi-pod] [--write results/roofline.json]
"""
from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12        # v5e bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # ICI per link

from repro.configs.base import ARCH_IDS, SHAPE_CELLS, get_config


def _param_counts(arch: str) -> tuple[float, float]:
    """(N_total, N_active) from the real config's param shapes."""
    from repro.models import model as M
    cfg = get_config(arch)
    shapes = M.param_shapes(cfg)
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    total = active = 0.0
    for kp, s in flat:
        n = 1
        for d in s.shape:
            n *= d
        path = jax.tree_util.keystr(kp)
        total += n
        if "['moe']" in path and len(s.shape) == 4 and "shared" not in path:
            # stacked expert kernels (L, E, d, f): only top_k/E active
            active += n * cfg.top_k / max(cfg.num_experts, 1)
        else:
            active += n
    return total, active


def model_flops(arch: str, cell_name: str) -> float:
    cell = SHAPE_CELLS[cell_name]
    n_total, n_active = _param_counts(arch)
    if cell.kind == "train":
        return 6.0 * n_active * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.global_batch * cell.seq_len
    return 2.0 * n_active * cell.global_batch  # one decoded token


def analyze_cell(dirpath: pathlib.Path, arch: str, cell: str,
                 multi_pod: bool) -> dict | None:
    tag = f"{arch}__{cell}__{'multipod' if multi_pod else 'pod'}"
    jf = dirpath / f"{tag}.json"
    if not jf.exists():
        return None
    rec = json.loads(jf.read_text())
    if rec.get("skipped"):
        return {"arch": arch, "cell": cell, "skipped": rec["skipped"]}
    if rec.get("error"):
        return {"arch": arch, "cell": cell, "error": rec["error"]}
    from repro.launch.hlo_analysis import analyze_file
    s = analyze_file(dirpath / f"{tag}.hlo.gz")
    n_dev = rec["devices"]
    t_c = s.dot_flops / PEAK_FLOPS
    t_m = 2.0 * s.bytes_out / HBM_BW
    t_x = s.coll_bytes / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    mf = model_flops(arch, cell)
    ratio = mf / max(n_dev * s.dot_flops, 1e-30)
    return {
        "arch": arch, "cell": cell, "devices": n_dev,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dom[1],
        "hlo_flops_per_dev": s.dot_flops,
        "hlo_bytes_per_dev": s.bytes_out,
        "coll_bytes_per_dev": s.coll_bytes,
        "coll_by_op": s.coll_by_op,
        "model_flops_global": mf,
        "useful_ratio": ratio,
        "hbm_per_dev_gb": rec.get("per_device_hbm_bytes", 0) / 1e9,
        "fits_16gb": rec.get("fits_16gb"),
        "compile_s": rec.get("compile_s"),
        "roofline_fraction": t_c / max(t_c, t_m, t_x),
        "note": _note(dom[1], ratio, s),
    }


def _note(dom: str, ratio: float, s) -> str:
    if dom == "compute":
        if ratio < 0.5:
            return ("compute-bound but only {:.0%} useful - cut remat "
                    "recompute or redundant (replicated) matmuls".format(ratio))
        return "compute-bound; gains need better MXU shapes or less remat"
    if dom == "memory":
        return ("memory-bound; fuse elementwise chains / shrink saved "
                "activations (bytes dominate flops)")
    ag = s.coll_by_op.get("all-gather", 0)
    ar = s.coll_by_op.get("all-reduce", 0)
    which = "all-gather (FSDP weight gathers)" if ag >= ar else \
        "all-reduce (grad sync)"
    return f"collective-bound, dominated by {which}; overlap or re-shard"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--write", default="results/roofline.json")
    args = ap.parse_args()
    d = pathlib.Path(args.dir)
    rows = []
    for arch in ARCH_IDS:
        for cell in SHAPE_CELLS:
            r = analyze_cell(d, arch, cell, args.multi_pod)
            if r is not None:
                rows.append(r)
    hdr = (f"{'arch':22s} {'cell':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dom':>6s} {'useful':>7s} {'HBM GB':>7s}")
    print(hdr)
    for r in rows:
        if r.get("skipped"):
            print(f"{r['arch']:22s} {r['cell']:12s} SKIP ({r['skipped'][:48]})")
            continue
        if r.get("error"):
            print(f"{r['arch']:22s} {r['cell']:12s} ERROR")
            continue
        print(f"{r['arch']:22s} {r['cell']:12s} "
              f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
              f"{r['t_collective_s']:9.2e} {r['dominant'][:6]:>6s} "
              f"{r['useful_ratio']:7.2f} {r['hbm_per_dev_gb']:7.2f}")
    if args.write:
        pathlib.Path(args.write).parent.mkdir(parents=True, exist_ok=True)
        pathlib.Path(args.write).write_text(json.dumps(rows, indent=1))
        print("wrote", args.write)


if __name__ == "__main__":
    main()
