"""Flight-recorder overhead bench: serving tok/s with telemetry on vs off.

Telemetry that costs real throughput never stays enabled, so the recorder's
contract is measured, not asserted: the same engine (same shared
``EngineFns``, compiled once in a warmup pass) serves the same request set
with the recorder disabled and enabled, alternating repetitions.  Overhead
is the median of the paired per-repetition on/off ratios - host clock
drift cancels inside each pair, where a best-of-N comparison aliases it
into fake overhead on runs this short - reported next to best-of tok/s per
mode and the exact count of jitted step-function dispatches per run.
Tracked per PR as
``results/bench/BENCH_obs.json`` and gated by ``benchmarks/run.py
--smoke``:

* decode overhead with telemetry enabled <= 3% of the disabled tok/s,
* identical dispatch counts in every mode (the recorder adds zero
  dispatches; disabled, the hot path IS the uninstrumented one),
* a fleet smoke run reports per-budget decode p50/p95 latency,
* a calibrate smoke run lands per-chunk loss/sparsity/mask-churn series
  in the JSONL trace (written under ``results/bench/obs_trace/`` and
  uploaded as a CI artifact).
"""
from __future__ import annotations

import pathlib
import statistics
import tempfile
import time

import jax
import numpy as np

from benchmarks.table8_inference import write_serve_json

TRACE_DIR = pathlib.Path(__file__).resolve().parent.parent / "results" / \
    "bench" / "obs_trace"


def _count_dispatches(fns) -> dict:
    """Wrap the shared jit entry points with dispatch counters.

    The engine caches ``fns.decode``/``fns.write_slot`` at construction, so
    the wrap must happen before any engine is built on this EngineFns.
    """
    counts = {"decode": 0, "prefill": 0, "write_slot": 0}
    orig_decode, orig_write, orig_prefill = \
        fns.decode, fns.write_slot, fns.prefill

    def decode(*a):
        counts["decode"] += 1
        return orig_decode(*a)

    def write_slot(*a):
        counts["write_slot"] += 1
        return orig_write(*a)

    def prefill(bucket):
        fn = orig_prefill(bucket)

        def wrapped(*a):
            counts["prefill"] += 1
            return fn(*a)
        return wrapped

    fns.decode, fns.write_slot, fns.prefill = decode, write_slot, prefill
    return counts


def obs_bench(out_rows: list, *, arch: str = "llama3.2-1b", gen: int = 48,
              reps: int = 5) -> dict:
    from repro import obs
    from repro.configs.base import PruneConfig, get_smoke_config
    from repro.data.synthetic import batches_for
    from repro.launch import calibrate as launch_cal
    from repro.models import model as M
    from repro.serve.engine import EngineFns, ServeEngine
    from repro.serve.fleet import SparsityFleet

    cfg = get_smoke_config(arch)
    params = M.init_params(cfg, jax.random.key(0))
    capacity = 64
    batch = batches_for(cfg, n=1, batch=4, seq=12, split="valid")[0]
    prompts = [np.asarray(batch["tokens"][i]) for i in range(4)]

    obs.reset()  # a clean, disabled recorder regardless of bench ordering
    trace_file = TRACE_DIR / "events.jsonl"
    if trace_file.exists():  # fresh trace per bench run: counts stay exact
        trace_file.unlink()
    fns = EngineFns(cfg, capacity)
    counts = _count_dispatches(fns)

    def serve_once() -> tuple[float, dict]:
        for k in counts:
            counts[k] = 0
        eng = ServeEngine(cfg, params, slots=4, capacity=capacity, fns=fns)
        rids = [eng.submit(p, gen) for p in prompts]
        t0 = time.perf_counter()
        res = eng.run()
        dt = time.perf_counter() - t0
        return sum(len(res[r]) for r in rids) / dt, dict(counts)

    serve_once()  # warmup: compiles land outside every timed run
    tok_s = {"disabled": [], "enabled": []}
    dispatches: dict[str, list[dict]] = {"disabled": [], "enabled": []}
    ratios = []  # paired on/off per repetition: host clock drift (CPU
    for _ in range(reps):  # frequency, noisy CI neighbors) cancels in the
        obs.disable()  # ratio where a best-of comparison would alias it
        ts_off, dc = serve_once()  # into fake overhead
        tok_s["disabled"].append(ts_off)
        dispatches["disabled"].append(dc)
        obs.configure(trace_dir=TRACE_DIR)
        ts_on, dc = serve_once()
        tok_s["enabled"].append(ts_on)
        dispatches["enabled"].append(dc)
        ratios.append(ts_on / ts_off)
    best_off = max(tok_s["disabled"])
    best_on = max(tok_s["enabled"])
    overhead_pct = max(0.0, (1.0 - statistics.median(ratios)) * 100.0)
    all_counts = dispatches["disabled"] + dispatches["enabled"]
    dispatch_identical = all(c == all_counts[0] for c in all_counts)

    # fleet + calibrate smoke under the live recorder: the signals the
    # autoscaling/speculative ROADMAP items will consume
    obs.configure(trace_dir=TRACE_DIR)
    pcfg = PruneConfig(local_metric="wanda", mode="nm", steps=4,
                       scan_chunk=2)
    calib = batches_for(cfg, n=2, batch=2, seq=16, split="calib")
    with tempfile.TemporaryDirectory() as td:
        launch_cal.calibrate_to_bank(td + "/bank", cfg=cfg, pcfg=pcfg,
                                     params=params, calib=calib, arch=arch,
                                     smoke=True)
        fleet = SparsityFleet.from_artifact(td + "/bank", params,
                                            ["0.0", "0.5", "2:4"], slots=6,
                                            capacity=32)
    obs.disable()  # warmup EVERY member (pinned routing: ab= would only
    for name in ("0.0", "0.5", "2:4"):  # reach the reference), so compiles
        fleet.submit(prompts[0], 4, budget=name)  # land outside the
    fleet.run()  # measured decode-latency percentiles
    obs.configure(trace_dir=TRACE_DIR)
    for p in prompts * 2:
        fleet.submit(p, 8, ab=True)
    fleet.run()
    freport = fleet.report()
    fleet_decode_ms = {
        name: {"p50": r["decode_ms_p50"], "p95": r["decode_ms_p95"]}
        for name, r in freport["budgets"].items()}
    mirrored = sum(r["cumulative"]["mirrored_picks"]
                   for r in freport["budgets"].values())

    obs.flush()
    chunks = [e for e in obs.read_jsonl(TRACE_DIR / "events.jsonl")
              if e.get("kind") == "log"
              and e.get("event") == "calibrate.search_chunk"]
    series_ok = bool(chunks) and all(
        len(c.get(k, [])) == c["steps"]
        for c in chunks for k in ("loss", "sparsity", "mask_churn"))
    span_events = sum(1 for e in obs.read_jsonl(TRACE_DIR / "events.jsonl")
                      if e.get("kind") == "span")
    (TRACE_DIR / "metrics.prom").write_text(obs.expose())

    result = {
        "arch": arch, "backend": jax.default_backend(),
        "decode_steps": gen, "reps": reps,
        "tok_s_disabled": best_off, "tok_s_enabled": best_on,
        "overhead_pct": overhead_pct,
        "dispatches_per_run": all_counts[0],
        "dispatch_counts_identical": dispatch_identical,
        "fleet_decode_ms": fleet_decode_ms,
        "fleet_mirrored_picks": mirrored,
        "trace_search_chunks": len(chunks),
        "trace_series_ok": series_ok,
        "trace_span_events": span_events,
        "trace_path": str(TRACE_DIR / "events.jsonl"),
        "obs": obs.summary(),
    }
    obs.reset()  # leave no live recorder behind for later bench modules

    print(f"\n=== obs bench ({arch} smoke, {jax.default_backend()}) ===")
    print(f"serve tok/s: {best_off:.1f} disabled vs {best_on:.1f} enabled "
          f"({overhead_pct:.2f}% overhead), dispatches/run "
          f"{result['dispatches_per_run']} "
          f"(identical across modes: {dispatch_identical})")
    for name, p in fleet_decode_ms.items():
        print(f"  fleet {name:>6}: decode p50/p95 "
              f"{p['p50']:.2f}/{p['p95']:.2f} ms")
    print(f"trace: {len(chunks)} search chunks (series ok: {series_ok}), "
          f"{span_events} span events -> {result['trace_path']}")
    out_rows.append({"table": "obs", **result})
    return result


def run(out_rows: list) -> None:
    obs_bench(out_rows)


if __name__ == "__main__":
    rows: list = []
    res = obs_bench(rows)
    print("wrote", write_serve_json(res, name="BENCH_obs.json"))
