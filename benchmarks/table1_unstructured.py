"""Paper Table 1: 60% unstructured sparsity across model families x methods.

Reports PPL + accuracy stand-ins for Magnitude / Wanda / RIA / stochRIA
one-shot baselines (each with its paper's comparison scope) and UniPruning.
Calibration comes from the shared per-family MaskBank artifact
(``common.get_bank`` -> ``launch.calibrate``): baselines read the bank's
persisted activation stats, UniPruning re-thresholds the bank's Gamma/V -
no inline stats/search runs here."""
from __future__ import annotations

import time

import jax

from benchmarks.common import FAMILIES, evaluate, fmt_row, get_bank, \
    get_trained
from repro.configs.base import PruneConfig
from repro.core import calibrate, masks as masks_mod
from repro.data.synthetic import batches_for

SPARSITY = 0.6
METHODS = ["magnitude", "wanda", "ria", "stochria"]
# ONE unstructured search per family, shared with fig2 + oneshot_export
PCFG = PruneConfig(local_metric="stochria", steps=60)


def run(out_rows: list) -> None:
    print(f"\n=== Table 1: unstructured {int(SPARSITY*100)}% sparsity ===")
    print(fmt_row(["model", "method", "ppl", "acc", "ind"]))
    for fam in FAMILIES:
        cfg, params = get_trained(fam)
        dense = evaluate(cfg, params)
        print(fmt_row([fam, "dense", f"{dense['ppl']:.2f}",
                       f"{dense['acc']:.3f}", f"{dense['ind']:.3f}"]))
        calib = batches_for(cfg, n=10, batch=8, seq=128, split="calib")
        t0 = time.time()
        bank = get_bank(fam, cfg, params, PCFG, calib, tag="unstructured")
        t_cal = time.time() - t0
        for m in METHODS:
            mask = calibrate.baseline_masks(m, params, bank.stats, SPARSITY,
                                            key=jax.random.key(5))
            r = evaluate(cfg, masks_mod.apply_masks(params, mask))
            print(fmt_row([fam, m, f"{r['ppl']:.2f}", f"{r['acc']:.3f}",
                           f"{r['ind']:.3f}"]))
            out_rows.append({"table": 1, "model": fam, "method": m, **r})
        pruned = masks_mod.apply_masks(params,
                                       bank.masks_at(sparsity=SPARSITY))
        r = evaluate(cfg, pruned)
        print(fmt_row([fam, "unipruning", f"{r['ppl']:.2f}",
                       f"{r['acc']:.3f}", f"{r['ind']:.3f}",
                       f"({t_cal:.0f}s calibrate-or-load)"]))
        out_rows.append({"table": 1, "model": fam, "method": "unipruning",
                         **r})
