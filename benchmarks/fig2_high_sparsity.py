"""Paper Fig. 2: robustness at 70% sparsity across methods/models."""
from __future__ import annotations

import jax

from benchmarks.common import FAMILIES, evaluate, fmt_row, get_trained
from repro.configs.base import PruneConfig
from repro.core import calibrate, masks as masks_mod
from repro.data.synthetic import batches_for

SP = 0.7


def run(out_rows: list) -> None:
    print("\n=== Fig 2: 70% sparsity robustness ===")
    print(fmt_row(["model", "method", "ppl"]))
    for fam in FAMILIES:
        cfg, params = get_trained(fam)
        calib = batches_for(cfg, n=10, batch=8, seq=128, split="calib")
        stats = calibrate.collect_stats(cfg, params, calib[:3])
        for m in ["magnitude", "wanda", "ria"]:
            mask = calibrate.baseline_masks(m, params, stats, SP,
                                            key=jax.random.key(5))
            r = evaluate(cfg, masks_mod.apply_masks(params, mask))
            print(fmt_row([fam, m, f"{r['ppl']:.2f}"]))
            out_rows.append({"table": "fig2", "model": fam, "method": m,
                             "ppl": r["ppl"]})
        pcfg = PruneConfig(local_metric="stochria", steps=60)
        pruned, _, _ = calibrate.unipruning_prune(cfg, pcfg, params, calib,
                                                  sparsities=[SP])
        r = evaluate(cfg, pruned[SP])
        print(fmt_row([fam, "unipruning", f"{r['ppl']:.2f}"]))
        out_rows.append({"table": "fig2", "model": fam,
                         "method": "unipruning", "ppl": r["ppl"]})
