"""Paper Fig. 2: robustness at 70% sparsity across methods/models.

Consumes the SAME per-family MaskBank artifacts as table1 (one shared
unstructured calibration), re-thresholded at 70% - the bank's one-shot
multi-budget property across benchmark modules."""
from __future__ import annotations

import jax

from benchmarks.common import FAMILIES, evaluate, fmt_row, get_bank, \
    get_trained
from benchmarks.table1_unstructured import PCFG
from repro.core import calibrate, masks as masks_mod
from repro.data.synthetic import batches_for

SP = 0.7


def run(out_rows: list) -> None:
    print("\n=== Fig 2: 70% sparsity robustness ===")
    print(fmt_row(["model", "method", "ppl"]))
    for fam in FAMILIES:
        cfg, params = get_trained(fam)
        calib = batches_for(cfg, n=10, batch=8, seq=128, split="calib")
        bank = get_bank(fam, cfg, params, PCFG, calib, tag="unstructured")
        for m in ["magnitude", "wanda", "ria"]:
            mask = calibrate.baseline_masks(m, params, bank.stats, SP,
                                            key=jax.random.key(5))
            r = evaluate(cfg, masks_mod.apply_masks(params, mask))
            print(fmt_row([fam, m, f"{r['ppl']:.2f}"]))
            out_rows.append({"table": "fig2", "model": fam, "method": m,
                             "ppl": r["ppl"]})
        r = evaluate(cfg, masks_mod.apply_masks(params,
                                                bank.masks_at(sparsity=SP)))
        print(fmt_row([fam, "unipruning", f"{r['ppl']:.2f}"]))
        out_rows.append({"table": "fig2", "model": fam,
                         "method": "unipruning", "ppl": r["ppl"]})
