"""Tensor-parallel sparse serving bench: K-sharded decode on a 4-device mesh.

XLA fixes the host device count at jax import, so the measurement runs in a
CHILD process launched with ``XLA_FLAGS=--xla_force_host_platform_device_count
=4`` - the parent (this module, imported by ``benchmarks/run.py`` after jax
is already up) parses the child's JSON and writes
``results/bench/BENCH_tp.json``.

Per mesh ((1, 4) pure TP and (2, 2) data x model), the child serves the
llama-smoke 2:4 engine sharded and replicated and reports:

* per-device tok/s (sharded) next to the replicated oracle's tok/s,
* the *static* collective count per decode trace, read from the
  ``dist.psum`` counters (they advance at trace time, so the delta around
  the first decode call IS the per-step count; a second same-shape decode
  must add zero - ``collectives_static``),
* ``tokens_match_replicated``: token-for-token parity vs the oracle.

Gated by ``benchmarks/run.py --smoke``: parity must hold, counts must be
static, and the fused up/gate pair must cost ONE psum (mlp site = 2 per
trace on (2, 2): the pair + down; 3 would mean the deferral regressed).

CPU numbers are functional (interpret-mode kernels; the psum runs through
the same shard_map the TPU path compiles) - the collective *counts* and the
parity flag are the invariants, the tok/s columns are trend-tracking only.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import textwrap

from benchmarks.table8_inference import write_serve_json

_CHILD = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import json, time
    import jax, jax.numpy as jnp, numpy as np
    from repro import obs
    from repro.configs.base import get_smoke_config
    from repro.core import masks as masks_mod, metrics as metrics_mod
    from repro.core.prunable import prunable_map
    from repro.dist.axes import make_rules
    from repro.models import model as M
    from repro.serve.engine import ServeEngine
    from repro.sparse import apply as apply_mod

    SITES = ("mlp", "attn", "moe", "attn_kv")
    SLOTS, CAPACITY, GEN = 4, 64, 24

    cfg = get_smoke_config("llama3.2-1b")
    params = M.init_params(cfg, jax.random.key(0))
    pr = prunable_map(params)
    scores = metrics_mod.metric_tree(
        "magnitude", params, jax.tree.map(lambda _: None, pr), pr)
    masks = masks_mod.nm_masks(scores)
    sparse = apply_mod.sparsify_params(
        params, masks, axes=M.param_axes(cfg), idx_bits=2,
        dtype=jnp.bfloat16)
    prompts = [(np.arange(1, 9) * (i + 3)) % cfg.vocab_size
               for i in range(SLOTS)]

    def snap(name):
        return {s: obs.counter_value(name, site=s) for s in SITES}

    def measure(rules):
        obs.configure(enabled=True)
        eng = ServeEngine(cfg, sparse, slots=SLOTS, capacity=CAPACITY,
                          rules=rules)
        toks = jnp.zeros((SLOTS,), jnp.int32)
        pos = jnp.zeros((SLOTS,), jnp.int32)
        b_n, b_bytes = snap("dist.psum"), snap("dist.psum_bytes")
        out, caches = eng._decode(eng.params, toks, eng.caches, pos)
        jax.block_until_ready(out)
        a_n, a_bytes = snap("dist.psum"), snap("dist.psum_bytes")
        out, _ = eng._decode(eng.params, toks, caches, pos + 1)
        jax.block_until_ready(out)
        c_n = snap("dist.psum")
        rids = [eng.submit(p, GEN) for p in prompts]
        t0 = time.perf_counter()
        res = eng.run()
        dt = time.perf_counter() - t0
        n_tok = sum(len(res[r]) for r in rids)
        return {
            "tokens": [res[r] for r in rids],
            "tok_s": n_tok / dt,
            "decode_psums_per_trace": {s: a_n[s] - b_n[s] for s in SITES},
            "decode_psum_bytes_per_trace": {s: a_bytes[s] - b_bytes[s]
                                            for s in SITES},
            "collectives_static": c_n == a_n,
        }

    oracle = measure(None)
    n_dev = jax.device_count()
    meshes = {}
    for shape in [(1, 4), (2, 2)]:
        mesh = jax.make_mesh(shape, ("data", "model"))
        r = measure(make_rules(mesh))
        r["tokens_match_replicated"] = r.pop("tokens") == oracle["tokens"]
        r["tok_s_per_device"] = r["tok_s"] / n_dev
        meshes["x".join(map(str, shape))] = r
    oracle.pop("tokens")
    print("BENCH_TP_JSON=" + json.dumps({
        "devices": n_dev, "arch": cfg.name, "slots": SLOTS,
        "capacity": CAPACITY, "gen_tokens": GEN,
        "replicated": oracle, "meshes": meshes}))
"""


def tp_bench(out_rows: list) -> dict:
    """Run the forced-4-device child and fold its JSON into the bench rows."""
    env = {**os.environ, "PYTHONPATH": "src"}
    env.pop("REPRO_FORCE_REPLICATED", None)
    root = pathlib.Path(__file__).resolve().parent.parent
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(_CHILD)],
                       capture_output=True, text=True, env=env,
                       cwd=str(root), timeout=1200)
    marker = "BENCH_TP_JSON="
    line = next((ln for ln in r.stdout.splitlines()
                 if ln.startswith(marker)), None)
    assert r.returncode == 0 and line is not None, (r.stdout, r.stderr)
    result = json.loads(line[len(marker):])
    result["parity"] = all(m["tokens_match_replicated"]
                           for m in result["meshes"].values())
    result["collectives_static"] = all(m["collectives_static"]
                                       for m in result["meshes"].values())
    print(f"tensor-parallel serve ({result['devices']} forced host devices, "
          f"{result['arch']}):")
    print(f"  replicated: {result['replicated']['tok_s']:8.1f} tok/s")
    for name, m in result["meshes"].items():
        psums = m["decode_psums_per_trace"]
        print(f"  mesh {name}: {m['tok_s']:8.1f} tok/s "
              f"({m['tok_s_per_device']:.1f}/device), "
              f"psums/decode-trace {psums}, "
              f"parity={m['tokens_match_replicated']}")
    out_rows.append({"table": "tp", **result})
    return result


def run(out_rows: list) -> None:
    tp_bench(out_rows)


if __name__ == "__main__":
    rows: list = []
    res = tp_bench(rows)
    print("wrote", write_serve_json(res, name="BENCH_tp.json"))
