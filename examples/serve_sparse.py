"""Mask-bank round trip: calibrate ONCE, serve at FOUR budgets (paper §4.3
+ Table 8 scenario).

Run 1 is the ``repro.launch.calibrate`` entry point: jitted sharded stats
-> scanned mirror-descent search -> mask-bank artifact
(Gamma/V/stats/PruneConfig).  Runs 2-4 never touch calibration again: they
load the bank, re-threshold to masks in one shot, and serve - first with
2:4-compressed weights executing through the nm_spmm kernel, then
masked-dense for an A/B token check, then a sparsity FLEET serving dense +
unstructured + 2:4 concurrently behind one router with weighted A/B
traffic.

  PYTHONPATH=src python examples/serve_sparse.py --arch llama3.2-1b
  PYTHONPATH=src python examples/serve_sparse.py --arch gemma2-2b \
      --sparsity 0.6 --gen 32
"""
import argparse
import subprocess
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3.2-1b")
ap.add_argument("--sparsity", type=float, default=None,
                help="unstructured re-threshold budget (default: the "
                     "calibrated 2:4 pattern)")
ap.add_argument("--gen", type=int, default=16)
ap.add_argument("--artifact", default=None,
                help="bank directory (default results/bank/<arch>)")
args = ap.parse_args()
artifact = args.artifact or f"results/bank/{args.arch}"

base = [sys.executable, "-m", "repro.launch.serve", "--arch", args.arch,
        "--smoke", "--batch", "4", "--prompt-len", "64",
        "--gen", str(args.gen)]
sparsity = (["--sparsity", str(args.sparsity)]
            if args.sparsity is not None else [])

runs = [
    # 1: calibrate once (the single entry point), persist the bank
    [sys.executable, "-m", "repro.launch.calibrate", "--arch", args.arch,
     "--smoke", "--out", artifact, "--metric", "wanda", "--mode", "nm",
     "--steps", "30", "--seq", "64"],
    # 2: serve compressed from the bank - no re-calibration
    base + ["--sparse-artifact", artifact] + sparsity,
    # 3: same masks, masked-dense weights - tokens must match run 2
    base + ["--sparse-artifact", artifact, "--weight-format", "masked"]
    + sparsity,
    # 4: the same ONE bank serving three budgets concurrently, A/B split
    base + ["--sparse-artifact", artifact, "--fleet", "0.0,0.5,2:4",
            "--ab", "1,1,2"],
]
for cmd in runs:
    print("+", " ".join(cmd), flush=True)
    rc = subprocess.call(cmd)
    if rc:
        raise SystemExit(rc)
