"""Batched serving of a 2:4-pruned model (paper Table 8 scenario).

  PYTHONPATH=src python examples/serve_sparse.py
"""
import subprocess
import sys

# The serve launcher is the real entry point; this example drives it with
# a sparse model + batched requests.
cmd = [sys.executable, "-m", "repro.launch.serve", "--arch", "llama3.2-1b",
       "--smoke", "--batch", "4", "--prompt-len", "64", "--gen", "16",
       "--sparse"]
print("+", " ".join(cmd))
raise SystemExit(subprocess.call(cmd))
