"""Quickstart: prune a small LM with UniPruning in ~2 minutes on CPU.

Calibration runs once through ``launch.calibrate`` and lands as a MaskBank
artifact; every budget afterwards is a one-shot re-threshold of it.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import ModelConfig, PruneConfig
from repro.core import calibrate, masks as masks_mod
from repro.data.synthetic import batches_for
from repro.models import model as M
from repro.optim.losses import eval_ppl

# 1. a model (normally: restored pretrained weights; here: 80 quick steps
#    on the synthetic corpus so the pruned-quality numbers mean something)
cfg = ModelConfig(name="demo", family="dense", d_model=128, num_layers=4,
                  num_heads=4, num_kv_heads=2, head_dim=32, d_ff=384,
                  vocab_size=512)
params = M.init_params(cfg, jax.random.key(0))

from repro.optim import optimizers as opt
from repro.optim.losses import lm_loss

_train = batches_for(cfg, n=20, batch=16, seq=128, split="train")
_ocfg = opt.AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=80)
_ostate = opt.adamw_init(params)


@jax.jit
def _step(p, o, b):
    (l, _), g = jax.value_and_grad(
        lambda pp, bb: lm_loss(cfg, pp, bb), has_aux=True)(p, b)
    p, o, _ = opt.adamw_update(_ocfg, g, o, p)
    return p, o, l


for i in range(80):
    params, _ostate, _loss = _step(params, _ostate, _train[i % len(_train)])

# 2. a calibration set (normally: 128 C4 samples)
calib = batches_for(cfg, n=8, batch=8, seq=128, split="calib")

# 3. UniPruning through the one entry point: jitted stats -> scanned
#    mirror-descent search -> a persisted MaskBank artifact.  Any budget is
#    then a one-shot re-threshold of the artifact - here, in another
#    process, or on the serving mesh.
from repro.launch.calibrate import calibrate_to_bank

pcfg = PruneConfig(local_metric="stochria", steps=30, stats_batches=2)
bank = calibrate_to_bank("results/bank/quickstart", cfg=cfg, pcfg=pcfg,
                         params=params, calib=calib, arch=cfg.name,
                         smoke=False)

valid = batches_for(cfg, n=2, batch=8, seq=128, split="valid")
print(f"dense  PPL: {eval_ppl(cfg, params, valid):.2f}")
for sp in [0.5, 0.7]:
    p = masks_mod.apply_masks(params, bank.masks_at(sparsity=sp))
    print(f"{int(sp*100)}%-sparse PPL: {eval_ppl(cfg, p, valid):.2f}")

# 4. baselines share the bank's persisted stats + the mask machinery
wanda = calibrate.baseline_masks("wanda", params, bank.stats, 0.5)
print(f"wanda 50% PPL: "
      f"{eval_ppl(cfg, masks_mod.apply_masks(params, wanda), valid):.2f}")
