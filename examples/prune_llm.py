"""End-to-end driver: train an LM on the synthetic corpus, prune it with
UniPruning and every baseline, evaluate, and export 2:4 weights.

Default scale finishes in ~5 min on CPU; --full trains a ~100M-param model
(same code path, a few hundred steps).

  PYTHONPATH=src python examples/prune_llm.py [--full] [--steps 300]
"""
import argparse
import time

import jax

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, PruneConfig
from repro.core import calibrate, masks as masks_mod
from repro.data.synthetic import batches_for
from repro.models import model as M
from repro.optim import optimizers as opt
from repro.optim.losses import eval_ppl, lm_loss

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="~100M-param model")
ap.add_argument("--steps", type=int, default=None)
ap.add_argument("--ckpt-dir", default="/tmp/prune_llm_ckpt")
args = ap.parse_args()

if args.full:
    cfg = ModelConfig(name="llm-100m", family="dense", d_model=640,
                      num_layers=10, num_heads=10, num_kv_heads=5,
                      head_dim=64, d_ff=2560, vocab_size=50304)
    steps = args.steps or 300
    batch, seq = 8, 512
else:
    cfg = ModelConfig(name="llm-mini", family="dense", d_model=192,
                      num_layers=6, num_heads=6, num_kv_heads=3,
                      head_dim=32, d_ff=512, vocab_size=1024)
    steps = args.steps or 250
    batch, seq = 16, 128

params = M.init_params(cfg, jax.random.key(0))
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"model: {n_params/1e6:.1f}M params")

# --- train --------------------------------------------------------------
train = batches_for(cfg, n=64, batch=batch, seq=seq, split="train")
ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=steps // 10, total_steps=steps)
ostate = opt.adamw_init(params)
mgr = CheckpointManager(args.ckpt_dir)

@jax.jit
def step(params, ostate, b):
    (l, m), g = jax.value_and_grad(
        lambda p, bb: lm_loss(cfg, p, bb, remat=True), has_aux=True)(params, b)
    params, ostate, om = opt.adamw_update(ocfg, g, ostate, params)
    return params, ostate, l

t0 = time.time()
for i in range(steps):
    params, ostate, loss = step(params, ostate, train[i % len(train)])
    if i % 50 == 0:
        print(f"  step {i} loss {float(loss):.3f} ({time.time()-t0:.0f}s)",
              flush=True)
        mgr.save_async(i, (params, ostate), metadata={"next_step": i})
mgr.wait()
valid = batches_for(cfg, n=3, batch=batch, seq=seq, split="valid")
print(f"dense PPL: {eval_ppl(cfg, params, valid):.2f}")

# --- prune: baselines + UniPruning, unstructured + 2:4 -------------------
# Both searches run ONCE through launch.calibrate and persist as MaskBank
# artifacts; every budget below is a re-threshold of a saved bank, and the
# baselines consume the bank's persisted activation stats.
from repro.launch.calibrate import calibrate_to_bank

calib = batches_for(cfg, n=12, batch=8, seq=seq, split="calib")
pcfg = PruneConfig(local_metric="stochria", steps=60, stats_batches=3)
bank = calibrate_to_bank(f"results/bank/{cfg.name}-unstructured", cfg=cfg,
                         pcfg=pcfg, params=params, calib=calib,
                         arch=cfg.name, smoke=False)
for m in ["magnitude", "wanda", "ria"]:
    mk = calibrate.baseline_masks(m, params, bank.stats, 0.6)
    print(f"{m:10s} 60% PPL: "
          f"{eval_ppl(cfg, masks_mod.apply_masks(params, mk), valid):.2f}")

for sp in [0.5, 0.6, 0.7]:
    pruned = masks_mod.apply_masks(params, bank.masks_at(sparsity=sp))
    print(f"unipruning {int(sp*100)}% PPL: "
          f"{eval_ppl(cfg, pruned, valid):.2f}")

pcfg24 = PruneConfig(local_metric="wanda", mode="nm", steps=40,
                     stats_batches=3)
bank24 = calibrate_to_bank(f"results/bank/{cfg.name}-nm", cfg=cfg,
                           pcfg=pcfg24, params=params, calib=calib,
                           arch=cfg.name, smoke=False)
mk = bank24.masks_at()
pruned24 = masks_mod.apply_masks(params, mk)
print(f"unipruning 2:4 PPL: {eval_ppl(cfg, pruned24, valid):.2f} "
      f"(sparsity {masks_mod.sparsity_of(mk):.3f})")
print("done.")
